"""Full-batch GCN training on a synthetic Cora-like graph.

    PYTHONPATH=src python examples/gnn_fullbatch.py

The layer aggregation runs on the GRE scatter-combine primitive; labels are
planted communities so accuracy is verifiable."""
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.gnn import (GraphBatch, compute_gcn_edge_norm, gnn_forward,
                              gnn_loss, init_gnn)
from repro.optim.adamw import AdamW

SMOKE = bool(os.environ.get("REPRO_SMOKE"))  # tiny sizes in CI
cfg, _ = get_config("gcn-cora")
rng = np.random.default_rng(0)

# synthetic community graph: 7 planted clusters + noise edges
V, C = (700 if SMOKE else 1400), cfg.n_classes
labels = rng.integers(0, C, V)
intra = [(u, v) for _ in range(V * 40)
         for u, v in [rng.integers(0, V, 2)] if labels[u] == labels[v]]
noise = [tuple(rng.integers(0, V, 2)) for _ in range(V // 2)]
edges = np.array(intra + noise)
src, dst = jnp.asarray(edges[:, 0], jnp.int32), jnp.asarray(edges[:, 1], jnp.int32)
mask = jnp.ones(len(edges), bool)
feats = jax.random.normal(jax.random.PRNGKey(0), (V, 64)) * 0.1
feats = feats.at[jnp.arange(V), jnp.asarray(labels % 64)].add(1.0)  # weak signal
train_mask = jnp.asarray(rng.random(V) < 0.5)

batch = GraphBatch(feats, src, dst, mask, jnp.asarray(labels), train_mask,
                   edge_norm=compute_gcn_edge_norm(src, dst, mask, V))
params = init_gnn(jax.random.PRNGKey(1), cfg, 64, C)
opt = AdamW(lr=5e-2, weight_decay=0.0)
opt_state = opt.init(params)


@jax.jit
def step(p, o):
    loss, g = jax.value_and_grad(gnn_loss)(p, batch, cfg)
    p, o = opt.update(g, o, p)
    return p, o, loss


for it in range(120 if SMOKE else 250):
    params, opt_state, loss = step(params, opt_state)
    if it % 30 == 0:
        print(f"iter {it:3d} loss {float(loss):.3f}")

logits = gnn_forward(params, batch, cfg)
pred = np.asarray(jnp.argmax(logits, -1))
test = ~np.asarray(train_mask)
acc = (pred[test] == labels[test]).mean()
print(f"test accuracy on planted communities: {acc:.3f}")
assert acc > 0.5, "GCN failed to learn planted structure"
