"""Continuous-batching LM serving: requests with different prompt lengths
stream through a fixed 4-slot decode batch (no decode step waits for a
prefill; static shapes — zero recompilation).

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.launch.train import reduced_lm_config
from repro.models import transformer as tfm
from repro.serving.scheduler import ContinuousBatcher, Request

cfg, _ = get_config("smollm-135m")
cfg = reduced_lm_config(cfg)
params = tfm.init_lm(jax.random.PRNGKey(0), cfg)

rng = np.random.default_rng(0)
sched = ContinuousBatcher(params, cfg, batch_slots=4, max_len=96)
reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=plen)
                .astype(np.int32), max_new=12)
        for i, plen in enumerate([8, 25, 12, 40, 16, 31, 9, 22])]
for r in reqs:
    sched.submit(r)

t0 = time.time()
steps = 0
while any(not r.done for r in reqs):
    active = sched.step()
    steps += 1
    if steps % 5 == 0:
        done = sum(r.done for r in reqs)
        print(f"step {steps:3d}: {active} active slots, {done}/8 done")
dt = time.time() - t0
total = sum(len(r.out) for r in reqs)
print(f"served 8 requests ({total} tokens) in {steps} scheduler steps, "
      f"{dt:.1f}s ({total / dt:.1f} tok/s)")
for r in reqs[:3]:
    print(f"  req {r.uid} (prompt {len(r.prompt)}): {r.out[:6]}...")
assert all(r.done and len(r.out) == 12 for r in reqs)
