"""Continuous batching of graph traversal queries over payload lanes.

A Poisson stream of mixed BFS / SSSP / PPR queries hits a
`ServingFrontend` (one `GraphQueryBatcher` per kind, D=4 lanes each) on a
power-law graph.  Lanes recycle between supersteps: short queries stream
through lanes a long query is not using, and the jitted superstep never
recompiles.  See `examples/recsys_serve.py` for the same scheduler over a
`DistGREEngine` mesh.

    PYTHONPATH=src python examples/continuous_batching.py
    REPRO_SMOKE=1 PYTHONPATH=src python examples/continuous_batching.py  # CI
"""
import os
import time

import numpy as np

from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import rmat_edges
from repro.serving import GraphQueryBatcher, ServingFrontend, poisson_ticks

SMOKE = bool(os.environ.get("REPRO_SMOKE"))
SCALE = 8 if SMOKE else 12
NUM_QUERIES = 12 if SMOKE else 64
D = 4

g = rmat_edges(scale=SCALE, edge_factor=8, seed=0, weights=True).dedup()
part = DevicePartition.from_graph(g)
print(f"graph: V={g.num_vertices} E={g.num_edges}")

frontend = ServingFrontend({
    "bfs": GraphQueryBatcher(GREEngine(algorithms.bfs_program(D)), part),
    "sssp": GraphQueryBatcher(GREEngine(algorithms.sssp_program(D)), part),
    # PPR pins frontier="dense" (docs/serving.md: sum monoids are bitwise
    # order-stable only on a fixed strategy) and carries a superstep budget
    "ppr": GraphQueryBatcher(
        GREEngine(algorithms.ppr_push_program(D), frontier="dense"), part,
        default_budget=256),
})

rng = np.random.default_rng(0)
kinds = rng.choice(["bfs", "sssp", "ppr"], size=NUM_QUERIES)
roots = rng.integers(0, g.num_vertices, size=NUM_QUERIES)
arrivals = poisson_ticks(NUM_QUERIES, rate_per_tick=1.5, rng=rng)

t0 = time.time()
done, nxt, rounds = [], 0, 0
while len(done) < NUM_QUERIES:
    while nxt < NUM_QUERIES and arrivals[nxt] <= rounds:  # Poisson arrivals
        frontend.submit(str(kinds[nxt]), int(roots[nxt]))
        nxt += 1
    done.extend(frontend.step())
    rounds += 1
dt = time.time() - t0

print(f"served {len(done)} queries in {rounds} rounds, {dt:.1f}s "
      f"({len(done) / dt:.1f} q/s)")
for kind, m in frontend.metrics().items():
    print(f"  {kind:5s} done={m['queries_done']:.0f} "
          f"p50={m['latency_p50_s'] * 1e3:.0f}ms "
          f"p95={m['latency_p95_s'] * 1e3:.0f}ms "
          f"occupancy={m['lane_occupancy']:.2f} "
          f"supersteps_p50={m['supersteps_p50']:.0f}")
assert len(done) == NUM_QUERIES
assert all(q.status in ("done", "evicted") for q in done)
