"""Recsys candidate generation as distributed PPR serving.

Personalized-PageRank forward push from each user's seed vertex is the
classic graph-side candidate generator: the top-scoring vertices of the
push are the recommendation pool.  Here a Poisson stream of such queries
runs through a `GraphQueryBatcher` over a `DistGREEngine` on 8 simulated
devices — lanes recycle between supersteps (no recompilation, no
re-initialization), and each query carries a superstep budget so a
pathological seed cannot pin a lane forever.

    PYTHONPATH=src python examples/recsys_serve.py
    REPRO_SMOKE=1 PYTHONPATH=src python examples/recsys_serve.py  # CI
"""
import os

SMOKE = bool(os.environ.get("REPRO_SMOKE"))
K = 2 if SMOKE else 8
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={K}")

import numpy as np
import jax

from repro.core import algorithms
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core.partition import greedy_partition
from repro.graph.generators import rmat_edges
from repro.serving import GraphQueryBatcher, poisson_ticks

SCALE = 8 if SMOKE else 12
NUM_QUERIES = 8 if SMOKE else 48
D = 4  # payload lanes = concurrently resident queries

g = rmat_edges(scale=SCALE, edge_factor=8, seed=1).dedup()
ag = build_agent_graph(g, greedy_partition(g, K, batch_size=128), K)
mesh = jax.make_mesh((K,), ("graph",))
# PPR is a sum-monoid program: pin frontier="dense" so recycled-lane
# results are bitwise stable (docs/serving.md), and budget each query.
eng = DistGREEngine(algorithms.ppr_push_program(D), mesh, ("graph",),
                    exchange="pipelined", frontier="dense")
batcher = GraphQueryBatcher(eng, ag, steps_per_tick=2, default_budget=128)
print(f"graph: V={g.num_vertices} E={g.num_edges} shards={K} lanes={D}")

rng = np.random.default_rng(0)
seeds = rng.integers(0, g.num_vertices, size=NUM_QUERIES)
arrivals = poisson_ticks(NUM_QUERIES, rate_per_tick=0.75, rng=rng)

done, nxt, rounds = [], 0, 0
while len(done) < NUM_QUERIES:
    while nxt < NUM_QUERIES and arrivals[nxt] <= rounds:
        batcher.submit(int(seeds[nxt]))
        nxt += 1
    done.extend(batcher.pump())
    if batcher.busy:
        batcher.tick()
    rounds += 1

m = batcher.metrics()
print(f"served {m['queries_done']:.0f} queries "
      f"({m['queries_evicted']:.0f} evicted) in {m['supersteps']:.0f} "
      f"supersteps; occupancy={m['lane_occupancy']:.2f} "
      f"p50={m['latency_p50_s'] * 1e3:.0f}ms "
      f"p95={m['latency_p95_s'] * 1e3:.0f}ms")
for q in done[:3]:
    mass = np.asarray(q.result)  # lane_view: per-vertex PPR estimate [n]
    top = np.argsort(-mass)[:5]
    print(f"  user seed {q.source}: top-5 candidates {top.tolist()} "
          f"(mass {mass[top].round(4).tolist()}, "
          f"{q.supersteps_used} supersteps)")
assert len(done) == NUM_QUERIES
assert all(q.status in ("done", "evicted") for q in done)
