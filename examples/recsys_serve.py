"""AutoInt CTR serving with batched requests + retrieval scoring.

    PYTHONPATH=src python examples/recsys_serve.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.autoint import (autoint_logits, init_autoint,
                                  retrieval_scores, synth_batch)

cfg, _ = get_config("autoint")
cfg = dataclasses.replace(cfg, vocab_sizes=tuple([5000] * cfg.n_sparse))
key = jax.random.PRNGKey(0)
params = init_autoint(key, cfg)

serve = jax.jit(lambda p, ids: autoint_logits(p, ids, cfg))
batch = synth_batch(key, cfg, 512)
logits = serve(params, batch["ids"])
t0 = time.time()
for i in range(5):
    b = synth_batch(jax.random.PRNGKey(i), cfg, 512)
    jax.block_until_ready(serve(params, b["ids"]))
dt = (time.time() - t0) / 5
print(f"serve_p99-style batch=512: {dt * 1e3:.1f} ms/batch "
      f"({512 / dt:.0f} req/s) logits[:4]={logits[:4].tolist()}")

# retrieval: one user against 100k candidates, single batched dot
cand = jax.random.normal(key, (100_000, cfg.d_attn))
proj = jax.random.normal(key, (cfg.n_sparse * cfg.d_attn, cfg.d_attn)) * 0.02
score = jax.jit(lambda p, ids, c, pr: retrieval_scores(p, ids, c, pr, cfg))
s = score(params, batch["ids"][:1], cand, proj)
top = jnp.argsort(-s)[:5]
print(f"retrieval over {cand.shape[0]} candidates; top-5 ids: {top.tolist()}")
