"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # full run
    PYTHONPATH=src python examples/train_lm.py --tiny     # CI-sized

Uses the full framework path: config -> data pipeline -> AdamW ->
checkpointing -> train loop (smollm-135m family; the --tiny flag shrinks
width/depth for CPU)."""
import argparse

from repro.launch import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    argv = ["--arch", "smollm-135m", "--lr", "1e-2",
            "--ckpt", "/tmp/gre_lm_ckpt", "--ckpt-every", "100"]
    if args.tiny:
        argv += ["--steps", "40", "--batch", "4", "--seq", "64"]
    else:
        argv += ["--steps", str(args.steps), "--batch", "16", "--seq", "256"]
    loss = train.main(argv)
    print(f"done; final loss {loss:.3f}")
