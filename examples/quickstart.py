"""Quickstart: PageRank on an R-MAT graph with the GRE Scatter-Combine engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

import numpy as np

from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.core.partition import greedy_partition, hash_edge_cut, partition_quality
from repro.graph.generators import rmat_edges

SCALE = 9 if os.environ.get("REPRO_SMOKE") else 12  # tiny sizes in CI

# 1. a Graph500-style scale-free graph (paper §7 generator parameters)
g = rmat_edges(scale=SCALE, edge_factor=16, seed=0).dedup()
print(f"graph: |V|={g.num_vertices} |E|={g.num_edges}")

# 2. run PageRank: 30 BSP supersteps of scatter -> combine -> apply
part = DevicePartition.from_graph(g)
engine = GREEngine(algorithms.pagerank_program())
state = engine.run(part, engine.init_state(part), max_steps=30)
pr = np.asarray(state.vertex_data)
top = np.argsort(-pr)[:5]
print("top-5 pagerank vertices:", [(int(v), round(float(pr[v]), 2)) for v in top])

# 3. SSSP from vertex 0 (halts when no vertex is active)
gw = rmat_edges(scale=SCALE, edge_factor=16, seed=0, weights=True).dedup()
pw = DevicePartition.from_graph(gw)
engine = GREEngine(algorithms.sssp_program())
state = engine.run(pw, engine.init_state(pw, source=0), max_steps=500)
dist = np.asarray(state.vertex_data)
print(f"sssp: reached {np.isfinite(dist).sum()} vertices "
      f"in {int(state.step)} supersteps")

# 4. Agent-Graph partitioning quality (paper Fig. 11)
partq = partition_quality(g, greedy_partition(g, 16, batch_size=256))
print(f"agent-graph k=16: equivalent edge-cut {partq.equivalent_edge_cut:.3f} "
      f"vs random-hash {hash_edge_cut(g, 16):.3f} "
      f"({hash_edge_cut(g, 16) / partq.equivalent_edge_cut:.1f}x better); "
      f"agent comm {partq.agent_comm} <= vertex-cut comm {partq.vertexcut_comm}")
