"""Distributed SSSP + checkpoint/restart over the Agent-Graph exchange on
8 simulated devices.

    PYTHONPATH=src python examples/distributed_sssp.py

Shows: greedy partitioning -> agent-graph build -> shard_map BSP execution
-> paper-§6.3 snapshot (masters + bitmap only) -> restore and continue."""
import os

SMOKE = bool(os.environ.get("REPRO_SMOKE"))  # tiny sizes in CI
_K = 2 if SMOKE else 8
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={_K}")

import numpy as np
import jax

from repro.checkpoint.manager import (CheckpointManager, graph_engine_restore,
                                      graph_engine_snapshot)
from repro.core import algorithms
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core.partition import greedy_partition, partition_quality
from repro.graph.generators import rmat_edges

g = rmat_edges(scale=9 if SMOKE else 11, edge_factor=16, seed=0,
               weights=True).dedup()
k = _K
part = greedy_partition(g, k, batch_size=256)
q = partition_quality(g, part)
print(f"|V|={g.num_vertices} |E|={g.num_edges} k={k} "
      f"equiv-cut={q.equivalent_edge_cut:.3f} "
      f"agent_comm={q.agent_comm} (vertex-cut would be {q.vertexcut_comm})")

ag = build_agent_graph(g, part, k)
mesh = jax.make_mesh((k,), ("graph",))
# pipelined exchange: the flush collective overlaps the local-tile combine
# (docs/exchange.md); bitwise-identical results to exchange="agent"
eng = DistGREEngine(algorithms.sssp_program(), mesh, ("graph",),
                    exchange="pipelined")

# run 5 supersteps, snapshot, run to completion, then verify a restore
state0 = eng.init_state(ag, source=0)
topo = eng.device_topology(ag)
run5 = eng.make_run(ag, max_steps=5)
mid = run5(topo, state0)
mgr = CheckpointManager("/tmp/gre_sssp_ckpt", async_write=False)
mgr.save(int(mid.step[0]), graph_engine_snapshot(mid, ag.cap))
print(f"snapshot at superstep {int(mid.step[0])} "
      f"(masters+bitmap only, agents dropped)")

snap, _ = mgr.restore(jax.tree.map(
    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
    graph_engine_snapshot(mid, ag.cap)))
resumed = graph_engine_restore(snap, ag.num_slots, identity=np.inf)
final = eng.make_run(ag, max_steps=500)(topo, resumed)
dist_resumed = np.asarray(final.vertex_data).reshape(-1)[ag.old2new]

full = eng.make_run(ag, max_steps=500)(topo, state0)
dist_full = np.asarray(full.vertex_data).reshape(-1)[ag.old2new]
same = np.allclose(np.nan_to_num(dist_resumed, posinf=-1),
                   np.nan_to_num(dist_full, posinf=-1))
print(f"resumed run matches uninterrupted run: {same}")
print(f"reached {np.isfinite(dist_full).sum()} / {g.num_vertices} vertices")
assert same
