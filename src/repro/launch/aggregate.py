"""Aggregate dry-run JSON records into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.launch.aggregate results/dryrun
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.launch.roofline import PEAK_FLOPS


def load(outdir: str, mesh: str = "single"):
    recs = []
    for f in sorted(Path(outdir).glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("ok"):
            recs.append(r)
    return recs


def fmt_row(r):
    rl = r["roofline"]
    meta = r.get("meta", {})
    n_dev = r.get("n_devices", 256)
    model_flops = meta.get("model_flops", 0.0)
    hlo_flops_total = rl["flops_per_device"] * n_dev
    ratio = model_flops / hlo_flops_total if hlo_flops_total else 0.0
    bound = rl["bound_time_s"]
    # roofline fraction: useful-compute time / bound time
    ideal_compute = model_flops / (n_dev * PEAK_FLOPS)
    frac = ideal_compute / bound if bound else 0.0
    mem = r.get("memory", {}).get("peak_per_device_gib", float("nan"))
    return {
        "arch": r["arch"], "shape": r["shape"], "kind": r["kind"],
        "compute_s": rl["compute_time_s"],
        "memory_s": rl.get("memory_time_fused_s", rl["memory_time_s"]),
        "memory_raw_s": rl["memory_time_s"],
        "coll_s": rl["collective_time_s"], "dominant": rl["dominant"],
        "model_flops": model_flops, "hlo_ratio": ratio,
        "roofline_frac": frac, "mem_gib": mem,
        "n_coll": rl.get("n_collectives", 0),
    }


def markdown_table(rows):
    hdr = ("| arch | shape | kind | compute (s) | memory fused (s) | raw (s) "
           "| collective (s) | dominant | MODEL_FLOPS | MODEL/HLO "
           "| roofline frac | mem GiB/dev |")
    sep = "|" + "---|" * 12
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['memory_raw_s']:.3e} "
            f"| {r['coll_s']:.3e} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | {r['hlo_ratio']:.2f} "
            f"| {r['roofline_frac']:.4f} | {r['mem_gib']} |")
    return "\n".join(out)


def main():
    outdir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    rows = [fmt_row(r) for r in load(outdir, mesh)]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(markdown_table(rows))
    print(f"\n{len(rows)} cells ({mesh} mesh)")
    if rows:
        worst = min(rows, key=lambda r: r["roofline_frac"] or 1)
        collb = max(rows, key=lambda r: r["coll_s"])
        print(f"worst roofline fraction: {worst['arch']} × {worst['shape']} "
              f"({worst['roofline_frac']:.4f})")
        print(f"most collective-bound: {collb['arch']} × {collb['shape']} "
              f"({collb['coll_s']:.3e}s)")


if __name__ == "__main__":
    main()
