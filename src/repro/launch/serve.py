"""Serving launcher: batched prefill + decode loop on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --batch 4 --prompt-len 64 --gen 32

Demonstrates the inference path of the framework (continuous batched decode
with a static KV cache); the production-shape serving steps are exercised by
the dry-run (prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.train import reduced_lm_config
from repro.models import transformer as tfm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, family = get_config(args.arch)
    assert family == "lm"
    cfg = reduced_lm_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_lm(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(lambda p, t: tfm.prefill(p, t, cfg, max_len=max_len))
    decode = jax.jit(lambda p, c, t: tfm.decode_step(p, c, t, cfg),
                     donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())
    return gen


if __name__ == "__main__":
    main()
