"""Training launcher (runs REAL steps — used by examples and the e2e test;
the production mesh path is exercised by dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 50 --batch 8 --seq 256 --mesh 1x1 --ckpt /tmp/ckpt

Fault tolerance: auto-resume from the newest snapshot; `--fail-at N`
simulates a crash at step N (the e2e test restarts and checks bit-identical
continuation).  `--grad-compression` turns on int8 error-feedback gradient
all-reduce across the data axis.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh, mesh_axes
from repro.models import transformer as tfm
from repro.optim import compression
from repro.optim.adamw import AdamW, cosine_warmup


def reduced_lm_config(cfg, layers=4, d_model=128, n_heads=4, n_kv=2,
                      d_head=32, d_ff=256, vocab=1024):
    """Shrink an assigned config to a trainable-on-CPU size, keeping its
    family structure (MoE stays MoE, activation stays)."""
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=min(moe.n_experts, 8),
                                  d_ff_expert=d_ff)
    return dataclasses.replace(
        cfg, n_layers=layers, d_model=d_model, n_heads=n_heads, n_kv=n_kv,
        d_head=d_head, d_ff=d_ff, vocab=vocab, moe=moe, dtype="float32",
        q_chunk=64, kv_chunk=64, remat_block=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="1x1", help="DxM, e.g. 2x4")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--full-size", action="store_true",
                    help="use the arch's real config (needs real hardware)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, family = get_config(args.arch)
    assert family == "lm", "train.py drives LM archs; see examples/ for others"
    if not args.full_size:
        cfg = reduced_lm_config(cfg)

    d, m = (int(x) for x in args.mesh.split("x"))
    use_mesh = d * m > 1
    if use_mesh:
        mesh = make_mesh((d, m), ("data", "model"))
        ax = mesh_axes(mesh)
        ctx = tfm.DistCtx(mesh=mesh, dp=ax["dp"], tp=ax["tp"])
        pspecs = shd.lm_param_specs(cfg, ax["dp"], ax["tp"])
        pshard = shd.to_shardings(mesh, pspecs)
        bshard = {k: NamedSharding(mesh, v)
                  for k, v in shd.lm_batch_specs(ax["dp"]).items()}
    else:
        mesh, ctx, pshard, bshard = None, tfm.LOCAL_CTX, None, None

    opt = AdamW(lr=args.lr, schedule=cosine_warmup(10, args.steps))
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_lm(key, cfg)
    opt_state = opt.init(params)
    if use_mesh:
        params = jax.device_put(params, pshard)
        oshard = jax.tree.map(lambda s: s,
                              shd.opt_specs(pspecs))
        oshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), oshard)
        opt_state = jax.device_put(opt_state, oshard)

    err_state = compression.init_error(params) if args.grad_compression else None

    def train_step(params, opt_state, err, batch):
        (loss, parts), grads = jax.value_and_grad(
            tfm.lm_loss, has_aux=True)(params, batch, cfg, ctx)
        if err is not None:
            # int8 error-feedback compression of the gradient signal
            q, scales, err = compression.compress(grads, err)
            grads = compression.decompress(q, scales)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, err, loss

    jitted = jax.jit(train_step, donate_argnums=(0, 1, 2))

    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        (params, opt_state), start = ckpt.restore((params, opt_state))
        if use_mesh:
            params = jax.device_put(params, pshard)
            opt_state = jax.device_put(opt_state, oshard)
        print(f"resumed from step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        if step == args.fail_at:
            print(f"simulated failure at step {step}")
            raise SystemExit(42)
        hb = stream.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in hb.items()}
        if use_mesh:
            batch = {k: jax.device_put(v, bshard[k]) for k, v in batch.items()}
        params, opt_state, err_state, loss = jitted(params, opt_state,
                                                    err_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state))
    if ckpt:
        ckpt.save(args.steps, (params, opt_state))
        ckpt.wait()
    print(f"final loss {float(loss):.4f}")
    return float(loss)


if __name__ == "__main__":
    main()
