"""Dry-run cell construction: one Cell per (architecture × input shape).

A Cell bundles the jittable step function, fully-abstract inputs
(ShapeDtypeStructs with NamedShardings — never allocated), explicit output
shardings, and analytic MODEL_FLOPS metadata for the roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.configs.base import (GNNConfig, GNNShape, LMConfig, LMShape,
                                RecSysConfig, RecSysShape)
from repro.dist import sharding as shd
from repro.launch.mesh import mesh_axes
from repro.models import transformer as tfm
from repro.models.autoint import autoint_loss, autoint_logits, retrieval_scores
from repro.models.gnn import GraphBatch, gnn_forward, gnn_loss, init_gnn, propagate_sharded
from repro.models.dimenet import dimenet_forward, init_dimenet
from repro.models.mace import init_mace, mace_forward
from repro.models.autoint import init_autoint
from repro.nn.embedding import sharded_embedding_lookup
from repro.optim.adamw import AdamW

R8 = lambda x: max(8, int(-(-x // 8) * 8))


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    abstract_args: Tuple
    out_shardings: Any
    meta: Dict[str, Any]
    donate_argnums: Tuple[int, ...] = ()


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _abstract(tree, mesh, specs):
    return shd.abstract_with_sharding(tree, mesh, specs)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=_ns(mesh, spec))


# ====================================================================== LM
def _lm_cell(arch: str, cfg: LMConfig, shape: LMShape, mesh: Mesh) -> Cell:
    ax = mesh_axes(mesh)
    dp, tp = ax["dp"], ax["tp"]
    ctx = tfm.DistCtx(mesh=mesh, dp=dp, tp=tp)
    pspecs = shd.lm_param_specs(cfg, dp, tp)
    params_abs = _abstract(tfm.abstract_params(cfg), mesh, pspecs)
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt = AdamW(lr=3e-4)
        ospecs = shd.opt_specs(pspecs)
        opt_abs = _abstract(jax.eval_shape(opt.init, params_abs), mesh, ospecs)
        bspecs = shd.lm_batch_specs(dp)
        batch_abs = {
            "tokens": _sds((B, S), jnp.int32, mesh, bspecs["tokens"]),
            "labels": _sds((B, S), jnp.int32, mesh, bspecs["labels"]),
        }

        def train_step(params, opt_state, batch):
            (loss, parts), grads = jax.value_and_grad(
                tfm.lm_loss, has_aux=True)(params, batch, cfg, ctx)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, {"loss": loss, **parts}

        out_sh = (shd.to_shardings(mesh, pspecs),
                  jax.tree.map(lambda s: _ns(mesh, s), ospecs),
                  {"loss": _ns(mesh, P()), "ce": _ns(mesh, P()),
                   "moe_aux": _ns(mesh, P())})
        return Cell(arch, shape.name, "train", train_step,
                    (params_abs, opt_abs, batch_abs), out_sh,
                    {"model_flops": 6.0 * n_active * B * S,
                     "tokens": B * S, "params": cfg.param_count(),
                     "active_params": n_active,
                     "scan_lengths": _lm_trips(cfg, S)},
                    donate_argnums=(0, 1))

    if shape.kind == "prefill":
        tok_abs = _sds((B, S), jnp.int32, mesh, P(shd.dp_entry(dp), None))
        cspecs = shd.lm_cache_specs(cfg, B, dp, tp, ax["dp_size"])

        def prefill_step(params, tokens):
            return tfm.prefill(params, tokens, cfg, ctx)

        out_sh = (_ns(mesh, P(shd.dp_entry(dp), tp)),
                  jax.tree.map(lambda s: _ns(mesh, s), cspecs))
        return Cell(arch, shape.name, "prefill", prefill_step,
                    (params_abs, tok_abs), out_sh,
                    {"model_flops": 2.0 * n_active * B * S,
                     "tokens": B * S, "params": cfg.param_count(),
                     "active_params": n_active,
                     "scan_lengths": _lm_trips(cfg, S)})

    # decode (decode_32k / long_500k): one new token against a seq_len cache
    cspecs = shd.lm_cache_specs(cfg, B, dp, tp, ax["dp_size"])
    cache_abs = {
        "k": _sds((cfg.n_layers, B, S, cfg.n_kv, cfg.d_head),
                  cfg.param_dtype, mesh, cspecs["k"]),
        "v": _sds((cfg.n_layers, B, S, cfg.n_kv, cfg.d_head),
                  cfg.param_dtype, mesh, cspecs["v"]),
        "len": _sds((B,), jnp.int32, mesh, cspecs["len"]),
    }
    tok_abs = _sds((B,), jnp.int32, mesh,
                   P(shd.dp_entry(dp)) if B >= ax["dp_size"] else P())

    def decode(params, cache, token):
        return tfm.decode_step(params, cache, token, cfg, ctx)

    out_sh = (_ns(mesh, P(shd.dp_entry(dp) if B >= ax["dp_size"] else None,
                          tp)),
              jax.tree.map(lambda s: _ns(mesh, s), cspecs))
    kv_bytes = (2 * cfg.n_layers * B * S * cfg.n_kv * cfg.d_head
                * jnp.dtype(cfg.param_dtype).itemsize)
    return Cell(arch, shape.name, "decode", decode,
                (params_abs, cache_abs, tok_abs), out_sh,
                {"model_flops": 2.0 * n_active * B +
                                4.0 * B * cfg.n_layers * cfg.n_heads
                                * cfg.d_head * S,
                 "tokens": B, "params": cfg.param_count(),
                 "active_params": n_active, "kv_bytes": float(kv_bytes),
                 "scan_lengths": {"layers": cfg.n_layers}},
                donate_argnums=(1,))


def _lm_trips(cfg: LMConfig, S: int) -> Dict[str, int]:
    """Static trip counts of every scan in the LM step (roofline hints)."""
    trips = {}
    if cfg.remat and cfg.n_layers % cfg.remat_block == 0 and cfg.remat_block > 1:
        trips["outer"] = cfg.n_layers // cfg.remat_block
        trips["inner"] = cfg.remat_block
    else:
        trips["layers"] = cfg.n_layers
    if cfg.attention_impl == "chunked":
        trips["q_chunks"] = max(1, min(S, -(-S // cfg.q_chunk)))
        trips["kv_chunks"] = max(1, -(-S // cfg.kv_chunk))
    return trips


# ====================================================================== GNN
def _agent_shape_estimates(V: int, E: int, K: int,
                           scatter_rate: float = 0.5) -> Dict[str, int]:
    """Static Agent-Graph partition shapes for the dry-run (no real graph is
    built at 10⁶+ scale on this host; stats follow the measured agent rates
    of the greedy partitioner — agents/vertex ≈ 2-4 on scale-free graphs;
    `scatter_rate` encodes the Fig. 12b scatter/combiner skew so the two
    exchange buffers are sized independently)."""
    cap = R8(-(-V // K))
    e_pad = R8(int(E / K * 1.25))
    agents = min(V - 1, 6 * cap)
    s_pad = R8(max(8, int(agents * max(scatter_rate, 0.1) * 1.25)))
    c_pad = R8(max(8, int(agents * max(1 - scatter_rate, 0.1) * 1.25)))
    s_x_pad = R8(max(8, (2 * s_pad) // K))
    c_x_pad = R8(max(8, (2 * c_pad) // K))
    return dict(cap=cap, e_pad=e_pad, s_pad=s_pad, c_pad=c_pad,
                s_x_pad=s_x_pad, c_x_pad=c_x_pad)


def _abstract_topo(est: Dict[str, int], K: int, mesh: Mesh, spec,
                   with_weight: bool = False):
    """ShapeDtypeStruct ShardTopology (stacked [K, ...]) for the dry run."""
    from repro.core.dist_engine import ShardTopology
    from repro.core.engine import DevicePartition
    cap, e_pad, s_pad, c_pad = (est["cap"], est["e_pad"], est["s_pad"],
                                est["c_pad"])
    s_x, c_x = est["s_x_pad"], est["c_x_pad"]
    slots = cap + s_pad + c_pad + 1
    f = lambda shape, dt: _sds(shape, dt, mesh, spec)
    part = DevicePartition(
        src=f((K, e_pad), jnp.int32), dst=f((K, e_pad), jnp.int32),
        edge_mask=f((K, e_pad), jnp.bool_), num_masters=cap,
        num_slots=slots, edges_sorted_by_dst=True,
        edge_props=({"weight": f((K, e_pad), jnp.float32)} if with_weight
                    else {}),
        aux={"out_degree": f((K, cap), jnp.float32),
             "global_id": f((K, cap), jnp.float32)},
    )
    return ShardTopology(
        part=part,
        comb_send_slot=f((K, K, c_x), jnp.int32),
        comb_recv_master=f((K, K, c_x), jnp.int32),
        scat_send_master=f((K, K, s_x), jnp.int32),
        scat_recv_slot=f((K, K, s_x), jnp.int32),
    )


def _gnn_flops(cfg: GNNConfig, V: int, E: int, d_in: int, T: int = 0) -> float:
    ch = cfg.d_hidden
    if cfg.family == "gcn":
        return 2.0 * (E * d_in + V * d_in * ch) + \
               2.0 * (cfg.n_layers - 1) * (E * ch + V * ch * ch)
    if cfg.family == "gin":
        f = 2.0 * (E * d_in + V * (d_in * ch + ch * ch))
        f += (cfg.n_layers - 1) * 2.0 * (E * ch + 2 * V * ch * ch)
        return f
    if cfg.family == "dimenet":
        per_block = 2.0 * T * ch * cfg.n_bilinear + 8.0 * E * ch * ch
        return cfg.n_layers * per_block + 4.0 * E * ch * cfg.n_radial
    if cfg.family == "mace":
        n_paths = 15  # valid (l1,l2,l3) for l_max=2
        per_layer = 2.0 * n_paths * E * ch * 27 + 6.0 * V * ch * ch \
                    + 2.0 * n_paths * V * ch * 27 * 2
        return cfg.n_layers * per_layer
    raise ValueError(cfg.family)


def _gnn_fullgraph_agent_cell(arch, cfg: GNNConfig, shape: GNNShape,
                              mesh: Mesh) -> Cell:
    """GCN/GIN full-graph training through the Agent-Graph exchange."""
    ax = mesh_axes(mesh)
    K = ax["n_devices"]
    axes = ax["all"]
    spec = P(axes)
    est = _agent_shape_estimates(shape.n_nodes, shape.n_edges, K)
    cap, slots = est["cap"], est["cap"] + est["s_pad"] + est["c_pad"] + 1
    d_in, n_out = shape.d_feat, cfg.n_classes
    topo_abs = _abstract_topo(est, K, mesh, spec)
    feats_abs = _sds((K, slots, d_in), jnp.float32, mesh, spec)
    norm_abs = _sds((K, est["e_pad"]), jnp.float32, mesh, spec)
    labels_abs = _sds((K, cap), jnp.int32, mesh, spec)
    mask_abs = _sds((K, cap), jnp.bool_, mesh, spec)
    params_abs = jax.eval_shape(
        lambda k: init_gnn(k, cfg, d_in, n_out),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    params_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=_ns(mesh, P())), params_abs)
    opt = AdamW(lr=1e-2)
    opt_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=_ns(mesh, P())),
        jax.eval_shape(opt.init, params_abs))

    def loss_fn(params, topo, feats, norm, labels, mask):
        def shard_loss(topo_s, feats_s, norm_s, labels_s, mask_s):
            sq = lambda t: jax.tree.map(lambda a: a[0], t)
            topo_l, h, nrm = sq(topo_s), feats_s[0], norm_s[0]
            lab, msk = labels_s[0], mask_s[0]

            def prop(hh, ew):
                full = jnp.zeros((slots, hh.shape[-1]), hh.dtype
                                 ).at[:hh.shape[0]].set(hh)
                out = propagate_sharded(full, topo_l, axes,
                                        ew if ew is not None else None)
                return out[:hh.shape[0]]

            b = GraphBatch(h, topo_l.part.src, topo_l.part.dst,
                           topo_l.part.edge_mask, lab, msk, edge_norm=nrm)
            # propagate over ALL slots; gnn_forward works on [slots, F]
            logits = gnn_forward(params, b, cfg, prop_fn=prop)
            logp = jax.nn.log_softmax(logits[:cap].astype(jnp.float32), -1)
            ll = jnp.take_along_axis(logp, lab[:, None], axis=-1)[:, 0]
            msk_f = msk.astype(jnp.float32)
            num = jax.lax.psum((ll * msk_f).sum(), axes)
            den = jax.lax.psum(msk_f.sum(), axes)
            return (-num / jnp.maximum(den, 1.0))[None]

        loss = shd.shard_map(
            shard_loss, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: spec, topo,
                                   is_leaf=lambda x: hasattr(x, "ndim")),
                      spec, spec, spec, spec),
            out_specs=P(axes[0] if len(axes) == 1 else axes))(
            topo, feats, norm, labels, mask)
        return loss.mean()

    def train_step(params, opt_state, topo, feats, norm, labels, mask):
        loss, grads = jax.value_and_grad(loss_fn)(params, topo, feats, norm,
                                                  labels, mask)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    out_sh = (jax.tree.map(lambda a: a.sharding, params_abs),
              jax.tree.map(lambda a: a.sharding, opt_abs),
              _ns(mesh, P()))
    return Cell(arch, shape.name, "train", train_step,
                (params_abs, opt_abs, topo_abs, feats_abs, norm_abs,
                 labels_abs, mask_abs), out_sh,
                {"model_flops": 3.0 * _gnn_flops(cfg, shape.n_nodes,
                                                 shape.n_edges, d_in),
                 "nodes": shape.n_nodes, "edges": shape.n_edges,
                 "agent_est": est, "exchange": "agent"},
                donate_argnums=(0, 1))


def _gnn_fullgraph_spmd_cell(arch, cfg: GNNConfig, shape: GNNShape,
                             mesh: Mesh) -> Cell:
    """DimeNet/MACE full-graph: GSPMD-sharded node/edge/triplet arrays
    (molecular models need positions; features are synthesized as 3D coords
    + species)."""
    ax = mesh_axes(mesh)
    axes = ax["all"]
    sp1 = P(axes)
    V, E = shape.n_nodes, shape.n_edges
    R512 = lambda x: max(512, int(-(-x // 512) * 512))
    Vp, Ep = R512(V), R512(E)
    # triplet count capped at 16·E (max_num_neighbors-style truncation)
    T = R512(min(16 * E, 2 ** 31 // 8))
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if cfg.family == "dimenet":
        params_abs = jax.eval_shape(lambda k: init_dimenet(k, cfg), key_abs)
    else:
        params_abs = jax.eval_shape(lambda k: init_mace(k, cfg), key_abs)
    params_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=_ns(mesh, P())), params_abs)
    opt = AdamW(lr=1e-3)
    opt_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=_ns(mesh, P())),
        jax.eval_shape(opt.init, params_abs))

    batch_abs = {
        "pos": _sds((Vp, 3), jnp.float32, mesh, sp1),
        "species": _sds((Vp,), jnp.int32, mesh, sp1),
        "src": _sds((Ep,), jnp.int32, mesh, sp1),
        "dst": _sds((Ep,), jnp.int32, mesh, sp1),
        "edge_mask": _sds((Ep,), jnp.bool_, mesh, sp1),
        "target": _sds((Vp,), jnp.float32, mesh, sp1),
    }
    if cfg.family == "dimenet":
        batch_abs.update({
            "tri_kj": _sds((T,), jnp.int32, mesh, sp1),
            "tri_ji": _sds((T,), jnp.int32, mesh, sp1),
            "tri_mask": _sds((T,), jnp.bool_, mesh, sp1),
        })

    def loss_fn(params, b):
        if cfg.family == "dimenet":
            def wsc(t):
                return jax.lax.with_sharding_constraint(
                    t, _ns(mesh, P(axes, *([None] * (t.ndim - 1)))))
            out = dimenet_forward(params, b["pos"], b["species"], b["src"],
                                  b["dst"], b["edge_mask"], b["tri_kj"],
                                  b["tri_ji"], b["tri_mask"], cfg, wsc=wsc)
        else:
            def prop(m, dst):
                # keep edge messages edge-sharded (otherwise SPMD replicates
                # the [E, ch, m] tensors after the node-feature all-gather)
                m = jax.lax.with_sharding_constraint(
                    m, _ns(mesh, P(axes, None, None)))
                agg = jax.ops.segment_sum(m, dst, Vp)
                return jax.lax.with_sharding_constraint(
                    agg, _ns(mesh, P(axes, None, None)))
            out = mace_forward(params, b["pos"], b["species"], b["src"],
                               b["dst"], b["edge_mask"], cfg, prop_fn=prop)
        return jnp.mean((out[:, 0] - b["target"]) ** 2)

    def train_step(params, opt_state, b):
        loss, grads = jax.value_and_grad(loss_fn)(params, b)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    out_sh = (jax.tree.map(lambda a: a.sharding, params_abs),
              jax.tree.map(lambda a: a.sharding, opt_abs), _ns(mesh, P()))
    return Cell(arch, shape.name, "train", train_step,
                (params_abs, opt_abs, batch_abs), out_sh,
                {"model_flops": 3.0 * _gnn_flops(cfg, V, E, 3, T),
                 "nodes": V, "edges": E, "triplets": T, "exchange": "spmd"},
                donate_argnums=(0, 1))


def _gnn_batched_cell(arch, cfg: GNNConfig, shape: GNNShape, mesh: Mesh,
                      minibatch: bool) -> Cell:
    """minibatch_lg (sampled subgraphs, one per data shard) and molecule
    (128 small graphs) — batch-parallel over dp, model replicated."""
    ax = mesh_axes(mesh)
    dp = shd.dp_entry(ax["dp"])
    if minibatch:
        G = ax["dp_size"]
        seeds = shape.batch_nodes
        f1, f2 = shape.fanout
        n_sub = R8(seeds * (1 + f1 + f1 * f2))
        e_sub = R8(seeds * (f1 + f1 * f2))
        d_in = shape.d_feat
    else:
        G = shape.batch_graphs
        n_sub, e_sub, d_in = R8(shape.n_nodes), R8(shape.n_edges), 16
    T = R8(e_sub * 8)
    sp = P(dp)
    molecular = cfg.family in ("dimenet", "mace")
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if cfg.family == "dimenet":
        params_abs = jax.eval_shape(lambda k: init_dimenet(k, cfg), key_abs)
    elif cfg.family == "mace":
        params_abs = jax.eval_shape(lambda k: init_mace(k, cfg), key_abs)
    else:
        params_abs = jax.eval_shape(
            lambda k: init_gnn(k, cfg, d_in, cfg.n_classes), key_abs)
    params_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=_ns(mesh, P())), params_abs)
    opt = AdamW(lr=1e-3)
    opt_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=_ns(mesh, P())),
        jax.eval_shape(opt.init, params_abs))

    g = lambda *s: _sds((G,) + s, jnp.int32, mesh, P(dp, *([None] * len(s))))
    gf = lambda *s: _sds((G,) + s, jnp.float32, mesh,
                         P(dp, *([None] * len(s))))
    gb = lambda *s: _sds((G,) + s, jnp.bool_, mesh, P(dp, *([None] * len(s))))
    batch_abs = {"src": g(e_sub), "dst": g(e_sub), "edge_mask": gb(e_sub)}
    if molecular:
        batch_abs.update({"pos": gf(n_sub, 3), "species": g(n_sub),
                          "target": gf(n_sub)})
        if cfg.family == "dimenet":
            batch_abs.update({"tri_kj": g(T), "tri_ji": g(T),
                              "tri_mask": gb(T)})
    elif minibatch:
        batch_abs.update({"feats": gf(n_sub, d_in), "labels": g(n_sub),
                          "train_mask": gb(n_sub),
                          "edge_norm": gf(e_sub)})
    else:  # molecule: GRAPH-level classification (GIN-TU semantics)
        batch_abs.update({"feats": gf(n_sub, d_in), "labels": g(),
                          "edge_norm": gf(e_sub)})

    def loss_one(params, b):
        if cfg.family == "dimenet":
            out = dimenet_forward(params, b["pos"], b["species"], b["src"],
                                  b["dst"], b["edge_mask"], b["tri_kj"],
                                  b["tri_ji"], b["tri_mask"], cfg)
            return jnp.mean((out[:, 0] - b["target"]) ** 2)
        if cfg.family == "mace":
            out = mace_forward(params, b["pos"], b["species"], b["src"],
                               b["dst"], b["edge_mask"], cfg)
            return jnp.mean((out[:, 0] - b["target"]) ** 2)
        if minibatch:
            gb_ = GraphBatch(b["feats"], b["src"], b["dst"], b["edge_mask"],
                             b["labels"], b["train_mask"],
                             edge_norm=b["edge_norm"])
            return gnn_loss(params, gb_, cfg)
        # one molecule per vmap lane: mean-pool to a graph logit
        gb_ = GraphBatch(b["feats"], b["src"], b["dst"], b["edge_mask"],
                         b["labels"][None], jnp.ones((1,), bool),
                         edge_norm=b["edge_norm"],
                         graph_ids=jnp.zeros((n_sub,), jnp.int32),
                         num_graphs=1)
        return gnn_loss(params, gb_, cfg)

    def loss_fn(params, batch):
        return jnp.mean(jax.vmap(lambda b: loss_one(params, b))(batch))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    out_sh = (jax.tree.map(lambda a: a.sharding, params_abs),
              jax.tree.map(lambda a: a.sharding, opt_abs), _ns(mesh, P()))
    return Cell(arch, shape.name, "train", train_step,
                (params_abs, opt_abs, batch_abs), out_sh,
                {"model_flops": 3.0 * G * _gnn_flops(cfg, n_sub, e_sub, d_in,
                                                     T),
                 "subgraphs": G, "nodes_per": n_sub, "edges_per": e_sub},
                donate_argnums=(0, 1))


def _dimenet_fullgraph_agent_cell(arch, cfg: GNNConfig, shape: GNNShape,
                                  mesh: Mesh) -> Cell:
    """§Perf-optimized DimeNet full-graph: both nested combines
    (triplet→edge, edge→node) through the Agent-Graph exchange, triplets
    ingress-sorted by kj edge so the message gather is local."""
    from repro.models.dimenet import dimenet_forward_sharded
    ax = mesh_axes(mesh)
    K = ax["n_devices"]
    axes = ax["all"]
    spec = P(axes)
    R512 = lambda x: max(512, int(-(-x // 512) * 512))
    V, E = shape.n_nodes, shape.n_edges
    T = min(16 * E, 2 ** 31 // 8)
    e_loc = R512(-(-E // K))
    v_loc = R512(-(-V // K))
    t_loc = R512(-(-T // K))
    # combiner estimates: remote-ji triplet targets ≈ T_loc/8 distinct edges,
    # remote-dst node targets ≈ 2·V_loc (scale-free fan-in)
    est_tri = dict(cap=e_loc, e_pad=8, s_pad=8,
                   c_pad=R512(min(e_loc, t_loc // 8)),
                   s_x_pad=8,
                   c_x_pad=R8(max(8, 2 * min(e_loc, t_loc // 8) // K)))
    est_node = dict(cap=v_loc, e_pad=8, s_pad=8, c_pad=R512(2 * v_loc),
                    s_x_pad=8, c_x_pad=R8(max(8, 4 * v_loc // K)))
    topo_tri = _abstract_topo(est_tri, K, mesh, spec)
    topo_node = _abstract_topo(est_node, K, mesh, spec)
    ch = cfg.d_hidden
    g = lambda *s: _sds((K,) + s, jnp.int32, mesh, spec)
    gf = lambda *s: _sds((K,) + s, jnp.float32, mesh, spec)
    gb = lambda *s: _sds((K,) + s, jnp.bool_, mesh, spec)
    shard_abs = {
        "d": gf(e_loc), "edge_mask": gb(e_loc),
        "species_src": g(e_loc), "species_dst": g(e_loc),
        "tri_kj_loc": g(t_loc), "tri_tgt_slot": g(t_loc),
        "tri_mask": gb(t_loc),
        "sbf": gf(t_loc, cfg.n_spherical * cfg.n_radial),
        "dst_slot": g(e_loc), "target": gf(v_loc),
    }
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=_ns(mesh, P())),
        jax.eval_shape(lambda k: init_dimenet(k, cfg), key_abs))
    opt = AdamW(lr=1e-3)
    opt_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=_ns(mesh, P())),
        jax.eval_shape(opt.init, params_abs))

    def loss_fn(params, topo_t, topo_n, shard):
        def shard_loss(tt, tn, sh):
            sq = lambda t: jax.tree.map(lambda a: a[0], t)
            tt, tn, sh = sq(tt), sq(tn), sq(sh)
            out = dimenet_forward_sharded(params, sh, tt, tn, cfg, axes)
            err = ((out[:, 0] - sh["target"]) ** 2).sum()
            num = jax.lax.psum(err, axes)
            den = jax.lax.psum(jnp.float32(sh["target"].shape[0]), axes)
            return (num / den)[None]

        tree_spec = lambda t: jax.tree.map(
            lambda _: spec, t, is_leaf=lambda x: hasattr(x, "ndim"))
        loss = shd.shard_map(
            shard_loss, mesh=mesh,
            in_specs=(tree_spec(topo_t), tree_spec(topo_n), tree_spec(shard)),
            out_specs=P(axes))(topo_t, topo_n, shard)
        return loss.mean()

    def train_step(params, opt_state, topo_t, topo_n, shard):
        loss, grads = jax.value_and_grad(loss_fn)(params, topo_t, topo_n,
                                                  shard)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    out_sh = (jax.tree.map(lambda a: a.sharding, params_abs),
              jax.tree.map(lambda a: a.sharding, opt_abs), _ns(mesh, P()))
    return Cell(arch, shape.name, "train", train_step,
                (params_abs, opt_abs, topo_tri, topo_node, shard_abs), out_sh,
                {"model_flops": 3.0 * _gnn_flops(cfg, V, E, 3, T),
                 "nodes": V, "edges": E, "triplets": T,
                 "exchange": "agent-2level",
                 "est_tri": est_tri, "est_node": est_node},
                donate_argnums=(0, 1))


def _gnn_cell(arch, cfg: GNNConfig, shape: GNNShape, mesh: Mesh) -> Cell:
    if shape.kind == "full_graph":
        if cfg.family in ("gcn", "gin"):
            return _gnn_fullgraph_agent_cell(arch, cfg, shape, mesh)
        if cfg.family == "dimenet" and shape.n_edges > 10_000_000:
            # §Perf: GSPMD gathers the full [E, ch] message tensor per block
            # at this scale (infeasible); route through the agent exchange
            return _dimenet_fullgraph_agent_cell(arch, cfg, shape, mesh)
        return _gnn_fullgraph_spmd_cell(arch, cfg, shape, mesh)
    return _gnn_batched_cell(arch, cfg, shape, mesh,
                             minibatch=shape.kind == "minibatch")


# =================================================================== recsys
def _recsys_cell(arch, cfg: RecSysConfig, shape: RecSysShape,
                 mesh: Mesh) -> Cell:
    ax = mesh_axes(mesh)
    dp, tp = shd.dp_entry(ax["dp"]), ax["tp"]
    rows = cfg.total_rows()
    rows_pad = -(-rows // ax["tp_size"]) * ax["tp_size"]
    rps = rows_pad // ax["tp_size"]
    pspecs = shd.recsys_param_specs(cfg, ax["dp"], tp)
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_abs = jax.eval_shape(lambda k: init_autoint(k, cfg), key_abs)
    # pad the table rows so the tp shards are even
    params_abs = dict(params_abs)
    params_abs["table"] = jax.ShapeDtypeStruct(
        (rows_pad, cfg.embed_dim), jnp.float32)
    params_abs = _abstract(params_abs, mesh, pspecs)

    def lookup(table, ids):
        def shard_lk(tbl, ids_l):
            idx = jax.lax.axis_index(tp)
            return sharded_embedding_lookup(tbl, ids_l, idx, rps, tp)
        return shd.shard_map(
            shard_lk, mesh=mesh, in_specs=(P(tp, None), P(dp, None)),
            out_specs=P(dp, None, None))(table, ids)

    B = shape.batch
    flops_interact = (cfg.n_attn_layers *
                      (3 * cfg.n_sparse * cfg.embed_dim * cfg.d_attn * 2 +
                       2 * cfg.n_sparse ** 2 * cfg.d_attn * 2))

    if shape.kind == "train":
        opt = AdamW(lr=1e-3)
        ospecs = shd.opt_specs(pspecs)
        opt_abs = _abstract(jax.eval_shape(opt.init, params_abs), mesh, ospecs)
        batch_abs = {"ids": _sds((B, cfg.n_sparse), jnp.int32, mesh,
                                 P(dp, None)),
                     "labels": _sds((B,), jnp.int32, mesh, P(dp))}

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(autoint_loss)(
                params, batch, cfg, lookup_fn=lookup)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        out_sh = (shd.to_shardings(mesh, pspecs),
                  jax.tree.map(lambda s: _ns(mesh, s), ospecs),
                  _ns(mesh, P()))
        return Cell(arch, shape.name, "train", train_step,
                    (params_abs, opt_abs, batch_abs), out_sh,
                    {"model_flops": 3.0 * B * flops_interact,
                     "rows": rows, "batch": B},
                    donate_argnums=(0, 1))

    if shape.kind == "serve":
        ids_abs = _sds((B, cfg.n_sparse), jnp.int32, mesh, P(dp, None))

        def serve_step(params, ids):
            return autoint_logits(params, ids, cfg, lookup_fn=lookup)

        return Cell(arch, shape.name, "serve", serve_step,
                    (params_abs, ids_abs), _ns(mesh, P(dp)),
                    {"model_flops": 1.0 * B * flops_interact,
                     "rows": rows, "batch": B})

    # retrieval: 1 query scored against n_candidates, candidates sharded
    # (rows padded to a 512-device multiple so both meshes divide evenly)
    N = -(-shape.n_candidates // 512) * 512
    allax = ax["all"]
    ids_abs = _sds((1, cfg.n_sparse), jnp.int32, mesh, P())
    cand_abs = _sds((N, cfg.d_attn), jnp.float32, mesh, P(allax, None))
    proj_abs = _sds((cfg.n_sparse * cfg.d_attn, cfg.d_attn), jnp.float32,
                    mesh, P())

    def retrieval_step(params, ids, cand, proj):
        return retrieval_scores(params, ids, cand, proj, cfg)

    return Cell(arch, shape.name, "retrieval", retrieval_step,
                (params_abs, ids_abs, cand_abs, proj_abs),
                _ns(mesh, P(allax)),
                {"model_flops": 1.0 * flops_interact +
                                2.0 * N * cfg.d_attn,
                 "candidates": N})


# =================================================================== factory
def build_cell(arch: str, shape_name: str, mesh: Mesh) -> Cell:
    cfg, family = get_config(arch)
    shape = get_shape(arch, shape_name)
    if family == "lm":
        return _lm_cell(arch, cfg, shape, mesh)
    if family == "gnn":
        return _gnn_cell(arch, cfg, shape, mesh)
    if family == "recsys":
        return _recsys_cell(arch, cfg, shape, mesh)
    raise ValueError(family)
