"""Static roofline analyzer over compiled HLO text.

Why not `compiled.cost_analysis()`: XLA counts a `while` body ONCE, so any
scan-over-layers / chunked-attention model is undercounted by the trip count
(verified experimentally: L=2,4,8 layer scans report identical flops).  This
analyzer parses the optimized per-device HLO, resolves the call graph
(fusions, calls, whiles, conditionals), extracts loop trip counts from the
`compare(iter, constant)` condition pattern, and multiplies per-computation
costs accordingly:

  FLOPs       — dot/convolution ops: 2 · |result| · contracted-size
  HBM bytes   — operand+result bytes of fusion/dot/collective/copy/
                scatter/gather/reduce/sort/dynamic-slice ops (fusion
                boundaries ≈ HBM round trips)
  link bytes  — per collective type: all-gather → result bytes,
                reduce-scatter/all-to-all/permute → operand bytes,
                all-reduce → 2 × operand bytes (ring)

All quantities are PER DEVICE (SPMD-partitioned module).  Roofline terms
use TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    rest: str          # operands + attributes (raw)

    def operand_names(self) -> List[str]:
        # operands are %refs before the closing paren of the op call
        depth, out, cur = 0, [], []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            cur.append(ch)
        args = "".join(cur)
        return re.findall(r"%([\w\.\-]+)", args)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", self.rest)
        return m.group(1) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    is_entry: bool = False

    def symtab(self) -> Dict[str, Instruction]:
        return {i.name: i for i in self.instructions}


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(2), [], is_entry=bool(m.group(1)))
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST_RE.match(line)
        if m:
            cur.instructions.append(Instruction(*m.groups()))
    return comps


def _dot_flops(inst: Instruction, symtab: Dict[str, Instruction],
               params_types: Dict[str, str]) -> float:
    """2 · |result| · contracted-size from lhs shape + contracting dims."""
    ops = inst.operand_names()
    if not ops:
        return 0.0
    lhs = ops[0]
    lhs_type = (symtab[lhs].type_str if lhs in symtab
                else params_types.get(lhs, ""))
    m = _SHAPE_RE.search(lhs_type)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.rest)
    contracted = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            contracted *= dims[int(d)] if int(d) < len(dims) else 1
    return 2.0 * shape_elems(inst.type_str) * contracted


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_BYTES_OPS = {"fusion", "dot", "convolution", "copy", "scatter", "gather",
              "reduce", "sort", "dynamic-slice", "dynamic-update-slice",
              "transpose", "broadcast", "concatenate", "select-and-scatter",
              "reduce-window", "iota", "convert", "slice", "reshape", "pad",
              "select"} | set(_COLLECTIVES)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    link_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_collectives: int = 0
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add_bytes(self, op: str, b: float):
        self.hbm_bytes += b
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + b

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.link_bytes += o.link_bytes
        for k, v in o.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v
        for k, v in o.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v
        self.n_collectives += o.n_collectives
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.hbm_bytes * k, self.link_bytes * k,
                    {a: b * k for a, b in self.coll_bytes.items()},
                    int(self.n_collectives * k),
                    {a: b * k for a, b in self.bytes_by_op.items()})


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, Cost] = {}
        self.warnings: List[str] = []

    # --------------------------------------------------------- trip counts
    def trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts: Dict[str, int] = {}
        for inst in comp.instructions:
            if inst.opcode == "constant":
                m = re.match(r"([\-\d]+)", inst.rest.rstrip(") "))
                if m:
                    try:
                        consts[inst.name] = int(m.group(1))
                    except ValueError:
                        pass
        for inst in comp.instructions:
            direct = inst.opcode == "compare" and "direction=LT" in inst.rest
            # CPU XLA wraps the compare in a kLoop fusion; the constant bound
            # is an operand of the fusion site
            wrapped = (inst.opcode == "fusion"
                       and "compare" in (inst.attr("calls") or ""))
            if direct or wrapped:
                for op in inst.operand_names():
                    if op in consts:
                        return max(1, consts[op])
        self.warnings.append(f"no trip count for {cond_name}; assuming 1")
        return 1

    # ------------------------------------------------------------- costing
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        cost = Cost()
        if comp is None:
            self._memo[name] = cost
            return cost
        self._memo[name] = cost  # break cycles
        symtab = comp.symtab()
        params_types = {i.name: i.type_str for i in comp.instructions
                        if i.opcode == "parameter"}
        for inst in comp.instructions:
            op = inst.opcode
            if op == "while":
                body = inst.attr("body")
                cond = inst.attr("condition")
                # primary: XLA records the static trip count directly
                m = re.search(r'"known_trip_count":\{"n":"?(\d+)', inst.rest)
                if m:
                    trips = int(m.group(1))
                else:
                    trips = self.trip_count(cond) if cond else 1
                if body:
                    cost += self.comp_cost(body).scaled(trips)
                if cond:
                    cost += self.comp_cost(cond).scaled(trips)
                continue
            if op in ("fusion", "call", "custom-call", "map"):
                callee = inst.attr("calls") or inst.attr("to_apply")
                if callee:
                    cost += self.comp_cost(callee)
            if op == "conditional":
                for key in ("true_computation", "false_computation"):
                    callee = inst.attr(key)
                    if callee:
                        cost += self.comp_cost(callee)
                for m in re.finditer(r"branch_computations=\{([^}]*)\}",
                                     inst.rest):
                    for c in re.findall(r"%([\w\.\-]+)", m.group(1)):
                        cost += self.comp_cost(c)
            if op in ("dot", "convolution"):
                cost.flops += _dot_flops(inst, symtab, params_types)
            # HBM bytes: top-level data-moving ops.  Slice-like ops touch
            # only the slice, not the (possibly loop-carried) big buffer —
            # critical inside while bodies where operands repeat per trip.
            if op in _BYTES_OPS:
                result_b = shape_bytes(inst.type_str)
                op_sizes = []
                for o in inst.operand_names():
                    t = (symtab[o].type_str if o in symtab
                         else params_types.get(o))
                    if t:
                        op_sizes.append(shape_bytes(t))
                operand_b = sum(op_sizes)
                max_op = max(op_sizes, default=0)
                callee_ops = set()
                if op == "fusion":
                    callee = inst.attr("calls")
                    ccomp = self.comps.get(callee) if callee else None
                    if ccomp:
                        callee_ops = {i.opcode for i in ccomp.instructions}
                if op in ("dynamic-slice", "slice", "gather"):
                    cost.add_bytes(op, 2 * result_b)     # read slice + write
                elif (op == "dynamic-update-slice"
                      or (op == "fusion"
                          and "dynamic-update-slice" in callee_ops
                          and result_b == max_op)):
                    upd = operand_b - max_op             # small operands only
                    cost.add_bytes("dus", 2 * max(upd, result_b // 64))
                elif (op == "fusion" and "dynamic-slice" in callee_ops
                      and result_b < max_op):
                    cost.add_bytes("fused-ds", 2 * result_b + (operand_b - max_op))
                else:
                    cost.add_bytes(op, result_b + operand_b)
            if op in _COLLECTIVES:
                result_b = shape_bytes(inst.type_str)
                operand_b = 0
                for o in inst.operand_names():
                    t = (symtab[o].type_str if o in symtab
                         else params_types.get(o))
                    if t:
                        operand_b += shape_bytes(t)
                if op == "all-gather":
                    link = result_b
                elif op == "all-reduce":
                    link = 2 * operand_b
                else:
                    link = operand_b
                cost.link_bytes += link
                cost.coll_bytes[op] = cost.coll_bytes.get(op, 0.0) + link
                cost.n_collectives += 1
        return cost

    def entry_cost(self) -> Cost:
        for name, comp in self.comps.items():
            if comp.is_entry:
                return self.comp_cost(name)
        raise ValueError("no ENTRY computation found")


# Pure-elementwise top-level ops: CPU XLA materializes them, TPU fuses them
# into producers/consumers.  The "fused" memory model excludes them.
_FUSABLE = {"convert", "copy", "broadcast", "transpose", "reshape", "pad",
            "iota", "select", "concatenate"}


def analyze(text: str) -> Dict:
    """Full per-device analysis + roofline terms (seconds).

    Two memory models:
      memory_time_s        — every materialized buffer of the CPU-compiled
                             HLO (conservative upper bound);
      memory_time_fused_s  — excludes pure-elementwise ops that a TPU
                             compilation fuses into neighbors (realistic).
    Dominance uses the fused model.
    """
    a = HloAnalyzer(text)
    c = a.entry_cost()
    compute_t = c.flops / PEAK_FLOPS
    memory_t = c.hbm_bytes / HBM_BW
    fused_bytes = c.hbm_bytes - sum(
        v for k, v in c.bytes_by_op.items() if k in _FUSABLE)
    memory_fused_t = fused_bytes / HBM_BW
    coll_t = c.link_bytes / LINK_BW
    dominant = max(("compute", compute_t), ("memory", memory_fused_t),
                   ("collective", coll_t), key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": c.flops,
        "hbm_bytes_per_device": c.hbm_bytes,
        "hbm_bytes_fused_per_device": fused_bytes,
        "link_bytes_per_device": c.link_bytes,
        "coll_bytes_by_type": dict(c.coll_bytes),
        "n_collectives": c.n_collectives,
        "compute_time_s": compute_t,
        "memory_time_s": memory_t,
        "memory_time_fused_s": memory_fused_t,
        "collective_time_s": coll_t,
        "dominant": dominant,
        "bound_time_s": max(compute_t, memory_fused_t, coll_t),
        "bytes_by_op": {k: v for k, v in sorted(c.bytes_by_op.items(),
                                                key=lambda kv: -kv[1])[:8]},
        "warnings": a.warnings[:10],
    }
