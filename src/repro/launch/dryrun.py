import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell for the production meshes and record memory/cost/roofline artifacts.

  single-pod: (16, 16)    = ("data", "model")          — 256 chips
  multi-pod:  (2, 16, 16) = ("pod", "data", "model")   — 512 chips

Usage:
  python -m repro.launch.dryrun                      # all 40 cells, both meshes
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --mesh single        # single-pod only
  python -m repro.launch.dryrun --graph              # GRE graph-engine dryrun
  python -m repro.launch.dryrun --out results/dryrun # JSON records per cell
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import all_cells
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl


def run_cell(arch: str, shape: str, mesh, save_hlo: str = "") -> dict:
    from repro.launch.cells import build_cell
    t0 = time.time()
    cell = build_cell(arch, shape, mesh)
    jitted = jax.jit(cell.step_fn, out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    lowered = jitted.lower(*cell.abstract_args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    rec = {"arch": arch, "shape": shape, "kind": cell.kind,
           "mesh": dict(mesh.shape), "n_devices": mesh.size,
           "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
           "meta": cell.meta, "ok": True}
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_gib": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["xla_cost"] = {"flops": float(ca.get("flops", -1)),
                           "bytes_accessed": float(ca.get("bytes accessed", -1))}
    except Exception as e:  # pragma: no cover
        rec["xla_cost"] = {"error": str(e)}
    text = compiled.as_text()
    rec["roofline"] = rl.analyze(text)
    if save_hlo:
        Path(save_hlo).write_text(text)
        rec["hlo_path"] = save_hlo
    return rec


def run_graph_engine_dryrun(mesh) -> dict:
    """The paper's own workload on the production mesh: one PageRank
    superstep program over an (estimated-shape) Agent-Graph partition."""
    import jax.numpy as jnp
    from repro.core import algorithms
    from repro.core.dist_engine import DistGREEngine
    from repro.core.engine import EngineState
    from repro.launch.cells import _abstract_topo, _agent_shape_estimates, _sds
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)
    K = mesh.size
    V, E = 1 << 26, (1 << 26) * 16          # paper's weak-scaling family
    est = _agent_shape_estimates(V, E, K)
    slots = est["cap"] + est["s_pad"] + est["c_pad"] + 1
    spec = P(axes)
    topo_abs = _abstract_topo(est, K, mesh, spec)
    state_abs = EngineState(
        vertex_data=_sds((K, est["cap"]), jnp.float32, mesh, spec),
        scatter_data=_sds((K, slots), jnp.float32, mesh, spec),
        active_scatter=_sds((K, slots), jnp.bool_, mesh, spec),
        step=_sds((K,), jnp.int32, mesh, spec),
    )
    eng = DistGREEngine(algorithms.pagerank_program(), mesh, axes,
                        exchange="agent")

    class _FakeAG:  # make_run only reads shapes via device_topology/state
        pass

    def run30(topo, state):
        # inline the shard body: 30 canonical supersteps with AgentExchange
        import jax as _jax
        from repro.dist.sharding import shard_map as _shard_map

        def shard(topo_s, state_s):
            sq = lambda t: _jax.tree.map(lambda a: a[0], t)
            topo_l, st = sq(topo_s), sq(state_s)
            backend = eng.make_exchange(topo_l)

            def body(i, s):
                return eng.local.superstep(topo_l.part, s, backend)

            out = _jax.lax.fori_loop(0, 30, body, st)
            return _jax.tree.map(lambda a: a[None], out)

        return _shard_map(
            shard, mesh=mesh,
            in_specs=(_jax.tree.map(lambda _: spec, topo,
                                    is_leaf=lambda x: hasattr(x, "ndim")),
                      _jax.tree.map(lambda _: spec, state,
                                    is_leaf=lambda x: hasattr(x, "ndim"))),
            out_specs=_jax.tree.map(lambda _: spec, state,
                                    is_leaf=lambda x: hasattr(x, "ndim")))(
            topo, state)

    t0 = time.time()
    lowered = jax.jit(run30).lower(topo_abs, state_abs)
    compiled = lowered.compile()
    rec = {"arch": "gre-pagerank", "shape": f"rmat26x16_k{K}",
           "kind": "graph-superstep", "mesh": dict(mesh.shape),
           "compile_s": round(time.time() - t0, 2),
           "meta": {"V": V, "E": E, "supersteps": 30, "agent_est": est},
           "roofline": rl.analyze(compiled.as_text()), "ok": True}
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {"peak_per_device_gib": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3)}
    except Exception as e:
        rec["memory"] = {"error": str(e)}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--graph", action="store_true",
                    help="also dry-run the GRE graph engine itself")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    cells = list(all_cells())
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]

    n_fail = 0
    for mesh_name, mesh in meshes:
        if args.graph:
            rec = run_graph_engine_dryrun(mesh)
            print(f"[{mesh_name}] gre-pagerank superstep: "
                  f"compile {rec['compile_s']}s "
                  f"dominant={rec['roofline']['dominant']}")
            (outdir / f"graph_{mesh_name}.json").write_text(
                json.dumps(rec, indent=1))
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{mesh_name}"
            hlo = str(outdir / f"{tag}.hlo") if args.save_hlo else ""
            try:
                rec = run_cell(arch, shape, mesh, save_hlo=hlo)
                r = rec["roofline"]
                mem = rec["memory"].get("peak_per_device_gib", "?")
                print(f"[{mesh_name}] {arch:22s} {shape:14s} "
                      f"compile={rec['compile_s']:7.1f}s "
                      f"mem/dev={mem}GiB "
                      f"compute={r['compute_time_s']:.3e}s "
                      f"memory={r['memory_time_s']:.3e}s "
                      f"coll={r['collective_time_s']:.3e}s "
                      f"dominant={r['dominant']}", flush=True)
            except Exception as e:
                n_fail += 1
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[{mesh_name}] {arch:22s} {shape:14s} FAILED: "
                      f"{type(e).__name__}: {str(e)[:160]}", flush=True)
            (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    print(f"\ndry-run complete; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
