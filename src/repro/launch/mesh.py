"""Production mesh construction.

Defined as functions (not module constants) so importing never touches jax
device state.  The dry-run sets XLA_FLAGS for 512 host devices BEFORE any
import; real deployments get the same shapes from the TPU runtime.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> dict:
    """Logical roles: dp axes tuple, tp axis, and the flattened graph axis."""
    names = tuple(mesh.axis_names)
    tp = "model" if "model" in names else names[-1]
    dp = tuple(n for n in names if n != tp)
    return {"dp": dp, "tp": tp, "all": names,
            "dp_size": int(jax.numpy.prod(
                jax.numpy.array([mesh.shape[a] for a in dp]))) if dp else 1,
            "tp_size": mesh.shape[tp],
            "n_devices": mesh.size}
