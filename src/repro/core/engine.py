"""GRE BSP engine: executes VertexPrograms in supersteps (paper Alg. 2).

There is ONE canonical superstep, parameterized by an ExchangeBackend
(`repro.core.exchange`):

  refresh          — the backend pushes master scatter state to any remote
      readers (identity on a single shard);
  scatter-combine  — every scatter-active vertex emits active messages along
      its out-edges; messages execute ⊕ at their destinations immediately
      (one fused gather → message → segment-reduce, no edge-state storage);
      the backend folds remote partial combines into master slots;
  apply            — every vertex whose combine_data changed recomputes
      vertex_data and decides whether to stay scatter-active
      (assert_to_halt).

Message payloads are first-class feature vectors: state arrays are
`[slots, *payload_shape]` and the same superstep drives scalar traversal
(SSSP, payload `()`), multi-stage vector programs (Brandes σ, payload
`(3,)`) and GNN feature aggregation (payload `(D,)`).

The distributed engine (`repro.core.dist_engine`) runs this same superstep
per shard with an AgentExchange or DenseExchange backend under shard_map.

HOW a run executes — which frontier strategy scans the edges, whether the
exchange runs as one synchronous reduce or as the pipelined local-phase /
deferred-merge shape, and which combine kernel folds the messages — is a
`SuperstepPlan` (`repro.core.plan`), resolved once per (engine, partition)
and driven by ONE loop, `plan.execute_plan`.  `GREEngine.run` and the
distributed `DistGREEngine.make_run` both call that executor; there is no
separate pipelined loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exchange import NULL_EXCHANGE, ExchangeBackend
from repro.core.plan import KernelPlan, SuperstepPlan, execute_plan
from repro.core.vertex_program import VertexProgram, segment_combine


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DevicePartition:
    """Static per-shard topology (column storage, local 32-bit ids).

    `num_slots` = masters + agents + 1 padding sink; padded edges point at
    the sink so combines on padding never touch real state (paper §6.1.1
    renumbers masters first, then agents; the sink is our addition for XLA
    static shapes).
    """

    # Edge columns are OPTIONAL: a partition that only anchors slot statics
    # and aux for the apply phase (the canonical part under the pipelined
    # exchange, whose edge scans all run on the split tiles) carries None
    # instead of paying device memory for columns nothing reads.
    src: Optional[jnp.ndarray] = None         # [E_pad] int32 local src slot
    dst: Optional[jnp.ndarray] = None         # [E_pad] int32 local dst slot
    edge_mask: Optional[jnp.ndarray] = None   # [E_pad] bool, False on padding
    # The slot sizing stays REQUIRED (keyword-only, no default): omitting it
    # must fail at construction, not as an opaque zero-shape trace error.
    num_masters: int = dataclasses.field(kw_only=True,
                                         metadata=dict(static=True))
    num_slots: int = dataclasses.field(kw_only=True,
                                       metadata=dict(static=True))
    edges_sorted_by_dst: bool = dataclasses.field(kw_only=True,
                                                  metadata=dict(static=True))
    edge_props: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    aux: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    # Src-sorted CSR secondary index (graph.structures.csr_layout) — the
    # substrate of the frontier-compacted scatter (core/frontier.py).  None
    # disables compaction for this partition.
    csr_indptr: Optional[jnp.ndarray] = None   # [num_slots + 1]
    csr_eidx: Optional[jnp.ndarray] = None     # [E_pad] pos in dst-sorted cols
    csr_max_deg: int = dataclasses.field(default=0,
                                         metadata=dict(static=True))
    # Degree-bucket binning (graph.structures.degree_buckets): slots binned
    # by local out-degree so the compacted frontier gathers one tight
    # [cap_b, max_deg_b] tile per bucket instead of padding everything to
    # the hub degree.  None/empty disables bucketed compaction.
    bucket_id: Optional[jnp.ndarray] = None    # [num_slots] int32, -1 = deg 0
    bucket_sizes: tuple = dataclasses.field(default=(),
                                            metadata=dict(static=True))
    bucket_max_deg: tuple = dataclasses.field(default=(),
                                              metadata=dict(static=True))

    @staticmethod
    def from_graph(graph, pad_to: Optional[int] = None,
                   sort_by_dst: bool = True, transpose: bool = False,
                   bucket_bounds: Optional[tuple] = None,
                   edge_slack: int = 0, chunk_size: Optional[int] = None):
        """Whole graph on one shard (no agents; slots = V + sink).

        `transpose=True` builds the partition of the reversed graph — the
        backward-traversal substrate for multi-stage algorithms (paper §4.2:
        Brandes' δ accumulation runs on the transposed graph).

        `bucket_bounds` overrides the default degree-bucket ladder
        (`graph.structures.DEFAULT_BUCKET_BOUNDS`) — the plan autotuner
        (repro.tuning) probes candidate ladders by rebuilding the
        partition per bounds, and a tuned `SuperstepPlan` carrying
        non-None `bucket_bounds` expects a partition built with them.

        `edge_slack` pads the edge columns with that many extra masked
        slots so future `apply_edge_delta` batches can append in place
        without regrowing the static edge length (= without an XLA
        retrace).  See docs/incremental.md.

        `graph` may also be an `EdgeChunkSource` (or any in-memory Graph
        with `chunk_size` set): the padded edge columns then fill
        directly from the chunk stream at a cursor and the dst sort runs
        in place over the filled prefix — bitwise-identical columns, but
        peak host state is the padded output columns plus ONE chunk, with
        no intermediate full edge-list copy (docs/partitioning.md).
        """
        from repro.graph.structures import (DEFAULT_BUCKET_BOUNDS,
                                            csr_layout, degree_buckets,
                                            pad_edges, sort_edges_by_dst)
        source = graph if hasattr(graph, "chunks") else (
            graph.chunk_source(chunk_size) if chunk_size else None)
        if source is not None:
            v, e = source.num_vertices, source.num_edges
            e_pad = pad_to or (e + edge_slack)
            assert e_pad >= e, (e_pad, e)
            psrc = np.full(e_pad, v, dtype=np.int32)
            pdst = np.full(e_pad, v, dtype=np.int32)
            mask = np.zeros(e_pad, dtype=bool)
            mask[:e] = True
            props = {k: np.zeros(e_pad, dtype=dt)
                     for k, dt in source.prop_dtypes.items()}
            out_deg = np.zeros(v, dtype=np.int64)
            cur = 0
            for chunk in source.chunks():
                s, d = ((chunk.dst, chunk.src) if transpose
                        else (chunk.src, chunk.dst))
                hi = cur + chunk.num_edges
                psrc[cur:hi] = s
                pdst[cur:hi] = d
                for k in props:
                    props[k][cur:hi] = chunk.props[k]
                out_deg += np.bincount(s, minlength=v)
                cur = hi
            if sort_by_dst:
                order = np.argsort(pdst[:e], kind="stable")
                psrc[:e] = psrc[:e][order]
                pdst[:e] = pdst[:e][order]
                for k in props:
                    props[k][:e] = props[k][:e][order]
            out_deg = out_deg.astype(np.float32)
        else:
            if transpose:
                graph = graph.reversed()
            src, dst, props = graph.src, graph.dst, dict(graph.edge_props)
            if sort_by_dst:
                src, dst, props, _ = sort_edges_by_dst(src, dst, props)
            v = graph.num_vertices
            e_pad = pad_to or (graph.num_edges + edge_slack)
            psrc, pdst, mask = pad_edges(src, dst, e_pad, pad_vertex=v)
            props = {k: np.pad(p, (0, e_pad - graph.num_edges))
                     for k, p in props.items()}
            out_deg = graph.out_degree().astype(np.float32)
        indptr, eidx, max_deg = csr_layout(psrc, mask, v + 1)
        bucket_id, sizes, max_degs = degree_buckets(
            indptr, v + 1, bounds=tuple(bucket_bounds or
                                        DEFAULT_BUCKET_BOUNDS))
        return DevicePartition(
            src=jnp.asarray(psrc), dst=jnp.asarray(pdst),
            edge_mask=jnp.asarray(mask), num_masters=v, num_slots=v + 1,
            edges_sorted_by_dst=sort_by_dst,
            edge_props={k: jnp.asarray(p) for k, p in props.items()},
            aux={"out_degree": jnp.asarray(out_deg),
                 "global_id": jnp.arange(v, dtype=jnp.float32)},
            csr_indptr=jnp.asarray(indptr), csr_eidx=jnp.asarray(eidx),
            csr_max_deg=max_deg,
            bucket_id=jnp.asarray(bucket_id), bucket_sizes=sizes,
            bucket_max_deg=max_degs,
        )

    def apply_edge_delta(self, delta, bucket_bounds: Optional[tuple] = None,
                         pad_multiple: int = 8):
        """Delta ingress (docs/incremental.md): retire + append edges in the
        padded columns without rebuilding the partition from a Graph.

        Removed edges become TOMBSTONES — folded into `edge_mask` as False
        and repointed at the sink slot (`src = dst = num_masters`), so even
        the dense-frontier scan (which skips the mask, relying on the sink's
        identity-pinned scatter row) never re-delivers them.  Added edges
        consume masked slack slots at the tail.  Live edges are then
        re-sorted by destination on the host, preserving the
        `edges_sorted_by_dst` contract of the segment combine, and the
        CSR/bucket secondary indices are rebuilt over the same padded
        length.

        The STATIC facets (`csr_max_deg`, `bucket_sizes`, `bucket_max_deg`)
        merge monotonically (elementwise max with the previous partition):
        larger tile caps are pure padding, and keeping them monotone means a
        sequence of small deltas reuses one jitted trace instead of
        recompiling per batch.  Only when the live edge count outgrows the
        padded columns do we COMPACT: regrow the edge length with ×1.25
        headroom (rounded up to `pad_multiple`) — the one recompile point,
        flagged in the report.

        Returns ``(new_partition, DeltaReport)``; `self` is not mutated.
        """
        from repro.graph.structures import (DEFAULT_BUCKET_BOUNDS,
                                            DeltaReport, csr_layout,
                                            degree_buckets, removal_selector,
                                            sort_edges_by_dst,
                                            validate_edge_delta)
        assert self.src is not None, \
            "tile-only partition carries no edge columns to mutate"
        n, slots = self.num_masters, self.num_slots
        sink = n  # single-shard layout: masters [0, n), sink at n
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        mask = np.asarray(self.edge_mask)
        props = {k: np.asarray(v) for k, v in self.edge_props.items()}
        # ---- validate up front (single-shard layout: master slot == the
        # original vertex id, so slot-space keys ARE original-id keys)
        validate_edge_delta(
            delta, n,
            live_keys=(src[mask].astype(np.int64) * np.int64(n) +
                       dst[mask].astype(np.int64)))
        # ---- retire: every live instance of each removed (src, dst) pair
        rem = removal_selector(src.astype(np.int64), dst.astype(np.int64),
                               delta.rem_src, delta.rem_dst, slots) & mask
        removed_src = src[rem].astype(np.int64)
        removed_dst = dst[rem].astype(np.int64)
        keep = mask & ~rem
        # ---- stage adds
        if delta.num_adds:
            for k in props:
                if k not in delta.add_props:
                    raise KeyError(f"delta adds missing edge prop {k!r}")
        live_src = np.concatenate([src[keep],
                                   delta.add_src.astype(np.int32)])
        live_dst = np.concatenate([dst[keep],
                                   delta.add_dst.astype(np.int32)])
        live_props = {
            k: np.concatenate([v[keep],
                               np.asarray(delta.add_props[k], v.dtype)
                               if delta.num_adds else v[:0]])
            for k, v in props.items()}
        e_live = int(live_src.shape[0])
        e_pad = int(src.shape[0])
        compacted = False
        if e_live > e_pad:  # slack exhausted: the one recompile point
            e_pad = max(e_live, int(e_pad * 1.25))
            e_pad = -(-e_pad // pad_multiple) * pad_multiple
            compacted = True
        if self.edges_sorted_by_dst:
            live_src, live_dst, live_props, _ = sort_edges_by_dst(
                live_src, live_dst, live_props)
        psrc = np.full(e_pad, sink, np.int32)
        pdst = np.full(e_pad, sink, np.int32)
        pmask = np.zeros(e_pad, dtype=bool)
        psrc[:e_live] = live_src
        pdst[:e_live] = live_dst
        pmask[:e_live] = True
        pprops = {}
        for k, v in live_props.items():
            col = np.zeros((e_pad,) + v.shape[1:], dtype=v.dtype)
            col[:e_live] = v
            pprops[k] = col
        indptr, eidx, max_deg = csr_layout(psrc, pmask, slots)
        bucket_id, sizes, max_degs = degree_buckets(
            indptr, slots,
            bounds=tuple(bucket_bounds or DEFAULT_BUCKET_BOUNDS))
        # monotone static merge (see docstring): max keeps traces stable
        max_deg = max(max_deg, self.csr_max_deg)
        if len(sizes) == len(self.bucket_sizes):
            sizes = tuple(max(a, b)
                          for a, b in zip(sizes, self.bucket_sizes))
            max_degs = tuple(max(a, b)
                             for a, b in zip(max_degs, self.bucket_max_deg))
        out_deg = np.bincount(live_src, minlength=slots)[:n]
        aux = dict(self.aux)
        aux["out_degree"] = jnp.asarray(out_deg.astype(np.float32))
        new = dataclasses.replace(
            self,
            src=jnp.asarray(psrc), dst=jnp.asarray(pdst),
            edge_mask=jnp.asarray(pmask),
            edge_props={k: jnp.asarray(v) for k, v in pprops.items()},
            aux=aux,
            csr_indptr=jnp.asarray(indptr), csr_eidx=jnp.asarray(eidx),
            csr_max_deg=max_deg,
            bucket_id=jnp.asarray(bucket_id), bucket_sizes=sizes,
            bucket_max_deg=max_degs)
        report = DeltaReport(added_src=delta.add_src.copy(),
                             added_dst=delta.add_dst.copy(),
                             removed_src=removed_src,
                             removed_dst=removed_dst,
                             compacted=compacted)
        return new, report


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    """Runtime vertex states (paper §6.1.3), flat column arrays per slot.

    `lane_active` is the OPTIONAL per-payload-lane halt tracker ([D] bool,
    None outside serving): today's global halt runs the batch until the
    SLOWEST lane converges, but multi-source programs exposing
    `VertexProgram.lane_activates` get per-lane improvement reduced into
    this field by `apply` each superstep — a False entry means that lane's
    query reached its fixed point (monotone programs: quiet stays quiet)
    and the serving layer (repro.serving.graph_scheduler) may retire it
    and reseed the lane between supersteps.  Enabled via
    `init_state(..., lane_tracking=True)`; None keeps the classic pytree
    structure (zero cost, zero recompilation for non-serving runs).
    """

    vertex_data: jnp.ndarray     # [num_masters, *V]
    scatter_data: jnp.ndarray    # [num_slots, *S] (agents hold forwarded copies)
    active_scatter: jnp.ndarray  # [num_slots] bool
    step: jnp.ndarray            # scalar int32 superstep counter
    lane_active: Optional[jnp.ndarray] = None  # [D] bool, serving only


class GREEngine:
    """Drives a VertexProgram over one DevicePartition.

    `frontier` selects the scatter strategy (core/frontier.py):

      "auto"    — per-superstep `lax.cond`: dense scan when the frontier is
                  large, degree-BUCKETED compacted gather when it fits (≈
                  the 5-10% density crossover).  Each degree bucket gathers
                  its own tight `[cap_b, max_deg_b]` tile, so power-law
                  hubs no longer poison `max_deg` for every frontier slot;
                  the only remaining static skip is the degenerate case
                  where even the worst-case bucket tiles would out-scan
                  the dense path (tiny graphs).
      "compact" — always attempt bucketed compaction (tests/micro-
                  benchmarks); per-bucket overflow guards still degrade an
                  overflowing bucket to a bucket-restricted dense scan.
      "flat"    — the PRE-bucketing compacted path: one padded
                  `[cap, max_deg]` tile over the whole frontier, statically
                  gated off when `cap * max_deg >= E` (kept as the
                  benchmark ablation showing why bucketing exists).
      "dense"   — the original every-edge masked scan.

    Engines in `dense_frontier` mode (iterative programs like PageRank,
    where every vertex stays active) and partitions without a CSR layout
    always take the dense path.  Level-synchronous iterative programs that
    opt INTO activity masks (`dense_frontier=False`, e.g. Brandes' backward
    δ whose frontier is one depth level) do compact; for their sum monoids
    the strategies agree to float tolerance (the segment reduction
    reorders), not bitwise like min/max.
    """

    FRONTIERS = ("auto", "dense", "compact", "flat")

    def __init__(self, program: VertexProgram, use_pallas: bool = False,
                 dense_frontier: Optional[bool] = None,
                 frontier: str = "auto", frontier_cap: Optional[int] = None,
                 dynamic_table: bool = True, plan=None, plan_cache=None):
        assert frontier in self.FRONTIERS, frontier
        self.program = program
        self.use_pallas = use_pallas
        # Pallas tile combine: on-device dynamic_block_table pruning pass
        # (default) vs the degenerate full-table fallback (docs/kernels.md).
        self.dynamic_table = dynamic_table
        self.frontier = frontier
        self.frontier_cap = frontier_cap
        # Iterative programs (halts=False, e.g. PageRank) keep every vertex
        # active (paper §4.1), so per-edge activity masks are pure overhead;
        # dense mode skips them (the sink slot's scatter_data is pinned to
        # the monoid identity so padded edges still contribute nothing).
        self.dense_frontier = (dense_frontier if dense_frontier is not None
                               else not program.halts)
        # `plan` overrides the knob-by-knob arguments with one composed
        # SuperstepPlan, or requests a persisted tuned plan:
        #   plan=SuperstepPlan(...)  — adopt its stages now;
        #   plan="auto-tuned"       — consult the tuned-plan cache
        #       (repro.tuning.cache.PlanCache at `plan_cache`, else the
        #       default location) the first time a partition is in hand
        #       (init_state — the last eager point before the jitted run
        #       traces its static tile shapes).  Cache hits adopt the
        #       stored plan without any probe execution; misses keep the
        #       defaults above.
        # `bucket_bounds` records the degree-bucket ladder an adopted tuned
        # plan was probed against (None = partition default); callers
        # rebuild matching partitions via
        # DevicePartition.from_graph(bucket_bounds=...).
        self.bucket_bounds = None
        self.frontier_hist = None   # set by calibrate_frontier_cap
        self._plan_cache = plan_cache
        self._auto_plan_pending = False
        # last consulted tuned-plan cache key + its frontier-hist facet —
        # `refresh_plan` re-keys against these after a graph mutation
        self._plan_key = None
        self._plan_hist = None
        if plan is None:
            pass
        elif plan == "auto-tuned":
            self._auto_plan_pending = True
        else:
            self.adopt_plan(plan)

    def adopt_plan(self, plan: SuperstepPlan) -> None:
        """Take a composed SuperstepPlan's stages as this engine's knobs
        (the inverse of `make_plan`).  Must run before the first jitted
        `run` trace — the adopted frontier capacity and kernel route are
        static compile-time decisions (same contract as
        `calibrate_frontier_cap`)."""
        assert plan.strategy in self.FRONTIERS, plan.strategy
        self.frontier = plan.strategy
        self.frontier_cap = plan.frontier_cap
        self.dense_frontier = plan.dense_frontier
        self.use_pallas = plan.kernel.use_pallas
        self.dynamic_table = plan.kernel.dynamic_table
        self.bucket_bounds = plan.bucket_bounds

    def _consult_plan_cache(self, part: DevicePartition,
                            state: EngineState) -> None:
        """`plan="auto-tuned"` resolution: probe the live frontier
        histogram (the fingerprint's density facet — the same measurement
        `tune()` keys its stored plans by), look the partition's
        fingerprint up in the persistent plan cache (repro.tuning); a hit
        adopts the stored plan (no evaluator probes run — the whole point
        of the cache), a miss keeps the engine's defaults."""
        self._auto_plan_pending = False
        from repro.tuning import PlanCache, plan_cache_key
        cache = self._plan_cache
        if not isinstance(cache, PlanCache):
            cache = PlanCache(cache)
        hist = self.probe_frontier_hist(part, state)
        key = plan_cache_key(part=part, program=self.program, mesh_size=1,
                             frontier_hist=hist)
        self._plan_key, self._plan_hist = key, hist
        plan = cache.lookup(key)
        if plan is not None:
            self.adopt_plan(plan)

    def refresh_plan(self, part: DevicePartition) -> bool:
        """Re-key a consulted tuned plan after a graph mutation.

        The fingerprint quantizes its facets (log2 edge counts, skew
        bins), so a small `apply_edge_delta` is ABSORBED — same key, the
        adopted plan stands and no retrace happens.  A large delta shifts
        a bin: the stale key (the bug this fixes — plans tuned for the
        pre-mutation graph silently governing the mutated one) is dropped,
        the cache is consulted under the new key (hit = adopt, miss = keep
        current knobs), and the new key becomes current.  Returns True
        when the key changed.  No-op unless this engine ever consulted
        the cache (`plan="auto-tuned"`).
        """
        if self._plan_key is None:
            return False
        from repro.tuning import PlanCache, plan_cache_key
        key = plan_cache_key(part=part, program=self.program, mesh_size=1,
                             frontier_hist=self._plan_hist)
        if key == self._plan_key:
            return False
        self._plan_key = key
        cache = self._plan_cache
        if not isinstance(cache, PlanCache):
            cache = PlanCache(cache)
        plan = cache.lookup(key)
        if plan is not None:
            self.adopt_plan(plan)
        return True

    def make_plan(self, phases: str = "sync",
                  staleness: int = 0) -> SuperstepPlan:
        """The engine's SuperstepPlan (repro.core.plan): frontier strategy
        request + kernel stage.  `phases` RECORDS the exchange phase shape
        (with `staleness` = the async ring depth k, 0 otherwise) so the
        composed mode is inspectable as one static object (the executor
        itself drives whichever shape the backend's phase protocol
        implements — see `plan.execute_plan`).  Rebuilt on demand so
        `calibrate_frontier_cap`'s capacity update is honored."""
        return SuperstepPlan(
            strategy=self.frontier, frontier_cap=self.frontier_cap,
            dense_frontier=self.dense_frontier, phases=phases,
            staleness=staleness,
            kernel=KernelPlan(use_pallas=self.use_pallas,
                              dynamic_table=self.dynamic_table))

    def _frontier_plan(self, part: DevicePartition):
        """Legacy shim over `plan.resolve_frontier`: None for the dense
        path (compile no compacted branch), else the FrontierPlan tuple
        (``("flat", cap)`` / ``("bucketed", caps)``)."""
        fp = self.make_plan().frontier(part)
        return None if fp.kind == "dense" else fp

    def calibrate_frontier_cap(self, part: DevicePartition,
                               state: EngineState, probe_steps: int = 2,
                               ) -> list:
        """Derive `frontier_cap` from the LIVE frontier sizes of the first
        superstep(s) instead of a fixed fraction of `num_slots` (which
        over-allocates on large shards — see `frontier.default_cap`).

        Runs up to `probe_steps` dense supersteps (the state is not
        consumed; callers re-run from the same initial state) and records
        the frontier-size histogram — the PROBE state is threaded through
        ONE jit-compiled superstep, so an N-step probe costs one trace
        plus N executions instead of N eager op-by-op dispatches.  Must
        be called BEFORE the first jitted `run` trace: the capacity is a
        static compile-time shape.  Sets `self.frontier_cap` and returns
        the measured histogram (also kept on `self.frontier_hist`) — the
        tuner's graph fingerprint reuses it as its frontier-density
        estimate rather than re-probing.
        """
        from repro.core.frontier import default_cap
        self.frontier_hist = self.probe_frontier_hist(part, state,
                                                      probe_steps)
        self.frontier_cap = default_cap(part.num_slots,
                                        frontier_hist=self.frontier_hist)
        return self.frontier_hist

    def probe_frontier_hist(self, part: DevicePartition, state: EngineState,
                            probe_steps: int = 2) -> list:
        """The shared probe harness's frontier measurement: run up to
        `probe_steps` dense supersteps from `state` (not consumed) and
        return the live frontier-size histogram `[|F_0|, |F_1|, ...]`.
        One dense-strategy superstep is jitted once and reused across
        probe steps."""
        probe = GREEngine(self.program, dense_frontier=self.dense_frontier,
                          frontier="dense")
        step = jax.jit(lambda s: probe.superstep(part, s))
        hist, s = [], state
        for _ in range(probe_steps):
            n = int(jnp.sum(s.active_scatter))
            if n == 0:
                break
            hist.append(n)
            s = step(s)
        return hist

    # ------------------------------------------------------------------ init
    def init_state(self, part: DevicePartition, source=None,
                   lane_tracking: bool = False) -> EngineState:
        """`source` may be a single vertex id, or — for multi-source batched
        traversal programs with `payload_shape=(D,)` — a length-D sequence:
        source d seeds payload lane d, so ONE pass answers D roots.

        Multi-source seeding is LANE-MASKED: entries that are None or
        negative leave their lane unseeded (identity values, inactive) —
        the serving layer starts with fewer queries than lanes and admits
        into the free lanes later.  Seeding goes through the program's
        `seed_sources` hook when it has one (PPR stages its first push);
        the default is the traversal convention (0.0 at `[src, lane]`).

        `lane_tracking=True` attaches the per-lane halt tracker
        (`EngineState.lane_active`, seeded lanes start active); requires a
        multi-source program exposing `lane_activates`.
        """
        p = self.program
        n, s = part.num_masters, part.num_slots
        vertex_data = p.init_vertex_data(n, part.aux)
        sd0 = jnp.asarray(p.init_scatter_data(n, part.aux), p.msg_dtype)
        scatter_data = jnp.full((s,) + sd0.shape[1:], p.monoid.identity,
                                p.msg_dtype).at[:n].set(sd0)
        active = jnp.zeros(s, dtype=bool).at[:n].set(p.init_active(n, part.aux))
        lane_active = None
        multi = source is not None and np.ndim(source) > 0
        if source is not None and not multi:
            src_idx = jnp.asarray(source, jnp.int32)
            vertex_data = vertex_data.at[src_idx].set(0.0)
            scatter_data = scatter_data.at[src_idx].set(0.0)
            active = jnp.zeros(s, dtype=bool).at[src_idx].set(True)
        elif multi:  # one source per payload lane, None/-1 = lane unseeded
            seeded = np.array([sv is not None and int(sv) >= 0
                               for sv in source])
            src_np = np.array([int(sv) if ok else s
                               for sv, ok in zip(source, seeded)], np.int32)
            src_idx = jnp.asarray(src_np)          # sentinel s drops
            lanes = jnp.arange(src_idx.shape[0])
            if p.seed_sources is not None:
                vertex_data, scatter_data = p.seed_sources(
                    vertex_data, scatter_data, src_idx, lanes, part.aux)
            else:
                vertex_data = vertex_data.at[src_idx, lanes].set(
                    0.0, mode="drop")
                scatter_data = scatter_data.at[src_idx, lanes].set(
                    0.0, mode="drop")
            active = jnp.zeros(s, dtype=bool).at[src_idx].set(
                True, mode="drop")
            if lane_tracking:
                lane_active = jnp.asarray(seeded)
        if lane_tracking and (lane_active is None
                              or p.lane_activates is None):
            raise ValueError("lane_tracking needs a multi-source (sequence) "
                             "`source` and a program with `lane_activates` "
                             "(payload_shape=(D,))")
        state = EngineState(vertex_data, scatter_data, active,
                            jnp.zeros((), jnp.int32), lane_active)
        if self._auto_plan_pending:
            # plan="auto-tuned": the seeded state is the last eager point
            # before a jitted run trace fixes the static tile shapes, and
            # the cache key's frontier-density facet needs it
            self._consult_plan_cache(part, state)
        return state

    # ------------------------------------------------------------ incremental
    def warm_start_state(self, part: DevicePartition, prev_state: EngineState,
                         report, source=None, lane_tracking: bool = False
                         ) -> EngineState:
        """Seed a re-convergence run on the MUTATED partition from the
        previous fixed point (repro.core.incremental; docs/incremental.md).

        Iterative programs (PageRank) carry the previous values forward
        under fresh init activity — the contraction resumes from a nearby
        point.  Halting min-monoid traversals get the exact treatment:
        entries no longer certified by the surviving edges are reset to
        their initial values (the program's `invalidation` policy), and
        only add-endpoints, in-neighbors of resets, and self-seeding
        resets start active.  An empty delta yields an empty frontier —
        the run terminates immediately at the previous fixed point.
        """
        from repro.core import incremental
        p = self.program
        incremental.check_supported(p, report)
        n = part.num_masters
        state0 = self.init_state(part, source=source,
                                 lane_tracking=lane_tracking)
        if not p.halts:
            return dataclasses.replace(
                state0,
                vertex_data=prev_state.vertex_data,
                scatter_data=state0.scatter_data.at[:n].set(
                    prev_state.scatter_data[:n]))
        vd_prev = np.asarray(prev_state.vertex_data)
        sd_prev = np.asarray(prev_state.scatter_data)[:n]
        src = np.asarray(part.src)
        mask = np.asarray(part.edge_mask)
        lsrc = src[mask].astype(np.int64)
        ldst = np.asarray(part.dst)[mask].astype(np.int64)
        eprop = None
        if p.needs_edge_prop:
            eprop = np.asarray(part.edge_props[p.needs_edge_prop])[mask]
        protected = incremental.source_mask(vd_prev.shape, source)
        tainted = incremental.compute_taint(p, n, lsrc, ldst, eprop,
                                            vd_prev, report, protected)
        vd = np.where(tainted, np.asarray(state0.vertex_data), vd_prev)
        sd = np.where(tainted, np.asarray(state0.scatter_data)[:n], sd_prev)
        tany = tainted if tainted.ndim == 1 else tainted.any(axis=-1)
        init_act = np.asarray(p.init_active(n, part.aux))
        act = incremental.warm_seed_active(n, lsrc, ldst, tany,
                                           report.added_src, init_act)
        active = jnp.zeros(part.num_slots, dtype=bool).at[:n].set(
            jnp.asarray(act))
        return dataclasses.replace(
            state0,
            vertex_data=jnp.asarray(vd, np.asarray(vd_prev).dtype),
            scatter_data=state0.scatter_data.at[:n].set(
                jnp.asarray(sd, p.msg_dtype)),
            active_scatter=active)

    def rerun_incremental(self, part: DevicePartition, prev_state: EngineState,
                          delta, *, source=None, max_steps: int = 100,
                          lane_tracking: bool = False):
        """Apply an EdgeDelta and re-converge from `prev_state`'s fixed
        point through the unchanged plan executor.

        Returns ``(new_partition, final_state, report)``.  The final state
        is bitwise-equal to a cold `run` on the mutated graph for halting
        min-monoid programs (tests/test_conformance.py locks this down);
        iterative programs re-converge to the same tolerance they always
        carry.  Supersteps and edge scans are proportional to the
        perturbation, not the graph (benchmarks/bench_incremental.py).
        """
        new_part, report = part.apply_edge_delta(
            delta, bucket_bounds=self.bucket_bounds)
        state = self.warm_start_state(new_part, prev_state, report,
                                      source=source,
                                      lane_tracking=lane_tracking)
        self.refresh_plan(new_part)
        out = self.run(new_part, state, max_steps)
        return new_part, out, report

    # ------------------------------------------------------- scatter-combine
    def scatter_combine(self, part: DevicePartition, state: EngineState,
                        num_segments: Optional[int] = None) -> jnp.ndarray:
        """Phase 1: active messages on all out-edges of active vertices.

        Returns the ⊕-accumulated combine_data over `num_segments` slots
        ([num_segments, *payload_shape]; defaults to all local slots).

        Dispatches between the dense every-edge scan and the
        frontier-compacted CSR-range gather (core/frontier.py) via the
        plan's scatter stage (`SuperstepPlan.scatter_combine`); exchange
        backends call THIS, so compaction slots in without touching them.
        """
        return self.make_plan().scatter_combine(self, part, state,
                                                num_segments)

    def dense_scatter_combine(self, part: DevicePartition, state: EngineState,
                              num_segments: Optional[int] = None
                              ) -> jnp.ndarray:
        """The dense strategy: scan every edge, mask inactive sources."""
        assert part.src is not None, \
            "partition carries no edge columns (tile-only topology)"
        p = self.program
        eprop = (part.edge_props[p.needs_edge_prop]
                 if p.needs_edge_prop else None)
        gathered = jnp.take(state.scatter_data, part.src, axis=0,
                            fill_value=p.monoid.identity)
        msgs = p.scatter_msg(gathered, eprop)
        if self.dense_frontier:
            msgs = msgs.astype(p.msg_dtype)
        else:
            live = jnp.take(state.active_scatter, part.src, axis=0,
                            fill_value=False) & part.edge_mask
            live = live.reshape(live.shape + (1,) * (msgs.ndim - live.ndim))
            msgs = jnp.where(live, msgs.astype(p.msg_dtype),
                             p.monoid.identity)
        return segment_combine(
            msgs, part.dst, num_segments or part.num_slots, p.monoid,
            indices_are_sorted=part.edges_sorted_by_dst,
            use_pallas=self.use_pallas)

    # ------------------------------------------------------------------ apply
    def apply(self, part: DevicePartition, state: EngineState,
              combined: jnp.ndarray) -> EngineState:
        """Phase 2: fold combine_data into vertex_data; assert_to_halt.

        `aux` reaching apply_fn carries the superstep counter under "step" —
        level-synchronous programs (Brandes' backward δ) schedule themselves
        off it without bespoke drivers.
        """
        p = self.program
        n = part.num_masters
        combined_m = combined[:n]
        aux = dict(part.aux)
        aux["step"] = state.step
        act_apply = p.combine_activates(state.vertex_data, combined_m)
        new_vd, new_sd, act_scatter = p.apply_fn(state.vertex_data,
                                                 combined_m, aux)
        bva = act_apply.reshape(act_apply.shape + (1,) * (new_vd.ndim - act_apply.ndim))
        vertex_data = jnp.where(bva, new_vd, state.vertex_data)
        bsa = act_apply.reshape(act_apply.shape + (1,) * (new_sd.ndim - act_apply.ndim))
        scatter_data = state.scatter_data.at[:n].set(
            jnp.where(bsa, new_sd.astype(p.msg_dtype),
                      state.scatter_data[:n]))
        if p.halts:  # traversal: only improved vertices scatter next round
            next_active = act_apply & act_scatter
        else:        # iterative: activity is whatever apply asserts
            next_active = act_scatter
        active = jnp.zeros_like(state.active_scatter).at[:n].set(next_active)
        # per-lane halt tracking (serving): reduce the program's per-lane
        # improvement over the masters — lane d quiet this superstep means
        # its query converged (monotone lanes cannot reawaken on their own)
        lane_active = state.lane_active
        if lane_active is not None and p.lane_activates is not None:
            lane_active = jnp.any(p.lane_activates(state.vertex_data,
                                                   combined_m), axis=0)
        return EngineState(vertex_data, scatter_data, active, state.step + 1,
                           lane_active)

    # ------------------------------------------------------------- superstep
    def superstep(self, part: DevicePartition, state: EngineState,
                  exchange: ExchangeBackend = NULL_EXCHANGE) -> EngineState:
        """THE superstep: refresh → scatter-combine/reduce → apply.

        Single-shard and distributed execution differ only in `exchange`.
        Delegates to the plan layer's phase-protocol form
        (`plan.execute_superstep`) so a single eager superstep — the
        serving tick — takes the same local_phase/merge path on every
        backend, including the pipelined split tiles.
        """
        from repro.core.plan import execute_superstep
        return execute_superstep(self, part, state, exchange)

    # -------------------------------------------------------------------- run
    @partial(jax.jit, static_argnums=(0, 3))
    def run(self, part: DevicePartition, state: EngineState,
            max_steps: int = 100) -> EngineState:
        """BSP loop: terminate when no vertex is scatter-active (paper §4.1)
        or after `max_steps` supersteps.

        Single-shard entry to the plan executor (`plan.execute_plan`) with
        the NullExchange — the SAME driver loop the distributed engine
        runs under shard_map with real backends (sync or pipelined phase
        shapes).
        """
        return execute_plan(self, part, state, NULL_EXCHANGE,
                            max_steps=max_steps)

    # ------------------------------------------------- GAS baseline (ablation)
    def gas_superstep(self, part: DevicePartition, state: EngineState,
                      edge_state: jnp.ndarray) -> tuple:
        """Two-sided GAS emulation (paper §2.2 motivation, Fig. 2 left).

        Phase S-1 scatter: materialize per-edge messages into `edge_state`
        (the intermediate storage Scatter-Combine eliminates).  Phase S
        gather: poll in-edges and reduce.  Used only by the GAS-vs-SC
        ablation benchmark; numerically identical, strictly more memory
        traffic (one extra [E] store + load).
        """
        p = self.program
        eprop = (part.edge_props[p.needs_edge_prop]
                 if p.needs_edge_prop else None)
        gathered = jnp.take(state.scatter_data, part.src, axis=0,
                            fill_value=p.monoid.identity)
        msgs = p.scatter_msg(gathered, eprop)
        live = jnp.take(state.active_scatter, part.src, axis=0,
                        fill_value=False) & part.edge_mask
        new_edge_state = jnp.where(live, msgs.astype(p.msg_dtype),
                                   p.monoid.identity)
        # --- super-step boundary: edge_state persists ---
        combined = segment_combine(
            new_edge_state, part.dst, part.num_slots, p.monoid,
            indices_are_sorted=part.edges_sorted_by_dst)
        return self.apply(part, state, combined), new_edge_state
