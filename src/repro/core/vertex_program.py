"""Scatter-Combine abstraction (paper §4, Alg. 1).

A `VertexProgram` instantiates the four primitives:

  scatter(u, v, e)   — generates an active message `msg = s(u.scatter_data,
                       e.state)` (here `scatter_msg`);
  combine(msg)       — folds the message into the destination's combine_data
                       with a commutative+associative generalized sum ⊕
                       (here a `Monoid`), optionally activating apply;
  apply(v)           — recomputes vertex_data from the accumulated sum and
                       optionally re-activates scatter;
  assert_to_halt(v)  — deactivates scatter (traversal algorithms) or keeps
                       the vertex active (iterative algorithms).

On TPU the data race the paper handles with vLock does not exist: the whole
scatter-combine phase is one fused `gather → message → segment-reduce`
dataflow op, race-free and deterministic by construction.

A worked example — in-degree counting as a one-superstep program.  Every
vertex starts active and scatters the constant 1 along its out-edges; ⊕ is
sum, so each vertex's accumulator ends up holding its in-degree; apply
stores it and deactivates (`halts=True` + all-False activation ends the
run after one superstep):

    >>> import numpy as np
    >>> import jax.numpy as jnp
    >>> from repro.core.vertex_program import MONOIDS, VertexProgram
    >>> indegree = VertexProgram(
    ...     name="indegree", monoid=MONOIDS["sum"],
    ...     scatter_msg=lambda src_scatter, eprop: jnp.ones_like(src_scatter),
    ...     apply_fn=lambda vd, combined, aux: (
    ...         combined, combined, jnp.zeros_like(combined, dtype=bool)),
    ...     init_vertex_data=lambda n, aux: jnp.zeros(n, jnp.float32),
    ...     init_scatter_data=lambda n, aux: jnp.zeros(n, jnp.float32),
    ...     init_active=lambda n, aux: jnp.ones(n, dtype=bool))
    >>> from repro.core.engine import DevicePartition, GREEngine
    >>> from repro.graph.structures import Graph
    >>> g = Graph(3, np.array([0, 0, 1]), np.array([1, 2, 2]))
    >>> part = DevicePartition.from_graph(g)
    >>> eng = GREEngine(indegree)
    >>> out = eng.run(part, eng.init_state(part), max_steps=5)
    >>> np.asarray(out.vertex_data)          # in-degrees of vertices 0,1,2
    array([0., 1., 2.], dtype=float32)
    >>> int(out.step)                        # halted after one superstep
    1

The same program object runs unchanged on a multi-device mesh through
`DistGREEngine` with any ExchangeBackend (`repro.core.exchange`), and
with any frontier strategy (`repro.core.frontier`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Monoid:
    """Commutative+associative generalized sum ⊕ with identity (paper §2.2)."""

    name: str
    identity: float
    op: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]

    def segment_reduce(self, msgs: jnp.ndarray, dst: jnp.ndarray,
                       num_segments: int, indices_are_sorted: bool = False
                       ) -> jnp.ndarray:
        if self.name == "sum":
            return jax.ops.segment_sum(msgs, dst, num_segments,
                                       indices_are_sorted=indices_are_sorted)
        if self.name == "min":
            return jax.ops.segment_min(msgs, dst, num_segments,
                                       indices_are_sorted=indices_are_sorted)
        if self.name == "max":
            return jax.ops.segment_max(msgs, dst, num_segments,
                                       indices_are_sorted=indices_are_sorted)
        raise ValueError(self.name)


MONOIDS: Dict[str, Monoid] = {
    "sum": Monoid("sum", 0.0, jnp.add),
    "min": Monoid("min", jnp.inf, jnp.minimum),
    "max": Monoid("max", -jnp.inf, jnp.maximum),
}


def segment_combine(msgs: jnp.ndarray, dst: jnp.ndarray, num_segments: int,
                    monoid: Monoid, indices_are_sorted: bool = False,
                    use_pallas: bool = False, interpret: bool = True
                    ) -> jnp.ndarray:
    """One-sided combine of active messages at their destinations.

    This is the Scatter-Combine hot path.  The XLA path lowers to a fused
    scatter-reduce; the Pallas path (TPU target) tiles dst-sorted edges into
    VMEM blocks and turns the irregular reduction into block-local one-hot
    MXU matmuls (sum) or masked VPU reductions (min/max).
    """
    if use_pallas:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.segment_combine(msgs, dst, num_segments,
                                          monoid.name, interpret=interpret)
    return monoid.segment_reduce(msgs, dst, num_segments, indices_are_sorted)


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """User-defined vertex computation in the Scatter-Combine model.

    State layout follows paper §6.1.3 (flat column arrays indexed by local
    vertex id):

      vertex_data   — result state, owned by masters, updated by `apply`;
      scatter_data  — the datum a vertex scatters, refreshed by `apply`
                      (and, for scatter agents, by the master's message);
      combine_data  — the ⊕ accumulator, reset after each apply.

    Message payloads are first-class `[slots, *payload_shape]` feature
    vectors; the scalar programs of the paper are the `payload_shape = ()`
    special case.  `payload_shape`/`msg_dtype` form the payload spec that
    init, scatter, combine, and apply all consume uniformly: init_scatter
    returns `[n, *payload-or-scatter shape]`, scatter_msg maps gathered
    scatter data `[E, *S]` to messages `[E, *payload_shape]`, the ⊕
    accumulator is `[slots, *payload_shape]`, and apply folds it.

    `scatter_msg(src_scatter_data, edge_prop)` builds message payloads for a
    batch of edges at once (the engine has already gathered source data).
    `apply_fn(vertex_data, combined, aux)` returns
    `(new_vertex_data, new_scatter_data, activate_scatter)`; the engine
    injects the superstep counter into `aux["step"]` so level-synchronous
    programs can schedule themselves.
    Init functions receive `(n, aux)` where aux holds static per-partition
    columns such as `out_degree`.
    """

    name: str
    monoid: Monoid
    scatter_msg: Callable[[jnp.ndarray, Optional[jnp.ndarray]], jnp.ndarray]
    apply_fn: Callable[[jnp.ndarray, jnp.ndarray, Any], tuple]
    init_vertex_data: Callable[[int, Dict[str, jnp.ndarray]], jnp.ndarray]
    init_scatter_data: Callable[[int, Dict[str, jnp.ndarray]], jnp.ndarray]
    init_active: Callable[[int, Dict[str, jnp.ndarray]], jnp.ndarray]
    # `combine_activates(old_vertex_data, combined) -> bool[V]`: whether the
    # accumulated message actually changes the vertex (paper's
    # `activate_apply`).  Vertices without any improving message skip apply.
    combine_activates: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray] = (
        lambda old, combined: jnp.ones(old.shape[0], dtype=bool))
    # Iterative programs (PageRank) keep scattering; traversal programs halt.
    halts: bool = True
    needs_edge_prop: Optional[str] = None
    # Payload spec: trailing feature shape of messages/⊕ accumulator.
    # () = scalar (PageRank, SSSP); (D,) = feature vectors (GNN aggregation,
    # Brandes σ, batched multi-source BFS).
    payload_shape: Tuple[int, ...] = ()
    msg_dtype: Any = jnp.float32
    # ------------------------------------------------------------ lane hooks
    # Multi-source programs treat the D payload lanes as independent queries
    # (one root per lane).  The three optional hooks below make lanes
    # individually observable and reseedable — the substrate of the serving
    # layer's lane recycling (repro.serving.graph_scheduler):
    #
    # `lane_activates(old_vertex_data, combined) -> bool[n, D]`: per-LANE
    # analogue of `combine_activates` — which (vertex, lane) pairs improved
    # this superstep.  The engine reduces `any` over vertices into
    # `EngineState.lane_active`; a lane with no improvement anywhere has
    # converged (monotone programs: a quiet lane stays quiet).
    lane_activates: Optional[Callable[[jnp.ndarray, jnp.ndarray],
                                      jnp.ndarray]] = None
    # `seed_sources(vertex_data, scatter_data, src, lanes, aux)` seeds root
    # `src[i]` into payload lane `lanes[i]` and returns the updated
    # `(vertex_data, scatter_data)`.  `src`/`lanes` are int32 arrays with
    # OUT-OF-BOUNDS sentinels marking no-op entries (use
    # `.set(..., mode="drop")`), so admission stays one static-shape jitted
    # call.  None = the traversal default (`value 0.0` at `[src, lane]`).
    seed_sources: Optional[Callable] = None
    # `lane_view(vertex_data, lane) -> [n]`: extract lane `lane`'s per-vertex
    # result (default: column `vertex_data[:, lane]`; PPR stores (p, r)
    # pairs and views the estimate).
    lane_view: Optional[Callable[[jnp.ndarray, int], jnp.ndarray]] = None

    @property
    def monotone(self) -> bool:
        """Whether delayed/re-ordered message delivery cannot change the
        fixed point: every message under an idempotent select monoid
        (⊕ = min/max) is a valid bound that a later delivery only
        re-tightens, so bounded-staleness execution
        (`exchange="async"`, repro.core.exchange.AsyncAgentExchange)
        converges to the same values as the synchronous schedule.  True
        for the halting label-correcting traversals (BFS/SSSP/CC); False
        for sum-monoid programs (PageRank/PPR/GNN aggregation), where a
        message folded against a stale accumulator is double-counted —
        those must refuse async execution loudly."""
        return self.halts and self.monoid.name in ("min", "max")
    # ------------------------------------------------------------ incremental
    # Removal-invalidation policy for warm-started re-convergence after an
    # edge delta (repro.core.incremental):
    #   "path"      — support-based worklist (strictly-increasing messages:
    #                 BFS/SSSP);
    #   "component" — forward-reachability reset (cyclic support: CC);
    #   None        — removals are not incrementally recoverable (warm
    #                 start over a delta with removals raises).
    # Pure adds never need a policy (min re-delivery is idempotent).
    invalidation: Optional[str] = None
