"""Multi-stage algorithms (paper §4.2): "with simple extension of backward
traversal on transposed graphs, GRE implements multi-staged algorithms like
Betweenness Centrality".

Brandes' algorithm as a driver over the Scatter-Combine primitive: every
stage is a sequence of BSP supersteps whose per-edge work is the same fused
`gather(src) → message → segment-combine(dst)` used by the engine:

  stage 1  BFS depths (min-combine)                — forward graph
  stage 2  shortest-path counts σ (sum-combine,    — forward graph
           level-synchronous along the BFS DAG)
  stage 3  dependency accumulation δ (sum-combine) — TRANSPOSED graph,
           by decreasing depth
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structures import Graph


@partial(jax.jit, static_argnums=(3, 4))
def _single_source(src, dst, source, num_vertices: int, max_depth: int):
    V = num_vertices
    INF = jnp.int32(2 ** 30)

    # ---- stage 1: BFS depth (min-combine over supersteps) ----
    def bfs_step(_, depth):
        cand = jax.ops.segment_min(jnp.take(depth, src) + 1, dst, V)
        return jnp.minimum(depth, cand)

    depth0 = jnp.full((V,), INF, jnp.int32).at[source].set(0)
    depth = jax.lax.fori_loop(0, max_depth, bfs_step, depth0)

    # ---- stage 2: σ — number of shortest paths, level by level ----
    def sigma_level(t, sigma):
        contrib = jnp.where(jnp.take(depth, src) == t,
                            jnp.take(sigma, src), 0.0)
        agg = jax.ops.segment_sum(contrib, dst, V)
        return jnp.where(depth == t + 1, agg, sigma)

    sigma0 = jnp.zeros((V,), jnp.float32).at[source].set(1.0)
    sigma = jax.lax.fori_loop(0, max_depth, sigma_level, sigma0)

    # ---- stage 3: δ on the TRANSPOSED graph, decreasing depth ----
    def delta_level(i, delta):
        t = max_depth - i                      # depth of the "downwind" side
        ratio = jnp.where((jnp.take(depth, dst) == t) & (sigma[dst] > 0),
                          (1.0 + jnp.take(delta, dst)) / jnp.maximum(
                              jnp.take(sigma, dst), 1.0), 0.0)
        # transposed edge (dst -> src): combine at src
        agg = jax.ops.segment_sum(ratio, src, V)
        upd = sigma * agg
        return jnp.where(depth == t - 1, delta + upd, delta)

    delta = jax.lax.fori_loop(0, max_depth, delta_level,
                              jnp.zeros((V,), jnp.float32))
    return jnp.where(jnp.arange(V) == source, 0.0, delta)


def betweenness_centrality(graph: Graph,
                           sources: Optional[Sequence[int]] = None,
                           max_depth: Optional[int] = None) -> np.ndarray:
    """Exact when `sources` covers all vertices; sampled-approximate
    otherwise (standard Brandes estimator)."""
    V = graph.num_vertices
    sources = range(V) if sources is None else sources
    max_depth = max_depth or min(V, 64)
    src = jnp.asarray(graph.src, jnp.int32)
    dst = jnp.asarray(graph.dst, jnp.int32)
    bc = jnp.zeros((V,), jnp.float32)
    for s in sources:
        bc = bc + _single_source(src, dst, int(s), V, max_depth)
    return np.asarray(bc)
