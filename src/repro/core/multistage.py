"""Multi-stage algorithms (paper §4.2): "with simple extension of backward
traversal on transposed graphs, GRE implements multi-staged algorithms like
Betweenness Centrality".

Brandes' algorithm as TWO staged VertexPrograms through the canonical
engine superstep — no hand-rolled loops:

  stage 1+2  forward σ   — FORWARD partition, vector payload (D, 3):
             per source lane d, msg = [frontier flag, depth+1, σ]; ⊕ = sum.
             BFS depth and shortest-path counts compute in one pass: an
             unvisited lane receiving flag > 0 folds
             depth = Σ(depth+1)/Σflag (all frontier parents share one
             depth, level-synchronous BSP) and σ = Σ σ_parent, then joins
             that lane's frontier.  Lane gating rides the ⊕ identity: apply
             zeroes every lane that did not JUST join, so re-activated
             vertices contribute nothing on already-settled lanes.
  stage 3    backward δ  — TRANSPOSED partition, payload (D,):
             levels run DESCENDING, scheduled off the superstep counter the
             engine injects as aux["step"]: lanes at level dmax-i scatter
             (1+δ)/σ at superstep i (other lanes hold the sum identity 0);
             receivers one level up fold δ += σ·⊕.  Level-synchrony makes
             every folded edge a shortest-path-DAG edge, so no per-edge
             filtering is needed.

Source batching is IN THE PAYLOAD: one engine pass serves all D sources of
a batch (topology is traversed once, not once per source), replacing the
earlier `jax.vmap` over per-source pipelines.  The same batching works
distributed — the programs are ordinary vector-payload VertexPrograms, so
every ExchangeBackend speaks them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DevicePartition, EngineState, GREEngine
from repro.core.vertex_program import MONOIDS, VertexProgram
from repro.graph.structures import Graph


def bc_forward_program(num_sources: int) -> VertexProgram:
    """Stage 1+2: BFS depth + σ for D sources in one forward pass.

    vertex_data is [n, D, 2] = (depth, σ); scatter_data IS the message
    triple [n, D, 3] = (frontier flag, depth+1, σ), zeroed on lanes off the
    current frontier so ⊕ = sum ignores them.
    """
    D = num_sources

    def scatter_msg(src_scatter, _eprop):
        return src_scatter  # apply pre-builds the gated (flag, depth+1, σ)

    def combine_activates(old_vd, combined):
        newly = jnp.isinf(old_vd[..., 0]) & (combined[..., 0] > 0)
        return jnp.any(newly, axis=-1)

    def apply_fn(vertex_data, combined, _aux):
        newly = jnp.isinf(vertex_data[..., 0]) & (combined[..., 0] > 0)
        depth = combined[..., 1] / jnp.maximum(combined[..., 0], 1.0)
        sigma = combined[..., 2]
        new_vd = jnp.where(newly[..., None],
                           jnp.stack([depth, sigma], axis=-1), vertex_data)
        sd = jnp.where(newly[..., None],
                       jnp.stack([jnp.ones_like(depth), depth + 1.0, sigma],
                                 axis=-1), 0.0)
        return new_vd, sd, jnp.any(newly, axis=-1)

    def init_unvisited(n, _aux):
        return jnp.stack([jnp.full((n, D), jnp.inf, jnp.float32),
                          jnp.zeros((n, D), jnp.float32)], axis=-1)

    return VertexProgram(
        name="bc_forward", monoid=MONOIDS["sum"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=init_unvisited,
        init_scatter_data=lambda n, aux: jnp.zeros((n, D, 3), jnp.float32),
        init_active=lambda n, aux: jnp.zeros(n, dtype=bool),
        combine_activates=combine_activates, halts=True,
        payload_shape=(D, 3))


def bc_backward_program(num_sources: int) -> VertexProgram:
    """Stage 3: δ accumulation, level-synchronous by DESCENDING depth.

    Needs aux columns "depth", "sigma" ([n, D] stage-1/2 outputs) and scalar
    "dmax" (global max over lanes); the engine injects "step".  Runs on the
    TRANSPOSED partition.  A lane scatters only at its level's superstep —
    off-level lanes hold the sum identity 0.
    """
    D = num_sources

    def scatter_msg(src_scatter, _eprop):
        return src_scatter  # (1 + δ_v) / σ_v on the level's lanes, else 0

    def apply_fn(delta, combined, aux):
        tgt = aux["dmax"] - aux["step"].astype(jnp.float32) - 1.0
        fold = aux["depth"] == tgt                       # [n, D]
        new_delta = jnp.where(fold, delta + aux["sigma"] * combined, delta)
        sd = jnp.where(fold, (1.0 + new_delta)
                       / jnp.maximum(aux["sigma"], 1.0), 0.0)
        return new_delta, sd, jnp.any(fold, axis=-1)

    def init_scatter(n, aux):
        top = aux["depth"] == aux["dmax"]
        return jnp.where(top, 1.0 / jnp.maximum(aux["sigma"], 1.0), 0.0)

    return VertexProgram(
        name="bc_backward", monoid=MONOIDS["sum"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=lambda n, aux: jnp.zeros((n, D), jnp.float32),
        init_scatter_data=init_scatter,
        init_active=lambda n, aux: jnp.any(aux["depth"] == aux["dmax"],
                                           axis=-1),
        halts=False, payload_shape=(D,))


def _make_bc_batch(graph: Graph, max_depth: int, batch: int):
    """Jitted payload-batched pipeline: [D] source ids -> [V, D] δ lanes."""
    V = graph.num_vertices
    fwd_part = DevicePartition.from_graph(graph)
    bwd_part = DevicePartition.from_graph(graph, transpose=True)
    fwd = GREEngine(bc_forward_program(batch))
    # backward is iterative (halts=False) but the frontier is one depth
    # level at a time — keep per-edge activity masks on.
    bwd = GREEngine(bc_backward_program(batch), dense_frontier=False)
    slots = fwd_part.num_slots

    def run_batch(sources):                              # [D] int32
        lanes = jnp.arange(batch)
        st = fwd.init_state(fwd_part)
        src_vd = jnp.array([0.0, 1.0], jnp.float32)      # depth 0, σ 1
        src_sd = jnp.array([1.0, 1.0, 1.0], jnp.float32)  # flag, depth+1, σ
        st = EngineState(
            st.vertex_data.at[sources, lanes].set(src_vd),
            st.scatter_data.at[sources, lanes].set(src_sd),
            jnp.zeros(slots, dtype=bool).at[sources].set(True),
            st.step)
        out = fwd.run(fwd_part, st, max_depth)
        depth, sigma = out.vertex_data[..., 0], out.vertex_data[..., 1]
        dmax = jnp.max(jnp.where(jnp.isinf(depth), -1.0, depth))
        part_b = dataclasses.replace(
            bwd_part, aux={**bwd_part.aux, "depth": depth, "sigma": sigma,
                           "dmax": dmax})
        delta = bwd.run(part_b, bwd.init_state(part_b),
                        max_depth + 1).vertex_data       # [V, D]
        own = jnp.arange(V)[:, None] == sources[None, :]
        return jnp.where(own, 0.0, delta)

    return jax.jit(run_batch)


def betweenness_centrality(graph: Graph,
                           sources: Optional[Sequence[int]] = None,
                           max_depth: Optional[int] = None,
                           batch: int = 64) -> np.ndarray:
    """Exact when `sources` covers all vertices; sampled-approximate
    otherwise (standard Brandes estimator).  Sources run `batch` at a time
    as payload lanes of ONE two-stage engine pipeline — the graph is
    traversed once per batch, not once per source."""
    V = graph.num_vertices
    sources = np.arange(V) if sources is None else np.asarray(list(sources))
    max_depth = max_depth or min(V, 64)
    batch = min(batch, max(1, sources.shape[0]))
    run_batch = _make_bc_batch(graph, max_depth, batch)
    bc = jnp.zeros((V,), jnp.float32)
    for lo in range(0, sources.shape[0], batch):
        chunk = sources[lo:lo + batch]
        # pad the ragged tail to a static lane count (one compile, not two);
        # padded lanes repeat a real source and are weighted out of the sum
        n = chunk.shape[0]
        padded = np.pad(chunk, (0, batch - n), mode="edge")
        w = jnp.asarray(np.arange(batch) < n, jnp.float32)
        bc = bc + (run_batch(jnp.asarray(padded, jnp.int32))
                   * w[None, :]).sum(axis=1)
    return np.asarray(bc)
