"""Multi-stage algorithms (paper §4.2): "with simple extension of backward
traversal on transposed graphs, GRE implements multi-staged algorithms like
Betweenness Centrality".

Brandes' algorithm as TWO staged VertexPrograms through the canonical
engine superstep — no hand-rolled loops:

  stage 1+2  forward σ   — FORWARD partition, vector payload (3,):
             msg = [frontier flag, depth+1, σ]; ⊕ = sum.  BFS depth and
             shortest-path counts compute in one pass: an unvisited vertex
             receiving flag > 0 folds depth = Σ(depth+1)/Σflag (all frontier
             parents share one depth, level-synchronous BSP) and
             σ = Σ σ_parent, then joins the frontier (assert_to_halt keeps
             everyone else silent).
  stage 3    backward δ  — TRANSPOSED partition, scalar payload:
             levels run DESCENDING, scheduled off the superstep counter the
             engine injects as aux["step"]: level dmax-i scatters
             (1+δ)/σ at superstep i; receivers one level up fold
             δ += σ·⊕.  Level-synchrony makes every folded edge a
             shortest-path-DAG edge, so no per-edge filtering is needed.

Sources batch through `jax.vmap` over the per-source two-stage pipeline —
the multi-source batching that first-class vector payloads buy us.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DevicePartition, EngineState, GREEngine
from repro.core.vertex_program import MONOIDS, VertexProgram
from repro.graph.structures import Graph


def bc_forward_program() -> VertexProgram:
    """Stage 1+2: BFS depth + σ in one forward pass (vector payload)."""

    def scatter_msg(src_scatter, _eprop):
        d, s = src_scatter[..., 0], src_scatter[..., 1]
        return jnp.stack([jnp.ones_like(d), d + 1.0, s], axis=-1)

    def combine_activates(old_vd, combined):
        return jnp.isinf(old_vd[..., 0]) & (combined[..., 0] > 0)

    def apply_fn(vertex_data, combined, _aux):
        depth = combined[..., 1] / jnp.maximum(combined[..., 0], 1.0)
        new = jnp.stack([depth, combined[..., 2]], axis=-1)
        return new, new, jnp.ones(vertex_data.shape[0], dtype=bool)

    def init_unvisited(n, _aux):
        return jnp.stack([jnp.full(n, jnp.inf, jnp.float32),
                          jnp.zeros(n, jnp.float32)], axis=-1)

    return VertexProgram(
        name="bc_forward", monoid=MONOIDS["sum"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=init_unvisited,
        init_scatter_data=init_unvisited,
        init_active=lambda n, aux: jnp.zeros(n, dtype=bool),
        combine_activates=combine_activates, halts=True,
        payload_shape=(3,))


def bc_backward_program() -> VertexProgram:
    """Stage 3: δ accumulation, level-synchronous by DESCENDING depth.

    Needs aux columns "depth", "sigma" (stage-1/2 outputs) and scalar
    "dmax"; the engine injects "step".  Runs on the TRANSPOSED partition.
    """

    def scatter_msg(src_scatter, _eprop):
        return src_scatter  # (1 + δ_v) / σ_v, refreshed by apply

    def apply_fn(delta, combined, aux):
        tgt = aux["dmax"] - aux["step"].astype(jnp.float32) - 1.0
        fold = aux["depth"] == tgt
        new_delta = jnp.where(fold, delta + aux["sigma"] * combined, delta)
        sd = (1.0 + new_delta) / jnp.maximum(aux["sigma"], 1.0)
        return new_delta, sd, fold

    return VertexProgram(
        name="bc_backward", monoid=MONOIDS["sum"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=lambda n, aux: jnp.zeros(n, jnp.float32),
        init_scatter_data=lambda n, aux: 1.0 / jnp.maximum(aux["sigma"], 1.0),
        init_active=lambda n, aux: aux["depth"] == aux["dmax"],
        halts=False)


def _make_bc_batch(graph: Graph, max_depth: int):
    """Jitted, vmapped per-source pipeline: source id -> δ contributions."""
    V = graph.num_vertices
    fwd_part = DevicePartition.from_graph(graph)
    bwd_part = DevicePartition.from_graph(graph, transpose=True)
    fwd = GREEngine(bc_forward_program())
    # backward is iterative (halts=False) but the frontier is one depth
    # level at a time — keep per-edge activity masks on.
    bwd = GREEngine(bc_backward_program(), dense_frontier=False)
    slots = fwd_part.num_slots

    def single(source):
        src_row = jnp.array([0.0, 1.0], jnp.float32)   # depth 0, σ 1
        st = fwd.init_state(fwd_part)
        st = EngineState(
            st.vertex_data.at[source].set(src_row),
            st.scatter_data.at[source].set(src_row),
            jnp.zeros(slots, dtype=bool).at[source].set(True),
            st.step)
        out = fwd.run(fwd_part, st, max_depth)
        depth, sigma = out.vertex_data[..., 0], out.vertex_data[..., 1]
        dmax = jnp.max(jnp.where(jnp.isinf(depth), -1.0, depth))
        part_b = dataclasses.replace(
            bwd_part, aux={**bwd_part.aux, "depth": depth, "sigma": sigma,
                           "dmax": dmax})
        delta = bwd.run(part_b, bwd.init_state(part_b),
                        max_depth + 1).vertex_data
        return jnp.where(jnp.arange(V) == source, 0.0, delta)

    return jax.jit(jax.vmap(single))


def betweenness_centrality(graph: Graph,
                           sources: Optional[Sequence[int]] = None,
                           max_depth: Optional[int] = None,
                           batch: int = 64) -> np.ndarray:
    """Exact when `sources` covers all vertices; sampled-approximate
    otherwise (standard Brandes estimator).  Sources run `batch` at a time
    through one vmapped two-stage engine pipeline."""
    V = graph.num_vertices
    sources = np.arange(V) if sources is None else np.asarray(list(sources))
    max_depth = max_depth or min(V, 64)
    batch = min(batch, max(1, sources.shape[0]))
    run_batch = _make_bc_batch(graph, max_depth)
    bc = jnp.zeros((V,), jnp.float32)
    for lo in range(0, sources.shape[0], batch):
        chunk = sources[lo:lo + batch]
        # pad the ragged tail to a static lane count (one compile, not two);
        # padded lanes repeat a real source and are weighted out of the sum
        n = chunk.shape[0]
        padded = np.pad(chunk, (0, batch - n), mode="edge")
        w = jnp.asarray(np.arange(batch) < n, jnp.float32)
        bc = bc + (run_batch(jnp.asarray(padded, jnp.int32))
                   * w[:, None]).sum(axis=0)
    return np.asarray(bc)
