"""Replication-aware streaming edge partitioning with bounded state.

The paper's greedy loader heuristic (Eq. 8, `repro.core.partition`) scores
only *presence* — has partition i seen this src/dst before — which on
power-law graphs replicates hubs and tails indiscriminately.  HDRF
("High-Degree Replicated First", Petroni et al.; the degree-aware family
surveyed in "Distributed Edge Partitioning for Graph Processing") weights
the affinity term by the endpoints' PARTIAL DEGREES observed so far in the
stream: when an edge must split a vertex across partitions, prefer
replicating the higher-degree endpoint — its replicas amortize over many
edges, while low-degree vertices stay whole.  Lower replication is lower
Agent-Graph cut: fewer combiners/scatters, fewer remote-destination edges
(`partition_quality.remote_dst_edge_fraction`), less exchange traffic.

Loader state is BOUNDED and packed (docs/partitioning.md):

  * per-vertex partition membership — one bitset row per vertex,
    ``ceil(k / 64)`` uint64 words: ``V * ceil(k/64) * 8`` bytes;
  * partial degree counters — ``V`` int32: ``4 * V`` bytes;
  * per-partition edge counts — ``k`` int64.

Total ``V*ceil(k/64)*8 + 4*V + 8*k`` bytes (`hdrf_state_bytes`), the
O(V·k/8 + V + k) bound the memory benchmark asserts — against the
O(2·k·V) bools the un-packed greedy loader used to carry.

Everything here is host-side numpy streaming over the chunk-source
protocol (`graph.structures.EdgeChunkSource`): the partitioner reads the
edge stream once, chunk by chunk, and never needs the whole edge list in
memory — the same pipeline the chunked `build_agent_graph` ingress rides.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.graph.structures import as_chunk_source

HDRF_EPS = 1.0  # balance-term regularizer (Δ-analog of partition.DELTA)


# --------------------------------------------------------------- bitsets
def make_bitset(rows: int, bits: int) -> np.ndarray:
    """Packed boolean matrix `[rows, bits]` as `[rows, ceil(bits/64)]`
    uint64 — bit ``b`` of row ``r`` lives in word ``b >> 6``."""
    return np.zeros((rows, (bits + 63) >> 6), dtype=np.uint64)


def bitset_rows(bs: np.ndarray, rows: np.ndarray, bits: int) -> np.ndarray:
    """Gather `[bits, len(rows)]` 0/1 membership for a batch of rows."""
    j = np.arange(bits)
    words = bs[rows][:, j >> 6]                      # [b, bits] uint64
    return ((words >> (j & 63).astype(np.uint64)) & np.uint64(1)).T


def bitset_set(bs: np.ndarray, rows: np.ndarray, bit: np.ndarray) -> None:
    """Set per-row bits in place (`bit[i]` of `rows[i]`); duplicate
    (row, bit) pairs within the batch OR harmlessly."""
    np.bitwise_or.at(bs, (rows, bit >> 6),
                     np.uint64(1) << (bit & 63).astype(np.uint64))


def bitset_popcount(bs: np.ndarray) -> int:
    """Total set bits (Σ_v |A(v)| — the partitioner's replica count)."""
    return int(np.unpackbits(bs.view(np.uint8)).sum())


# ------------------------------------------------------ state-byte models
def hdrf_state_bytes(num_vertices: int, k: int) -> int:
    """The documented HDRF loader-state bound: packed membership bitset +
    int32 partial degrees + int64 partition loads."""
    return (num_vertices * ((k + 63) >> 6) * 8     # membership bitset
            + 4 * num_vertices                     # partial degrees
            + 8 * k)                               # edge loads


def greedy_state_bytes(num_vertices: int, k: int,
                       num_loaders: int = 1) -> int:
    """Per the packed rewrite of `partition.greedy_partition`: TWO packed
    `[k, ceil(V/64)]` bitsets (src/dst presence) + loads, per loader."""
    return num_loaders * (2 * k * ((num_vertices + 63) >> 6) * 8 + 8 * k)


# ------------------------------------------------------------------ HDRF
def hdrf_partition(graph, k: int, *, lam: float = 1.0,
                   batch_size: int = 256, seed: int = 0,
                   chunk_size: Optional[int] = None,
                   stats: Optional[Dict] = None) -> np.ndarray:
    """HDRF streaming edge placement.

    For edge (u, v) with partial degrees δ(u), δ(v) — counts of stream
    occurrences so far — and θ = δ(u) / (δ(u) + δ(v)):

      score(i) = g(u,i) + g(v,i) + λ · (Max − Ne(i)) / (ε + Max − Min)

      g(u,i) = 1 + (1 − θ)  if i ∈ A(u) else 0      (A = replica set)
      g(v,i) = 1 + θ        if i ∈ A(v) else 0

    The degree normalization is the whole trick: an existing replica of
    the LOWER-degree endpoint scores higher, so ties split by replicating
    the hub — whose copies amortize over its many remaining edges —
    while tail vertices stay on one partition.  λ trades replication for
    balance: λ→0 is pure affinity (lowest replication, worst balance),
    large λ approaches round-robin (perfect balance, hash-like
    replication); replication is monotone non-decreasing in λ.

    `graph` may be a `Graph` or any `EdgeChunkSource`; edges stream chunk
    by chunk and, inside each chunk, score in batches of `batch_size`
    (degrees and replica sets update per batch — `batch_size=1` is the
    exact per-edge stream, matching GRE-S vs GRE-P in the greedy loader).
    Deterministic for a fixed seed (the tiny rng tie-break is the only
    randomness).  `stats`, when given, is filled with the measured
    `state_bytes`, `replication` (Σ|A(v)|), and `replication_factor`.
    """
    source = as_chunk_source(graph, chunk_size or (1 << 18))
    V, E = source.num_vertices, source.num_edges
    part = np.zeros(E, dtype=np.int32)
    member = make_bitset(V, k)                    # A(v): replica bitsets
    deg = np.zeros(V, dtype=np.int32)             # partial degrees
    ne = np.zeros(k, dtype=np.int64)              # per-partition edges
    rng = np.random.default_rng(seed)
    for chunk in source.chunks():
        for lo in range(0, chunk.num_edges, batch_size):
            u = chunk.src[lo:lo + batch_size]
            v = chunk.dst[lo:lo + batch_size]
            np.add.at(deg, u, 1)
            np.add.at(deg, v, 1)
            du = deg[u].astype(np.float64)
            theta = du / (du + deg[v])            # [b]
            g_u = bitset_rows(member, u, k) * (2.0 - theta)   # [k, b]
            g_v = bitset_rows(member, v, k) * (1.0 + theta)
            mx, mn = ne.max(), ne.min()
            bal = lam * (mx - ne) / (HDRF_EPS + mx - mn)      # [k]
            score = g_u + g_v + bal[:, None]
            score += rng.random(score.shape) * 1e-9           # tie-break
            idx = np.argmax(score, axis=0).astype(np.int32)
            part[chunk.offset + lo:chunk.offset + lo + u.shape[0]] = idx
            bitset_set(member, u, idx)
            bitset_set(member, v, idx)
            np.add.at(ne, idx, 1)
    if stats is not None:
        rep = bitset_popcount(member)
        stats["state_bytes"] = member.nbytes + deg.nbytes + ne.nbytes
        stats["replication"] = rep
        stats["replication_factor"] = rep / max(V, 1)
    return part


# -------------------------------------------------------------- registry
def _greedy(graph, k, **kw):
    from repro.core.partition import greedy_partition
    return greedy_partition(graph, k, **kw)


def _hash(graph, k, **kw):
    from repro.core.partition import hash_partition
    return hash_partition(graph, k, **kw)


PARTITIONERS = {
    "hdrf": hdrf_partition,   # replication-aware degree-weighted streaming
    "greedy": _greedy,        # the paper's Eq. 8 presence heuristic
    "hash": _hash,            # random vertex sharding baseline
}


def partition_edges(graph, k: int, method: str = "hdrf",
                    **kw) -> np.ndarray:
    """Name-dispatched edge partitioning — the hook `build_agent_graph`
    uses when handed a partitioner NAME instead of a placement array (the
    name is then recorded on `AgentGraph.partitioner` and folded into the
    tuned-plan cache key, `repro.tuning.fingerprint`)."""
    if method not in PARTITIONERS:
        raise ValueError(f"unknown partitioner {method!r}; "
                         f"choose from {sorted(PARTITIONERS)}")
    return PARTITIONERS[method](graph, k, **kw)
