"""ExchangeBackend: the pluggable communication substrate of the engine.

The GRE computation model (paper §4, Alg. 2) is one canonical superstep —
refresh scatter state, fused scatter-combine, apply — independent of HOW
partial combines cross device boundaries.  This module isolates that seam:

  NullExchange   — single shard: every destination is local, nothing moves.
  AgentExchange  — the paper's Agent-Graph (§5): masters push ONE message per
                   (master, peer) to scatter agents before the local phase;
                   combiners push ONE ⊕-reduced message per agent to their
                   master after it.  |V_s| + |V_c| messages per superstep.
                   `overlap=True` issues the flush for remote-destined edges
                   before local-destined edges compute (§6.2's communication/
                   computation overlap, as an XLA scheduling hint).
  DenseExchange  — hash-partition/Pregel baseline: ⊕-reduce the full
                   relabeled vertex vector with a collective (psum/pmin/pmax).

All three speak first-class feature-vector payloads: state and message
arrays are `[slots, *payload_shape]`; scalars are the `payload_shape=()`
special case.  Backends are plain callables on jnp arrays, usable inside
`shard_map` (Agent/Dense) or outside any mesh (Null).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.vertex_program import Monoid, segment_combine

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids a cycle
    from repro.core.engine import DevicePartition, EngineState, GREEngine


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardTopology:
    """Device-local (inside shard_map) view of one AgentGraph partition."""

    part: "DevicePartition"        # local slots + edges
    comb_send_slot: jnp.ndarray    # [k, x_pad]
    comb_recv_master: jnp.ndarray  # [k, x_pad]
    scat_send_master: jnp.ndarray  # [k, x_pad]
    scat_recv_slot: jnp.ndarray    # [k, x_pad]


def _master_mask(combined: jnp.ndarray, num_masters: int) -> jnp.ndarray:
    """[slots] -> broadcastable-to-payload bool mask of master slots."""
    m = jnp.arange(combined.shape[0]) < num_masters
    return m.reshape(m.shape + (1,) * (combined.ndim - 1))


def refresh_scatter_agents(topo: ShardTopology, scatter_data: jnp.ndarray,
                           active: jnp.ndarray, axes,
                           dense: bool = False):
    """Exchange 1 (master → scatter agent): ONE message per (master, peer).

    Works for scalar or feature-vector `scatter_data` ([slots] or
    [slots, *D]).  Returns refreshed (scatter_data, active).  With
    `dense=True` (iterative programs: every vertex active) the activity
    payload is skipped — half the exchange ops.
    """
    vals = jnp.take(scatter_data, topo.scat_send_master, axis=0)   # [k, x, *D]
    rec_v = jax.lax.all_to_all(vals, axes, split_axis=0, concat_axis=0,
                               tiled=True)
    slots = topo.scat_recv_slot.reshape(-1)
    flat_v = rec_v.reshape((-1,) + rec_v.shape[2:])
    sd = scatter_data.at[slots].set(flat_v.astype(scatter_data.dtype),
                                    mode="drop")
    if dense:
        return sd, active
    acts = jnp.take(active, topo.scat_send_master, axis=0)         # [k, x]
    rec_a = jax.lax.all_to_all(acts, axes, split_axis=0, concat_axis=0,
                               tiled=True)
    act = active.at[slots].set(rec_a.reshape(-1), mode="drop")
    return sd, act


def flush_combiners(topo: ShardTopology, combined: jnp.ndarray, axes,
                    monoid: Monoid) -> jnp.ndarray:
    """Exchange 2 (combiner → master): ONE ⊕-reduced value per agent.

    Returns a [num_slots, *D] array of remote contributions folded into
    local master slots (identity elsewhere).
    """
    vals = jnp.take(combined, topo.comb_send_slot, axis=0)          # [k, x, *D]
    rec = jax.lax.all_to_all(vals, axes, split_axis=0, concat_axis=0,
                             tiled=True)
    flat = rec.reshape((-1,) + rec.shape[2:])
    return segment_combine(flat.astype(combined.dtype),
                           topo.comb_recv_master.reshape(-1),
                           topo.part.num_slots, monoid)


@runtime_checkable
class ExchangeBackend(Protocol):
    """The seam between the canonical superstep and the network.

    `refresh` runs before the local scatter-combine (push master scatter
    state to remote readers); `reduce` produces the fully ⊕-combined
    [num_slots, *payload] array the apply phase folds (identity outside
    master slots).
    """

    def refresh(self, state: "EngineState") -> "EngineState": ...

    def reduce(self, engine: "GREEngine", part: "DevicePartition",
               state: "EngineState") -> jnp.ndarray: ...


class NullExchange:
    """Single shard: all destinations are local; refresh is the identity."""

    def refresh(self, state):
        return state

    def reduce(self, engine, part, state):
        return engine.scatter_combine(part, state)


NULL_EXCHANGE = NullExchange()


class _RefreshingExchange:
    """Shared base for backends that refresh scatter agents before the
    local phase (the first half of the Agent-Graph protocol)."""

    def __init__(self, topo: ShardTopology, axes, monoid: Monoid,
                 dense_frontier: bool = False):
        self.topo = topo
        self.axes = axes
        self.monoid = monoid
        self.dense_frontier = dense_frontier

    def refresh(self, state):
        from repro.core.engine import EngineState
        sd, act = refresh_scatter_agents(self.topo, state.scatter_data,
                                         state.active_scatter, self.axes,
                                         dense=self.dense_frontier)
        return EngineState(state.vertex_data, sd, act, state.step)


class AgentExchange(_RefreshingExchange):
    """Agent-Graph exchange (paper §5): scatter refresh + combiner flush."""

    def __init__(self, topo: ShardTopology, axes, monoid: Monoid,
                 dense_frontier: bool = False, overlap: bool = False):
        super().__init__(topo, axes, monoid, dense_frontier)
        self.overlap = overlap

    def reduce(self, engine, part, state):
        monoid = self.monoid
        if self.overlap:
            # remote-destined edges first; their flush overlaps local compute
            sink = part.num_slots - 1
            is_remote = part.dst >= part.num_masters  # agents live high
            remote_part = dataclasses.replace(
                part, dst=jnp.where(is_remote, part.dst, sink),
                edges_sorted_by_dst=False)
            local_part = dataclasses.replace(
                part, dst=jnp.where(is_remote, sink, part.dst),
                edges_sorted_by_dst=False)
            combined_remote = engine.scatter_combine(remote_part, state)
            flushed = flush_combiners(self.topo, combined_remote, self.axes,
                                      monoid)
            combined_local = engine.scatter_combine(local_part, state)
            return monoid.op(combined_local, flushed)
        combined = engine.scatter_combine(part, state)
        flushed = flush_combiners(self.topo, combined, self.axes, monoid)
        # master slots take direct local + flushed remote contributions
        local = jnp.where(_master_mask(combined, part.num_masters),
                          combined, monoid.identity)
        return monoid.op(local, flushed)


class DenseExchange(_RefreshingExchange):
    """Pregel-style baseline: collective ⊕ over the full relabeled vector.

    Strictly more traffic than AgentExchange (every device reduces the whole
    [k·cap, *payload] vector); kept as the communication baseline for
    benchmarks and rooflines.
    """

    def __init__(self, topo: ShardTopology, axes, monoid: Monoid,
                 my_row: jnp.ndarray, dense_frontier: bool = False):
        super().__init__(topo, axes, monoid, dense_frontier)
        self.my_row = my_row

    def reduce(self, engine, part, state):
        monoid = self.monoid
        topo = self.topo
        k = jax.lax.psum(1, self.axes)
        cap = part.num_masters
        combined_loc = engine.scatter_combine(part, state)  # [slots, *D]
        payload = combined_loc.shape[1:]
        dtype = combined_loc.dtype
        # project local master slots back to the global vector [k*cap, *D]
        myslice = self.my_row * cap
        global_vec = jnp.full((k * cap,) + payload, monoid.identity, dtype)
        global_vec = global_vec.at[myslice + jnp.arange(cap)].set(
            combined_loc[:cap])
        # combiner slots scatter their partial ⊕ at their global master id
        comb_vals = jnp.take(combined_loc, topo.comb_send_slot, axis=0,
                             fill_value=monoid.identity)  # [k, x, *D]
        recv = jax.lax.all_to_all(topo.comb_recv_master, self.axes, 0, 0,
                                  tiled=True)
        tgt = jnp.arange(k)[:, None] * cap + recv
        tgt = jnp.where(recv >= cap, k * cap, tgt)  # drop padding to sink
        global_vec = segment_combine(
            jnp.concatenate([global_vec,
                             comb_vals.reshape((-1,) + payload)]),
            jnp.concatenate([jnp.arange(k * cap), tgt.reshape(-1)]),
            k * cap + 1, monoid)[:k * cap]
        if monoid.name == "sum":
            total = jax.lax.psum(global_vec, self.axes)
        elif monoid.name == "min":
            total = jax.lax.pmin(global_vec, self.axes)
        else:
            total = jax.lax.pmax(global_vec, self.axes)
        mine = jax.lax.dynamic_slice_in_dim(total, myslice, cap, axis=0)
        return jnp.full((part.num_slots,) + payload, monoid.identity,
                        dtype).at[:cap].set(mine)
