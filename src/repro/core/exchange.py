"""ExchangeBackend: the pluggable communication substrate of the engine.

The GRE computation model (paper §4, Alg. 2) is one canonical superstep —
refresh scatter state, fused scatter-combine, apply — independent of HOW
partial combines cross device boundaries.  This module isolates that seam:

  NullExchange   — single shard: every destination is local, nothing moves.
  AgentExchange  — the paper's Agent-Graph (§5): masters push ONE message per
                   (master, peer) to scatter agents before the local phase;
                   combiners push ONE ⊕-reduced message per agent to their
                   master after it.  |V_s| + |V_c| messages per superstep.
                   `overlap=True` issues the flush for remote-destined edges
                   before local-destined edges compute (§6.2's communication/
                   computation overlap, as an XLA scheduling hint).
  DenseExchange  — hash-partition/Pregel baseline: ⊕-reduce the full
                   relabeled vertex vector with a collective (psum/pmin/pmax).
  PipelinedAgentExchange — the Agent-Graph protocol restructured for
                   communication/computation overlap (paper §6.2): edges are
                   split ONCE at ingress into remote-destined and
                   local-destined tiles (`agent_graph.split_edge_tiles`);
                   each superstep ⊕-combines the remote tile first, issues
                   the flush collective, then combines the local tile while
                   the collective is in flight.  The two partial combines
                   ride a two-slot `Mailbox` so the merge can be deferred to
                   the top of the NEXT superstep (the plan executor,
                   `repro.core.plan.execute_plan`).
  AsyncAgentExchange — bounded-staleness execution for MONOTONE programs
                   (`VertexProgram.monotone`: halting ⊕ = min/max): the
                   Mailbox generalizes to a k-deep ring of remote-tile
                   partials, the scatter refresh and combiner flush
                   collectives run once per k supersteps instead of every
                   superstep, and local updates keep applying eagerly in
                   between — each shard runs up to `staleness_bound = k`
                   supersteps ahead on stale remote state.  The fixed
                   point matches the synchronous schedule exactly
                   (delayed delivery of a valid min/max bound only
                   re-tightens later); the trajectory does not, which is
                   why non-monotone (sum) programs must refuse this
                   backend.

All backends speak first-class feature-vector payloads: state and message
arrays are `[slots, *payload_shape]`; scalars are the `payload_shape=()`
special case.  Backends are plain callables on jnp arrays, usable inside
`shard_map` (Agent/Dense/Pipelined) or outside any mesh (Null).

A doctest for the master-slot mask helper (masters are renumbered first,
agents live high — paper §6.1.1):

    >>> import jax.numpy as jnp
    >>> bool(_master_mask(jnp.zeros((4, 2)), 2)[2, 0])
    False
    >>> [bool(b) for b in _master_mask(jnp.zeros(3), 2)]
    [True, True, False]
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.vertex_program import Monoid, segment_combine

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids a cycle
    from repro.core.engine import DevicePartition, EngineState, GREEngine


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PipelineTiles:
    """Device-local remote/local edge tiles for PipelinedAgentExchange.

    Built at ingress from `agent_graph.split_edge_tiles`: `part_remote`
    carries the combiner-destined edges with dst relabeled into the compact
    combiner space `[0, num_combiners]`, `part_local` the master-destined
    edges (`[0, num_masters]`); index `num_combiners`/`num_masters` is the
    padding identity slot of each tile.  The exchange indices are the same
    per-peer layout as `ShardTopology`'s, remapped into those compact
    spaces.
    """

    part_remote: "DevicePartition"   # combiner-destined edge tile
    part_local: "DevicePartition"    # master-destined edge tile
    comb_send_compact: jnp.ndarray   # [k, x_pad] into the remote ⊕ array
    comb_recv_master: jnp.ndarray    # [k, x_pad] master slot; fill = cap
    num_combiners: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Mailbox:
    """Two-slot superstep buffer carried through the pipelined loop.

    Slot `flushed` holds the in-flight remote contributions (the flush
    collective's landing buffer); slot `local` holds the local-tile partial
    ⊕.  `PipelinedAgentExchange.merge` folds the two at the top of the next
    superstep — legal because ⊕ is commutative/associative, so remote and
    local partials can be combined in either order.
    """

    local: jnp.ndarray    # [num_masters + 1, *payload]
    flushed: jnp.ndarray  # [num_masters + 1, *payload]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AsyncRing:
    """k-deep generalization of `Mailbox` for bounded-staleness supersteps.

    `ring[i]` holds the remote-tile partial ⊕ (compact combiner space)
    produced at the superstep with `step % k == i`; at the window boundary
    (`step % k == k - 1`) all k entries ⊕-fold and flush in ONE collective,
    landing in `landed` for the next merge, and the ring resets to
    identity.  `local` is the eager local-tile partial (merged every
    superstep).  `dirty` records whether any master improved since the
    last scatter refresh — in-flight information the termination predicate
    must count: a shard is quiescent only when its frontier is empty AND
    every ring entry is identity AND no un-refreshed improvement is held
    (`AsyncAgentExchange.carry_pending`).
    """

    local: jnp.ndarray    # [num_masters + 1, *payload]
    landed: jnp.ndarray   # [num_masters + 1, *payload]
    ring: jnp.ndarray     # [k, num_combiners + 1, *payload]
    dirty: jnp.ndarray    # scalar bool: master improved since last refresh


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardTopology:
    """Device-local (inside shard_map) view of one AgentGraph partition."""

    part: "DevicePartition"        # local slots + edges
    comb_send_slot: jnp.ndarray    # [k, x_pad]
    comb_recv_master: jnp.ndarray  # [k, x_pad]
    scat_send_master: jnp.ndarray  # [k, x_pad]
    scat_recv_slot: jnp.ndarray    # [k, x_pad]
    tiles: Optional[PipelineTiles] = None  # pipelined-exchange edge split


def _master_mask(combined: jnp.ndarray, num_masters: int) -> jnp.ndarray:
    """[slots] -> broadcastable-to-payload bool mask of master slots."""
    m = jnp.arange(combined.shape[0]) < num_masters
    return m.reshape(m.shape + (1,) * (combined.ndim - 1))


def refresh_scatter_agents(topo: ShardTopology, scatter_data: jnp.ndarray,
                           active: jnp.ndarray, axes,
                           dense: bool = False):
    """Exchange 1 (master → scatter agent): ONE message per (master, peer).

    Works for scalar or feature-vector `scatter_data` ([slots] or
    [slots, *D]).  Returns refreshed (scatter_data, active).  With
    `dense=True` (iterative programs: every vertex active) the activity
    payload is skipped — half the exchange ops.
    """
    vals = jnp.take(scatter_data, topo.scat_send_master, axis=0)   # [k, x, *D]
    rec_v = jax.lax.all_to_all(vals, axes, split_axis=0, concat_axis=0,
                               tiled=True)
    slots = topo.scat_recv_slot.reshape(-1)
    flat_v = rec_v.reshape((-1,) + rec_v.shape[2:])
    sd = scatter_data.at[slots].set(flat_v.astype(scatter_data.dtype),
                                    mode="drop")
    if dense:
        return sd, active
    acts = jnp.take(active, topo.scat_send_master, axis=0)         # [k, x]
    rec_a = jax.lax.all_to_all(acts, axes, split_axis=0, concat_axis=0,
                               tiled=True)
    act = active.at[slots].set(rec_a.reshape(-1), mode="drop")
    return sd, act


def flush_combiners(topo: ShardTopology, combined: jnp.ndarray, axes,
                    monoid: Monoid, send_slot: Optional[jnp.ndarray] = None,
                    recv_master: Optional[jnp.ndarray] = None,
                    num_segments: Optional[int] = None) -> jnp.ndarray:
    """Exchange 2 (combiner → master): ONE ⊕-reduced value per agent.

    Returns a [num_segments, *D] array of remote contributions folded into
    local master slots (identity elsewhere).  By default `combined` is the
    full slot space and the topology's exchange indices apply; the
    pipelined backend passes its compact-space indices and the
    `[num_masters + 1]` segment count instead (`PipelineTiles`).
    """
    send = topo.comb_send_slot if send_slot is None else send_slot
    recv = topo.comb_recv_master if recv_master is None else recv_master
    vals = jnp.take(combined, send, axis=0)                         # [k, x, *D]
    rec = jax.lax.all_to_all(vals, axes, split_axis=0, concat_axis=0,
                             tiled=True)
    flat = rec.reshape((-1,) + rec.shape[2:])
    return segment_combine(flat.astype(combined.dtype), recv.reshape(-1),
                           num_segments or topo.part.num_slots, monoid)


@runtime_checkable
class ExchangeBackend(Protocol):
    """The seam between the canonical superstep and the network.

    `refresh` runs before the local scatter-combine (push master scatter
    state to remote readers); `reduce` produces the fully ⊕-combined
    array the apply phase folds — at least `[num_masters, *payload]` rows
    (apply reads only master slots; Null/Agent/Dense return the full
    `[num_slots]` slot space, the pipelined backend the compact
    `[num_masters + 1]` master space).

    Every backend additionally speaks the PHASE protocol the plan executor
    drives (`repro.core.plan.execute_plan`): `local_phase` produces a
    per-superstep carry (receiving the PREVIOUS carry, which only the
    async shape reads — its ring persists across supersteps), `merge`
    folds it into the combined array apply consumes, `carry_init` builds
    the carry's identity-valued shape placeholder for the loop seed, and
    `carry_pending` reports whether the carry still holds in-flight
    contributions the termination predicate must wait for (identity-False
    for sync/pipelined: their carries are fully consumed by the very next
    merge).  `phases` names the shape ("sync": the carry IS the reduce
    output and merge is the identity; "pipelined": the carry is a two-slot
    `Mailbox` whose flush collective overlaps the next local combine;
    "async": the carry is a k-deep `AsyncRing` flushed once per k
    supersteps).
    """

    phases: str

    def refresh(self, state: "EngineState") -> "EngineState": ...

    def reduce(self, engine: "GREEngine", part: "DevicePartition",
               state: "EngineState") -> jnp.ndarray: ...

    def local_phase(self, engine: "GREEngine", part: "DevicePartition",
                    state: "EngineState", carry=None): ...

    def merge(self, carry) -> jnp.ndarray: ...

    def carry_init(self, engine: "GREEngine", part: "DevicePartition"): ...

    def carry_pending(self, carry) -> jnp.ndarray: ...


class _SyncPhase:
    """Default sync phase shape: the whole ⊕-reduce is the local phase and
    the merge is the identity, so the plan executor's deferred-merge loop
    degenerates op-for-op to the classic refresh → reduce → apply
    superstep."""

    phases = "sync"

    def local_phase(self, engine, part, state, carry=None):
        return self.reduce(engine, part, state)

    def merge(self, carry):
        return carry

    def carry_init(self, engine, part):
        p = engine.program
        return jnp.full((part.num_slots,) + tuple(p.payload_shape),
                        p.monoid.identity, p.msg_dtype)

    def carry_pending(self, carry):
        # sync/pipelined carries are fully consumed by the next merge:
        # nothing in them can outlive the frontier-emptiness check
        return jnp.zeros((), dtype=bool)


class NullExchange(_SyncPhase):
    """Single shard: all destinations are local; refresh is the identity."""

    def refresh(self, state):
        return state

    def reduce(self, engine, part, state):
        return engine.scatter_combine(part, state)


NULL_EXCHANGE = NullExchange()


class _RefreshingExchange(_SyncPhase):
    """Shared base for backends that refresh scatter agents before the
    local phase (the first half of the Agent-Graph protocol)."""

    def __init__(self, topo: ShardTopology, axes, monoid: Monoid,
                 dense_frontier: bool = False):
        self.topo = topo
        self.axes = axes
        self.monoid = monoid
        self.dense_frontier = dense_frontier

    def refresh(self, state):
        from repro.core.engine import EngineState
        sd, act = refresh_scatter_agents(self.topo, state.scatter_data,
                                         state.active_scatter, self.axes,
                                         dense=self.dense_frontier)
        return EngineState(state.vertex_data, sd, act, state.step,
                           state.lane_active)


class AgentExchange(_RefreshingExchange):
    """Agent-Graph exchange (paper §5): scatter refresh + combiner flush."""

    def __init__(self, topo: ShardTopology, axes, monoid: Monoid,
                 dense_frontier: bool = False, overlap: bool = False):
        super().__init__(topo, axes, monoid, dense_frontier)
        self.overlap = overlap

    def reduce(self, engine, part, state):
        monoid = self.monoid
        if self.overlap:
            # remote-destined edges first; their flush overlaps local compute
            sink = part.num_slots - 1
            is_remote = part.dst >= part.num_masters  # agents live high
            remote_part = dataclasses.replace(
                part, dst=jnp.where(is_remote, part.dst, sink),
                edges_sorted_by_dst=False)
            local_part = dataclasses.replace(
                part, dst=jnp.where(is_remote, sink, part.dst),
                edges_sorted_by_dst=False)
            combined_remote = engine.scatter_combine(remote_part, state)
            flushed = flush_combiners(self.topo, combined_remote, self.axes,
                                      monoid)
            combined_local = engine.scatter_combine(local_part, state)
            return monoid.op(combined_local, flushed)
        combined = engine.scatter_combine(part, state)
        flushed = flush_combiners(self.topo, combined, self.axes, monoid)
        # master slots take direct local + flushed remote contributions
        local = jnp.where(_master_mask(combined, part.num_masters),
                          combined, monoid.identity)
        return monoid.op(local, flushed)


class DenseExchange(_RefreshingExchange):
    """Pregel-style baseline: collective ⊕ over the full relabeled vector.

    Strictly more traffic than AgentExchange (every device reduces the whole
    [k·cap, *payload] vector); kept as the communication baseline for
    benchmarks and rooflines.
    """

    def __init__(self, topo: ShardTopology, axes, monoid: Monoid,
                 my_row: jnp.ndarray, dense_frontier: bool = False):
        super().__init__(topo, axes, monoid, dense_frontier)
        self.my_row = my_row

    def reduce(self, engine, part, state):
        monoid = self.monoid
        topo = self.topo
        k = jax.lax.psum(1, self.axes)
        cap = part.num_masters
        combined_loc = engine.scatter_combine(part, state)  # [slots, *D]
        payload = combined_loc.shape[1:]
        dtype = combined_loc.dtype
        # project local master slots back to the global vector [k*cap, *D]
        myslice = self.my_row * cap
        global_vec = jnp.full((k * cap,) + payload, monoid.identity, dtype)
        global_vec = global_vec.at[myslice + jnp.arange(cap)].set(
            combined_loc[:cap])
        # combiner slots scatter their partial ⊕ at their global master id
        comb_vals = jnp.take(combined_loc, topo.comb_send_slot, axis=0,
                             fill_value=monoid.identity)  # [k, x, *D]
        recv = jax.lax.all_to_all(topo.comb_recv_master, self.axes, 0, 0,
                                  tiled=True)
        tgt = jnp.arange(k)[:, None] * cap + recv
        tgt = jnp.where(recv >= cap, k * cap, tgt)  # drop padding to sink
        global_vec = segment_combine(
            jnp.concatenate([global_vec,
                             comb_vals.reshape((-1,) + payload)]),
            jnp.concatenate([jnp.arange(k * cap), tgt.reshape(-1)]),
            k * cap + 1, monoid)[:k * cap]
        if monoid.name == "sum":
            total = jax.lax.psum(global_vec, self.axes)
        elif monoid.name == "min":
            total = jax.lax.pmin(global_vec, self.axes)
        else:
            total = jax.lax.pmax(global_vec, self.axes)
        mine = jax.lax.dynamic_slice_in_dim(total, myslice, cap, axis=0)
        return jnp.full((part.num_slots,) + payload, monoid.identity,
                        dtype).at[:cap].set(mine)


class PipelinedAgentExchange(_RefreshingExchange):
    """Double-buffered Agent-Graph exchange (paper §6.2 overlap, pipelined).

    Protocol per superstep, over the static ingress-time edge split
    (`ShardTopology.tiles`):

      local_phase  — ⊕-combine the remote-destined tile into the compact
                     combiner space, ISSUE the flush collective, then
                     ⊕-combine the local-destined tile while the collective
                     is in flight; both partials return in a `Mailbox`.
      merge        — fold `Mailbox.local ⊕ Mailbox.flushed` into the master
                     contributions; deferred to the top of the next
                     superstep by the plan executor
                     (`repro.core.plan.execute_plan`), which carries the
                     mailbox through the loop.

    Compared to `AgentExchange(overlap=True)` — which rewrites `dst` to
    split the SAME edge array twice, scanning 2·E edges per superstep —
    the tiles scan each edge exactly once and ⊕-reduce into
    `[num_masters + 1]` / `[num_combiners + 1]` segment spaces instead of
    the full `[num_slots]` slot space.  Results are bitwise-identical to
    the synchronous `AgentExchange` for min/max monoids (the tiles preserve
    the canonical per-segment reduction order; sums agree to the same order
    too, but cross-backend float guarantees stay at tolerance).

    `reduce` merges immediately, so the backend also drops into the
    standard synchronous superstep (used by the equivalence tests to
    isolate the loop restructure from the edge split).
    """

    phases = "pipelined"

    def __init__(self, topo: ShardTopology, axes, monoid: Monoid,
                 dense_frontier: bool = False):
        super().__init__(topo, axes, monoid, dense_frontier)
        assert topo.tiles is not None, \
            "PipelinedAgentExchange needs ShardTopology.tiles " \
            "(agent_graph.split_edge_tiles)"
        self.tiles = topo.tiles

    def local_phase(self, engine: "GREEngine", part: "DevicePartition",
                    state: "EngineState", carry=None) -> Mailbox:
        """Remote-tile combine + flush issue, then local-tile combine.

        The flush is `flush_combiners` with the compact-space indices: the
        send gather reads the compact combiner ⊕ array and the receive
        folds into `[num_masters + 1]` (identity slot last) — same wire
        traffic, ONE ⊕-reduced message per combiner agent.  Edge scans run
        on the split tiles only; `part` (the canonical partition, which
        carries no edge columns under this backend) is unused.
        """
        t = self.tiles
        masters = self.topo.part.num_masters
        remote = engine.scatter_combine(t.part_remote, state,
                                        num_segments=t.num_combiners + 1)
        flushed = flush_combiners(self.topo, remote, self.axes, self.monoid,
                                  send_slot=t.comb_send_compact,
                                  recv_master=t.comb_recv_master,
                                  num_segments=masters + 1)
        local = engine.scatter_combine(t.part_local, state,
                                       num_segments=masters + 1)
        return Mailbox(local=local, flushed=flushed)

    def merge(self, mailbox: Mailbox) -> jnp.ndarray:
        """⊕ the two mailbox slots: [num_masters + 1, *payload]."""
        return self.monoid.op(mailbox.local, mailbox.flushed)

    def carry_init(self, engine, part):
        p = engine.program
        idm = jnp.full((part.num_masters + 1,) + tuple(p.payload_shape),
                       p.monoid.identity, p.msg_dtype)
        return Mailbox(local=idm, flushed=idm)

    def reduce(self, engine, part, state):
        return self.merge(self.local_phase(engine, part, state))


class AsyncAgentExchange(_RefreshingExchange):
    """Bounded-staleness Agent-Graph exchange: collectives once per k steps.

    Valid ONLY for monotone programs (`VertexProgram.monotone`: halting
    ⊕ = min/max) — every message is a valid bound computed by the same ops
    the synchronous schedule would run, so delaying its delivery changes
    the trajectory but not the unique fixed point.  The engine refuses to
    construct this backend for sum-monoid programs (a partial folded
    against a stale accumulator is double-counted, not re-tightened).

    Protocol per superstep, over the same static ingress edge split as
    the pipelined backend (`ShardTopology.tiles`), with
    `staleness_bound = k`:

      refresh      — the scatter-agent refresh collective runs only at
                     `step % k == 0`; in between, shards scatter from the
                     STALE agent copies.  Because a master's activity flag
                     clears one superstep after it improves, the refresh
                     re-derives agent activity from VALUE CHANGE (received
                     copy != held copy): any improvement since the last
                     refresh — whenever it happened inside the window —
                     scatters exactly once after landing.
      local_phase  — the remote-tile partial is ⊕-combined EVERY superstep
                     into ring slot `step % k`; at the window boundary
                     (`step % k == k - 1`) the k ring entries ⊕-fold and
                     flush in ONE collective (1/k of the pipelined
                     backend's flush traffic), landing for the next merge;
                     the local-tile partial is computed every superstep
                     and merged eagerly — intra-shard propagation runs at
                     full speed, only shard crossings wait (≤ k - 1
                     supersteps in the ring + ≤ k - 1 until the next
                     refresh).
      merge        — `local ⊕ landed`, every superstep (landed is identity
                     except just after a boundary flush).

    Both `step % k` predicates are mesh-uniform (superstep counters
    advance in lockstep inside `plan.execute_plan`'s while-loop), so the
    collectives under their `lax.cond`s stay matched across shards — the
    same discipline as the executor's own continuation cond.

    Termination counts the in-flight state (`carry_pending`): a shard is
    quiescent only when its frontier is empty AND all k ring entries are
    identity AND no master improved since the last refresh (`dirty`) —
    without the last term an improvement whose only cross-shard readers
    are scatter agents on OTHER shards could be stranded between
    refreshes.  `k = 1` degenerates to the pipelined cadence with an
    eager local merge.
    """

    phases = "async"

    def __init__(self, topo: ShardTopology, axes, monoid: Monoid,
                 dense_frontier: bool = False, staleness: int = 2):
        super().__init__(topo, axes, monoid, dense_frontier)
        assert topo.tiles is not None, \
            "AsyncAgentExchange needs ShardTopology.tiles " \
            "(agent_graph.split_edge_tiles)"
        assert staleness >= 1, staleness
        self.tiles = topo.tiles
        self.staleness = staleness

    def refresh(self, state):
        from repro.core.engine import EngineState

        def do(s):
            old_sd = s.scatter_data
            sd, act = refresh_scatter_agents(self.topo, s.scatter_data,
                                             s.active_scatter, self.axes,
                                             dense=self.dense_frontier)
            if not self.dense_frontier:
                # value-change activation: masters that improved mid-window
                # have long-cleared activity flags, but the agents still
                # hold the previous refresh's copy, so != finds them.  Only
                # agent slots can differ (refresh writes nothing else).
                changed = sd != old_sd
                if changed.ndim > 1:
                    changed = jnp.any(
                        changed, axis=tuple(range(1, changed.ndim)))
                act = act | changed
            return EngineState(s.vertex_data, sd, act, s.step,
                               s.lane_active)

        return jax.lax.cond(state.step % self.staleness == 0,
                            do, lambda s: s, state)

    def local_phase(self, engine: "GREEngine", part: "DevicePartition",
                    state: "EngineState", carry=None) -> AsyncRing:
        assert carry is not None, \
            "async local_phase needs the prior AsyncRing carry " \
            "(driven by plan.execute_plan; the serving tick refuses async)"
        t = self.tiles
        k = self.staleness
        masters = self.topo.part.num_masters
        remote = engine.scatter_combine(t.part_remote, state,
                                        num_segments=t.num_combiners + 1)
        slot = state.step % k
        ring = jax.lax.dynamic_update_index_in_dim(carry.ring, remote,
                                                   slot, axis=0)

        def flush(r):
            folded = r[0]
            for i in range(1, k):
                folded = self.monoid.op(folded, r[i])
            landed = flush_combiners(self.topo, folded, self.axes,
                                     self.monoid,
                                     send_slot=t.comb_send_compact,
                                     recv_master=t.comb_recv_master,
                                     num_segments=masters + 1)
            return landed, jnp.full_like(r, self.monoid.identity)

        def hold(r):
            idm = jnp.full((masters + 1,) + r.shape[2:],
                           self.monoid.identity, r.dtype)
            return idm, r

        landed, ring = jax.lax.cond(slot == k - 1, flush, hold, ring)
        local = engine.scatter_combine(t.part_local, state,
                                       num_segments=masters + 1)
        # improvements land on masters as activity the superstep after
        # they happen; at a refresh step everything so far was just pushed
        dirty = jnp.where(state.step % k == 0, False,
                          carry.dirty
                          | jnp.any(state.active_scatter[:masters]))
        return AsyncRing(local=local, landed=landed, ring=ring, dirty=dirty)

    def merge(self, carry: AsyncRing) -> jnp.ndarray:
        return self.monoid.op(carry.local, carry.landed)

    def carry_init(self, engine, part):
        p = engine.program
        masters = self.topo.part.num_masters
        payload = tuple(p.payload_shape)
        idm = jnp.full((masters + 1,) + payload, p.monoid.identity,
                       p.msg_dtype)
        ring = jnp.full((self.staleness, self.tiles.num_combiners + 1)
                        + payload, p.monoid.identity, p.msg_dtype)
        return AsyncRing(local=idm, landed=idm, ring=ring,
                         dirty=jnp.zeros((), dtype=bool))

    def carry_pending(self, carry: AsyncRing) -> jnp.ndarray:
        return jnp.any(carry.ring != self.monoid.identity) | carry.dirty

    def reduce(self, engine, part, state):
        raise NotImplementedError(
            "AsyncAgentExchange has no single-superstep reduce: partials "
            "live in the k-deep ring across supersteps.  Use the plan "
            "executor (DistGREEngine.make_run); the serving tick refuses "
            "exchange='async'.")
