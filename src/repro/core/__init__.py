# GRE's primary contributions: the Scatter-Combine computation model and the
# Agent-Graph distributed data model, plus the BSP engine that executes them.
from repro.core.vertex_program import VertexProgram, Monoid, MONOIDS, segment_combine
from repro.core.engine import GREEngine, EngineState, DevicePartition
from repro.core.plan import (FrontierPlan, KernelPlan, SuperstepPlan,
                             execute_plan)
from repro.core.agent_graph import AgentGraph, build_agent_graph
from repro.core.partition import greedy_partition, hash_partition, partition_quality
from repro.core import algorithms
