"""Agent-Graph construction (paper §5.1).

Given an edge partition P(e) and master placement owner(v), extends the
directed graph with agent vertices:

  combiner  v_c — lives on a partition holding in-edges of a remote master v;
                  local messages ⊕-accumulate on v_c, then ONE message
                  (v_c → v) crosses the network per superstep;
  scatter   v_s — lives on a partition holding out-edges of a remote master;
                  the master sends ONE message (v → v_s) per superstep and
                  v_s fans out locally.

Local slot layout per partition (paper §6.1.1 renumbering, masters first then
agents, plus one padding sink for XLA static shapes):

  [0, cap)                       masters (global ids relabeled contiguous)
  [cap, cap+S_pad)               scatter agents
  [cap+S_pad, cap+S_pad+C_pad)   combiners
  cap+S_pad+C_pad                sink (padding target, never read)

All per-partition arrays are stacked along a leading axis of size k so the
distributed engine can hand row i to device i under `shard_map`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.partition import (accumulate_owner_counts, assign_owners,
                                  owners_from_counts, rebalance_owners)
from repro.graph.structures import (DeltaReport, Graph, csr_layout,
                                    degree_buckets, removal_selector,
                                    validate_edge_delta)


@dataclasses.dataclass
class AgentGraph:
    """Host-side stacked representation of k agent-graph partitions."""

    k: int
    num_vertices: int          # original |V|
    cap: int                   # masters per partition (padded)
    s_pad: int                 # scatter-agent slots per partition
    c_pad: int                 # combiner slots per partition
    e_pad: int                 # edge slots per partition
    s_x_pad: int               # scatter-exchange slots per (i, j) peer pair
    c_x_pad: int               # combine-exchange slots per (i, j) peer pair

    # topology, stacked [k, ...]
    src: np.ndarray            # [k, e_pad] local src slot
    dst: np.ndarray            # [k, e_pad] local dst slot
    edge_mask: np.ndarray      # [k, e_pad]
    edge_props: Dict[str, np.ndarray]
    out_degree: np.ndarray     # [k, cap] GLOBAL out-degree of each master

    # vertex id bookkeeping
    old2new: np.ndarray        # [V] -> global relabeled id (owner-contiguous)
    new2old: np.ndarray        # [k*cap] -> original id or -1 (padding master)

    # exchange metadata
    comb_send_slot: np.ndarray    # [k, k, x_pad] on i: row j = combiner slots -> j
    comb_recv_master: np.ndarray  # [k, k, x_pad] on j: row i = master slot for payload from i
    scat_send_master: np.ndarray  # [k, k, x_pad] on j: row i = master slots to push to i
    scat_recv_slot: np.ndarray    # [k, k, x_pad] on i: row j = scatter-agent slot for payload from j

    num_scatter: np.ndarray    # [k] real scatter-agent counts
    num_combiner: np.ndarray   # [k] real combiner counts
    num_edges: np.ndarray      # [k] real edge counts

    # src-sorted CSR secondary index per partition (frontier compaction);
    # masters AND scatter agents have out-edge ranges.
    csr_indptr: np.ndarray     # [k, num_slots + 1]
    csr_eidx: np.ndarray       # [k, e_pad] positions in the dst-sorted cols
    csr_max_deg: int = 0       # max local out-degree over all partitions

    # Degree-bucket binning per partition (graph.structures.degree_buckets,
    # keyed by LOCAL out-degree).  sizes/max_deg are the per-bucket maxima
    # ACROSS partitions: shard_map traces one program for every shard, so
    # the static tile shapes must be mesh-uniform.
    bucket_id: np.ndarray = None      # [k, num_slots] int32, -1 = deg 0
    bucket_sizes: tuple = ()
    bucket_max_deg: tuple = ()

    # Name of the partitioner that produced `edge_part` ("" when the
    # caller handed in a raw placement array).  Folded into the tuned-plan
    # cache fingerprint (repro.tuning.fingerprint) so plans measured on
    # one placement never answer for another.
    partitioner: str = ""

    @property
    def num_slots(self) -> int:
        return self.cap + self.s_pad + self.c_pad + 1

    @property
    def sink(self) -> int:
        return self.cap + self.s_pad + self.c_pad


def _pad_to(arr: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full(n, fill, dtype=arr.dtype if arr.size else np.int64)
    out[:arr.shape[0]] = arr
    return out


def _merge_bucket_stats(acc: tuple, stats: tuple) -> tuple:
    """Elementwise max of per-bucket stats across partitions: shard_map
    traces ONE program for all shards, so static bucket shapes (sizes used
    for caps, tile max degrees) must be mesh-uniform."""
    if not acc:
        return tuple(stats)
    return tuple(max(a, s) for a, s in zip(acc, stats))


@dataclasses.dataclass
class EdgeTile:
    """One destination-class edge tile, stacked [k, width] (host-side)."""

    src: np.ndarray
    dst: np.ndarray                # compact destination index (see split)
    mask: np.ndarray
    props: Dict[str, np.ndarray]
    csr_indptr: np.ndarray         # [k, num_slots + 1]
    csr_eidx: np.ndarray           # [k, width]
    csr_max_deg: int
    # Per-tile degree buckets: a slot's TILE-LOCAL out-degree (its edges
    # that landed in this destination class) drives the binning, so the
    # bucketed frontier gather stays tight on each tile independently.
    bucket_id: np.ndarray = None   # [k, num_slots] int32
    bucket_sizes: tuple = ()       # per-bucket max across partitions
    bucket_max_deg: tuple = ()


@dataclasses.dataclass
class EdgeTileSplit:
    """Static remote/local edge tiles for the pipelined exchange.

    Each partition's edge shard is split ONCE at ingress by destination
    class: `remote` holds the combiner-destined edges (their ⊕ partials
    are what the flush collective carries), `local` the master-destined
    ones.  The pipelined backend (`exchange.PipelinedAgentExchange`) scans
    the remote tile first and issues the flush while the local tile
    computes — total edge work stays E (the in-superstep `overlap=True`
    rewrite scans all E edges twice).

    Destination relabeling compacts the ⊕ segment spaces:

      remote tile  dst ∈ [0, c_pad]   — combiner slot minus `cap + s_pad`;
                                        padding lands on the identity slot
                                        `c_pad`;
      local  tile  dst ∈ [0, cap]     — the master slot unchanged; padding
                                        lands on the identity slot `cap`.

    Combiner indices inherit the owner-contiguous global order of
    `comb_ids`, so each tile's combiner range is CONTIGUOUS PER DESTINATION
    SHARD — the flush can take per-peer slices straight out of the remote
    ⊕ array.  Both tiles keep the canonical dst-sorted edge order (they are
    subsequences of it), preserving per-segment reduction order: min/max
    results are bitwise-identical to the unsplit scan, sums reduce in the
    same order.  Per-tile CSR position indices keep the frontier-compacted
    scatter (`core/frontier.py`) available on both tiles.
    """

    remote: EdgeTile               # [k, er_pad] combiner-destined edges
    local: EdgeTile                # [k, el_pad] master-destined edges
    remote_fraction: float         # real remote edges / real edges


def split_edge_tiles(ag: AgentGraph, pad_multiple: int = 8) -> EdgeTileSplit:
    """Split each partition's edges into remote/local destination tiles.

    Host-side (numpy) ingress pass; see `EdgeTileSplit` for the layout
    contract.  Every real edge lands in exactly one tile: destinations are
    either local masters (< cap) or combiners (>= cap + s_pad) — scatter
    agents never terminate edges.
    """
    k, cap, s_pad, c_pad = ag.k, ag.cap, ag.s_pad, ag.c_pad
    comb_base = cap + s_pad
    sels = []
    for i in range(k):
        d = ag.dst[i]
        real = ag.edge_mask[i]
        is_comb = real & (d >= comb_base) & (d < ag.sink)
        is_master = real & (d < cap)
        assert np.array_equal(is_comb | is_master, real), \
            "edge destinations must be masters or combiners"
        sels.append((np.flatnonzero(is_comb), np.flatnonzero(is_master)))

    er_pad = max(1, max(r.shape[0] for r, _ in sels))
    el_pad = max(1, max(l.shape[0] for _, l in sels))
    er_pad = -(-er_pad // pad_multiple) * pad_multiple
    el_pad = -(-el_pad // pad_multiple) * pad_multiple
    num_slots = ag.num_slots

    def tile(width: int, junk_dst: int) -> EdgeTile:
        return EdgeTile(
            src=np.full((k, width), ag.sink, dtype=np.int32),
            dst=np.full((k, width), junk_dst, dtype=np.int32),
            mask=np.zeros((k, width), dtype=bool),
            props={n: np.zeros((k, width), dtype=v.dtype)
                   for n, v in ag.edge_props.items()},
            csr_indptr=np.zeros((k, num_slots + 1), dtype=np.int32),
            csr_eidx=np.zeros((k, width), dtype=np.int32),
            csr_max_deg=0,
            bucket_id=np.full((k, num_slots), -1, dtype=np.int32),
        )

    remote, local = tile(er_pad, c_pad), tile(el_pad, cap)
    n_remote = n_real = 0
    for i, (rsel, lsel) in enumerate(sels):
        n_remote += rsel.shape[0]
        n_real += rsel.shape[0] + lsel.shape[0]
        for t, sel, shift in ((remote, rsel, comb_base), (local, lsel, 0)):
            n = sel.shape[0]
            t.src[i, :n] = ag.src[i, sel]
            t.dst[i, :n] = ag.dst[i, sel] - shift
            t.mask[i, :n] = True
            for name, v in ag.edge_props.items():
                t.props[name][i, :n] = v[i, sel]
            t.csr_indptr[i], t.csr_eidx[i], deg = csr_layout(
                t.src[i], t.mask[i], num_slots)
            t.csr_max_deg = max(t.csr_max_deg, deg)
            t.bucket_id[i], sizes, max_degs = degree_buckets(
                t.csr_indptr[i], num_slots)
            t.bucket_sizes = _merge_bucket_stats(t.bucket_sizes, sizes)
            t.bucket_max_deg = _merge_bucket_stats(t.bucket_max_deg,
                                                   max_degs)

    return EdgeTileSplit(remote=remote, local=local,
                         remote_fraction=n_remote / max(n_real, 1))


def slot_to_original(ag: AgentGraph) -> np.ndarray:
    """Recover, per partition, each local slot's ORIGINAL vertex id
    (`[k, num_slots]` int64; -1 for padding/sink slots).

    Masters come straight from `new2old`; agent slots are recovered from
    the positional exchange pairs — `scat_recv_slot[i, j, p]` (agent slot
    on i) is paired with `scat_send_master[j, i, p]` (master slot on j),
    and `comb_send_slot[i, j, p]` with `comb_recv_master[j, i, p]`.  This
    is the inverse the delta-ingress pass needs to match mutations
    (expressed in original ids) against a built AgentGraph's edges.
    """
    k, cap, sink = ag.k, ag.cap, ag.sink
    out = np.full((k, ag.num_slots), -1, dtype=np.int64)
    for i in range(k):
        out[i, :cap] = ag.new2old[i * cap:(i + 1) * cap]
        for j in range(k):
            slots = ag.scat_recv_slot[i, j]
            t = slots != sink
            g = j * cap + ag.scat_send_master[j, i][t]
            out[i, slots[t]] = ag.new2old[g]
            slots = ag.comb_send_slot[i, j]
            t = slots != sink
            g = j * cap + ag.comb_recv_master[j, i][t]
            out[i, slots[t]] = ag.new2old[g]
    return out


def apply_edge_delta(ag: AgentGraph, delta, pad_multiple: int = 8):
    """Delta ingress on a built AgentGraph (docs/incremental.md): retire and
    append edges WITHOUT repartitioning — master placement (`old2new`),
    `cap`, and every live slot's meaning are preserved, so a warm-started
    `EngineState` remains directly valid on the mutated topology.

    Fast path (slack-consuming, no shape change):

      * removals tombstone in place — `edge_mask` goes False and the edge
        is repointed at the sink;
      * adds land on `owner(dst)` (the destination is always a LOCAL
        master there, so the split-tile invariant "every real dst is a
        master or combiner" holds by construction), reusing an existing
        scatter agent for a remote src or allocating a fresh one from the
        `s_pad` slack (with its positional exchange pair appended);
      * each touched partition's live edges re-sort by destination slot
        and the CSR/bucket indices rebuild; static facets merge
        monotonically (elementwise max) so the shard_map trace survives.

    When any pad would overflow (`e_pad` edges, `s_pad` agents, `s_x_pad`
    exchange slots), the graph COMPACTS instead: rebuilt from the
    recovered edge set through `build_agent_graph` with the SAME owner
    vector — `old2new` is bit-identical (the relabeling is a
    deterministic lexsort of the unchanged owner assignment), only the
    pads regrow.  That is the one recompile point, flagged in the report.

    Returns ``(new_ag, DeltaReport)``; `ag` is not mutated.
    """
    V, k, cap, sink = ag.num_vertices, ag.k, ag.cap, ag.sink
    s2o = slot_to_original(ag)
    # ---- validate up front, against the ORIGINAL-id live edge set, with
    # the SAME rules as the single-shard path (structures.validate_edge_delta)
    # — a malformed batch fails identically on a mesh and on one device.
    live_keys = []
    for i in range(k):
        m = ag.edge_mask[i]
        live_keys.append(s2o[i][ag.src[i][m]] * np.int64(V)
                         + s2o[i][ag.dst[i][m]])
    validate_edge_delta(delta, V,
                        live_keys=(np.concatenate(live_keys) if live_keys
                                   else np.zeros(0, np.int64)))
    if delta.num_adds:
        for name in ag.edge_props:
            if name not in delta.add_props:
                raise KeyError(f"delta adds missing edge prop {name!r}")
    owner = (ag.old2new // cap).astype(np.int64)

    # ---- removals: match (src, dst) pairs in original-id space
    keep = ag.edge_mask.copy()
    removed_src, removed_dst = [], []
    for i in range(k):
        o_s = s2o[i][ag.src[i]]
        o_d = s2o[i][ag.dst[i]]
        # masked rows read -1 (negative key) and can never match
        rem = removal_selector(o_s, o_d, delta.rem_src, delta.rem_dst,
                               V) & ag.edge_mask[i]
        keep[i] = ag.edge_mask[i] & ~rem
        removed_src.append(o_s[rem])
        removed_dst.append(o_d[rem])
    removed_src = (np.concatenate(removed_src) if removed_src
                   else np.zeros(0, np.int64))
    removed_dst = (np.concatenate(removed_dst) if removed_dst
                   else np.zeros(0, np.int64))

    # ---- stage adds on owner(dst); allocate scatter agents as needed
    agent_of = []              # per partition: original id -> agent slot
    for i in range(k):
        agent_of.append({int(s2o[i, s]): s
                         for s in range(cap, cap + int(ag.num_scatter[i]))})
    scat_used = np.array([[int(np.sum(ag.scat_recv_slot[i, j] != sink))
                           for j in range(k)] for i in range(k)])
    num_scatter = ag.num_scatter.copy()
    scat_appends = []          # (i, j, agent_slot_on_i, master_loc_on_j, pos)
    add_rows = [[] for _ in range(k)]   # (s_loc, d_loc, delta_row)
    overflow = False
    for t in range(delta.num_adds):
        u, v = int(delta.add_src[t]), int(delta.add_dst[t])
        i = int(owner[v])
        d_loc = int(ag.old2new[v] - i * cap)
        j = int(owner[u])
        if j == i:
            s_loc = int(ag.old2new[u] - i * cap)
        else:
            s_loc = agent_of[i].get(u)
            if s_loc is None:
                if (int(num_scatter[i]) >= ag.s_pad
                        or scat_used[i, j] >= ag.s_x_pad):
                    overflow = True
                    break
                s_loc = cap + int(num_scatter[i])
                agent_of[i][u] = s_loc
                scat_appends.append((i, j, s_loc,
                                     int(ag.old2new[u] - j * cap),
                                     int(scat_used[i, j])))
                scat_used[i, j] += 1
                num_scatter[i] += 1
        add_rows[i].append((s_loc, d_loc, t))
    if not overflow:
        overflow = any(int(np.sum(keep[i])) + len(add_rows[i]) > ag.e_pad
                       for i in range(k))
    if overflow:
        return _rebuild_with_delta(ag, delta, pad_multiple)

    # ---- commit: tombstone + append + per-partition dst re-sort
    src = np.full_like(ag.src, sink)
    dst = np.full_like(ag.dst, sink)
    edge_mask = np.zeros_like(ag.edge_mask)
    eprops = {name: np.zeros_like(v) for name, v in ag.edge_props.items()}
    num_edges = np.zeros(k, dtype=np.int64)
    num_slots = ag.num_slots
    csr_indptr = np.zeros_like(ag.csr_indptr)
    csr_eidx = np.zeros_like(ag.csr_eidx)
    csr_max_deg = ag.csr_max_deg          # monotone: max with old statics
    bucket_id = np.full_like(ag.bucket_id, -1)
    bucket_sizes, bucket_max_deg = (), ()
    for i in range(k):
        ksel = np.flatnonzero(keep[i])
        rows = add_rows[i]
        s_all = np.concatenate([ag.src[i][ksel],
                                np.array([r[0] for r in rows], np.int32)])
        d_all = np.concatenate([ag.dst[i][ksel],
                                np.array([r[1] for r in rows], np.int32)])
        tsel = np.array([r[2] for r in rows], np.int64)
        props = {name: np.concatenate(
                     [v[i][ksel],
                      np.asarray(delta.add_props[name], v.dtype)[tsel]
                      if rows else v[i][:0]])
                 for name, v in ag.edge_props.items()}
        eorder = np.argsort(d_all, kind="stable")
        n_e = int(s_all.shape[0])
        num_edges[i] = n_e
        src[i, :n_e] = s_all[eorder]
        dst[i, :n_e] = d_all[eorder]
        edge_mask[i, :n_e] = True
        for name, v in props.items():
            eprops[name][i, :n_e] = v[eorder]
        csr_indptr[i], csr_eidx[i], deg = csr_layout(src[i], edge_mask[i],
                                                     num_slots)
        csr_max_deg = max(csr_max_deg, deg)
        bucket_id[i], sizes, max_degs = degree_buckets(csr_indptr[i],
                                                       num_slots)
        bucket_sizes = _merge_bucket_stats(bucket_sizes, sizes)
        bucket_max_deg = _merge_bucket_stats(bucket_max_deg, max_degs)
    bucket_sizes = _merge_bucket_stats(bucket_sizes, ag.bucket_sizes)
    bucket_max_deg = _merge_bucket_stats(bucket_max_deg, ag.bucket_max_deg)

    scat_recv = ag.scat_recv_slot.copy()
    scat_send = ag.scat_send_master.copy()
    for i, j, slot, master_loc, pos in scat_appends:
        scat_recv[i, j, pos] = slot
        scat_send[j, i, pos] = master_loc

    # global out-degree aux: adjust masters by the delta's degree change
    d_out = (np.bincount(delta.add_src, minlength=V)
             - np.bincount(removed_src, minlength=V)).astype(np.float32)
    out_degree = ag.out_degree.copy()
    for i in range(k):
        own_old = ag.new2old[i * cap:(i + 1) * cap]
        valid = own_old >= 0
        out_degree[i, valid] += d_out[own_old[valid]]

    new_ag = dataclasses.replace(
        ag, src=src, dst=dst, edge_mask=edge_mask, edge_props=eprops,
        out_degree=out_degree, scat_recv_slot=scat_recv,
        scat_send_master=scat_send, num_scatter=num_scatter,
        num_edges=num_edges, csr_indptr=csr_indptr, csr_eidx=csr_eidx,
        csr_max_deg=csr_max_deg, bucket_id=bucket_id,
        bucket_sizes=bucket_sizes, bucket_max_deg=bucket_max_deg)
    report = DeltaReport(added_src=delta.add_src.copy(),
                         added_dst=delta.add_dst.copy(),
                         removed_src=removed_src, removed_dst=removed_dst,
                         compacted=False)
    return new_ag, report


def _rebuild_with_delta(ag: AgentGraph, delta, pad_multiple: int):
    """Slack exhausted: recover the live edge set (original ids + their
    partition assignment), apply the delta at the COO level, and rebuild
    through `build_agent_graph` with the same owner vector — master
    placement and `old2new` are preserved; only agent/edge pads regrow."""
    V, k, cap = ag.num_vertices, ag.k, ag.cap
    s2o = slot_to_original(ag)
    srcs, dsts, parts = [], [], []
    props = {name: [] for name in ag.edge_props}
    removed_src, removed_dst = [], []
    for i in range(k):
        m = ag.edge_mask[i]
        o_s = s2o[i][ag.src[i]][m]
        o_d = s2o[i][ag.dst[i]][m]
        rem = removal_selector(o_s, o_d, delta.rem_src, delta.rem_dst, V)
        srcs.append(o_s[~rem])
        dsts.append(o_d[~rem])
        parts.append(np.full(int((~rem).sum()), i, np.int64))
        removed_src.append(o_s[rem])
        removed_dst.append(o_d[rem])
        for name, v in ag.edge_props.items():
            props[name].append(v[i][m][~rem])
    owner = (ag.old2new // cap).astype(np.int64)
    srcs.append(delta.add_src)
    dsts.append(delta.add_dst)
    parts.append(owner[delta.add_dst])
    for name in props:
        col = (np.asarray(delta.add_props[name],
                          ag.edge_props[name].dtype)
               if delta.num_adds else props[name][0][:0])
        props[name].append(col)
    graph = Graph(V, np.concatenate(srcs), np.concatenate(dsts),
                  {name: np.concatenate(v) for name, v in props.items()})
    new_ag = build_agent_graph(graph, np.concatenate(parts), k,
                               owner=owner, pad_multiple=pad_multiple,
                               partitioner=ag.partitioner)
    assert np.array_equal(new_ag.old2new, ag.old2new), \
        "compaction must preserve master placement"
    report = DeltaReport(added_src=delta.add_src.copy(),
                         added_dst=delta.add_dst.copy(),
                         removed_src=np.concatenate(removed_src),
                         removed_dst=np.concatenate(removed_dst),
                         compacted=True)
    return new_ag, report


def _bits_to_ids(row: np.ndarray) -> np.ndarray:
    """Set-bit positions of one packed uint64 bitset row, ascending."""
    return np.flatnonzero(np.unpackbits(row.view(np.uint8),
                                        bitorder="little"))


def build_agent_graph(graph, edge_part, k: int,
                      owner: Optional[np.ndarray] = None,
                      pad_multiple: int = 8,
                      transpose: bool = False,
                      chunk_size: Optional[int] = None,
                      partitioner: Optional[str] = None) -> AgentGraph:
    """Chunked two-pass Agent-Graph ingress.

    `graph` is either an in-memory `Graph` or any `EdgeChunkSource`
    (docs/partitioning.md): the build only ever touches the edge stream
    through restartable chunk iteration, so per-shard tiles are assembled
    WITHOUT a second full copy of the edge list — peak host state is the
    output tiles themselves plus one chunk plus O(V·k/8) packed
    bookkeeping bitsets (the same bound the streaming partitioners obey).
    An in-memory `Graph` with `chunk_size=None` streams as one
    whole-list chunk; any `chunk_size` produces a BITWISE-identical
    AgentGraph (tests/test_partition_stream.py), because both passes
    visit edges in stream order and the final per-partition dst sort is
    stable.

      pass A  per chunk: master-placement incidence counts (when `owner`
              is None), global out-degrees, per-partition edge counts,
              and packed (partition, vertex) src/dst touch bitsets — the
              bounded substitute for the monolithic path's per-partition
              `np.unique` over materialized relabeled endpoints;
      pass B  per chunk: translate endpoints to local slots and append to
              each partition's tile at its cursor (stream order), then
              stable-sort every tile by destination slot and build the
              CSR/bucket/exchange metadata.

    `edge_part` may be the usual per-edge placement array or a partitioner
    NAME (`repro.core.partition_stream.PARTITIONERS`); a name is
    dispatched through `partition_edges` and recorded on
    `AgentGraph.partitioner`, which the tuned-plan cache folds into its
    fingerprint so plans never leak across placements.

    `transpose=True` builds the agent graph of the REVERSED edge set
    (paper §4.2: backward traversal for multi-stage algorithms) while
    keeping the same edge partition and master placement (owners are
    assigned on the FORWARD graph), so forward and backward stages share
    vertex ownership and results relabel identically stage to stage."""
    from repro.core.partition_stream import bitset_set, partition_edges
    from repro.graph.structures import as_chunk_source

    if isinstance(edge_part, str):
        partitioner = edge_part
        edge_part = partition_edges(graph, k, method=partitioner)
    if hasattr(graph, "chunks"):
        source = graph
    else:
        source = graph.chunk_source(chunk_size or max(graph.num_edges, 1))
    V, E = source.num_vertices, source.num_edges
    edge_part = np.asarray(edge_part)
    if edge_part.shape[0] != E:
        raise ValueError(f"edge_part has {edge_part.shape[0]} entries "
                         f"for a {E}-edge stream")

    # ---- pass A: counts + touch bitsets -------------------------------
    need_owner = owner is None
    counts = np.zeros((k, V), dtype=np.int64) if need_owner else None
    glob_outdeg = np.zeros(V, dtype=np.int64)
    ne = np.zeros(k, dtype=np.int64)
    words = (V + 63) >> 6
    touch_src = np.zeros((k, words), dtype=np.uint64)
    touch_dst = np.zeros((k, words), dtype=np.uint64)
    for chunk in source.chunks():
        ep = edge_part[chunk.offset:chunk.offset + chunk.num_edges]
        fs, fd = chunk.src, chunk.dst
        s, d = (fd, fs) if transpose else (fs, fd)
        if need_owner:
            accumulate_owner_counts(counts, fs, fd, ep)
        glob_outdeg += np.bincount(s, minlength=V)
        ne += np.bincount(ep, minlength=k)
        bitset_set(touch_src, ep, s)
        bitset_set(touch_dst, ep, d)
    if need_owner:
        owner = owners_from_counts(counts)
        del counts

    cap = -(-V // k)
    cap = -(-cap // pad_multiple) * pad_multiple
    owner = rebalance_owners(owner, k, cap)

    # contiguous relabeling: partition i owns global ids [i*cap, i*cap+n_i)
    order = np.lexsort((np.arange(V), owner))
    old2new = np.empty(V, dtype=np.int64)
    new2old = np.full(k * cap, -1, dtype=np.int64)
    offs = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(np.bincount(owner, minlength=k), out=offs[1:])
    ranks = np.arange(V) - offs[owner[order]]
    old2new[order] = owner[order] * cap + ranks
    new2old[old2new] = np.arange(V)

    # remote-master agent id lists, from the touch bitsets: ascending
    # relabeled order (old2new of a set, sorted) == the monolithic
    # `np.unique(s_g[s_rem])`.
    per = []
    for i in range(k):
        us = _bits_to_ids(touch_src[i])
        vs = _bits_to_ids(touch_dst[i])
        scat_ids = np.sort(old2new[us[owner[us] != i]])  # scatter FROM
        comb_ids = np.sort(old2new[vs[owner[vs] != i]])  # combine FOR
        per.append(dict(scat_ids=scat_ids, comb_ids=comb_ids))

    s_pad = max(1, max(p["scat_ids"].shape[0] for p in per))
    c_pad = max(1, max(p["comb_ids"].shape[0] for p in per))
    e_pad = max(1, int(ne.max()))
    s_pad = -(-s_pad // pad_multiple) * pad_multiple
    c_pad = -(-c_pad // pad_multiple) * pad_multiple
    e_pad = -(-e_pad // pad_multiple) * pad_multiple
    sink = cap + s_pad + c_pad

    src = np.full((k, e_pad), sink, dtype=np.int32)
    dst = np.full((k, e_pad), sink, dtype=np.int32)
    edge_mask = np.zeros((k, e_pad), dtype=bool)
    eprops = {name: np.zeros((k, e_pad), dtype=dt)
              for name, dt in source.prop_dtypes.items()}
    out_degree = np.zeros((k, cap), dtype=np.float32)
    num_scatter = np.zeros(k, dtype=np.int64)
    num_combiner = np.zeros(k, dtype=np.int64)
    num_edges = ne.copy()

    # per-pair exchange lists
    comb_send = [[[] for _ in range(k)] for _ in range(k)]   # [i][j] combiner slots on i
    comb_recv = [[[] for _ in range(k)] for _ in range(k)]   # [j][i] master slots on j
    scat_send = [[[] for _ in range(k)] for _ in range(k)]   # [j][i] master slots on j
    scat_recv = [[[] for _ in range(k)] for _ in range(k)]   # [i][j] agent slots on i

    # ---- pass B: fill tiles at cursors in stream order ----------------
    cursor = np.zeros(k, dtype=np.int64)
    for chunk in source.chunks():
        ep = edge_part[chunk.offset:chunk.offset + chunk.num_edges]
        fs, fd = chunk.src, chunk.dst
        s, d = (fd, fs) if transpose else (fs, fd)
        s_g, d_g = old2new[s], old2new[d]
        s_own, d_own = owner[s], owner[d]
        for i in np.unique(ep):
            m = ep == i
            p = per[i]
            s_loc = np.where(
                s_own[m] != i,
                cap + np.searchsorted(p["scat_ids"], s_g[m]),
                s_g[m] - i * cap)
            d_loc = np.where(
                d_own[m] != i,
                cap + s_pad + np.searchsorted(p["comb_ids"], d_g[m]),
                d_g[m] - i * cap)
            lo = int(cursor[i])
            hi = lo + s_loc.shape[0]
            src[i, lo:hi] = s_loc
            dst[i, lo:hi] = d_loc
            for name in eprops:
                eprops[name][i, lo:hi] = chunk.props[name][m]
            cursor[i] = hi

    for i, p in enumerate(per):
        n_e = int(ne[i])
        num_scatter[i] = p["scat_ids"].shape[0]
        num_combiner[i] = p["comb_ids"].shape[0]
        # sort local edges by destination slot (combine key); the stream
        # order laid down in pass B is the monolithic selection order, so
        # the stable permutation — and every downstream array — matches
        # the single-pass build bit for bit.
        eorder = np.argsort(dst[i, :n_e], kind="stable")
        src[i, :n_e] = src[i, :n_e][eorder]
        dst[i, :n_e] = dst[i, :n_e][eorder]
        edge_mask[i, :n_e] = True
        for name in eprops:
            eprops[name][i, :n_e] = eprops[name][i, :n_e][eorder]
        # master aux: global out-degree
        own_old = new2old[i * cap:(i + 1) * cap]
        valid = own_old >= 0
        out_degree[i, valid] = glob_outdeg[own_old[valid]].astype(np.float32)
        # exchange lists
        for r, g in enumerate(p["comb_ids"]):
            j = int(g // cap)
            comb_send[i][j].append(cap + s_pad + r)
            comb_recv[j][i].append(int(g - j * cap))
        for r, g in enumerate(p["scat_ids"]):
            j = int(g // cap)
            scat_send[j][i].append(int(g - j * cap))
            scat_recv[i][j].append(cap + r)

    # The scatter/combiner loads are SKEWED (paper Fig. 12b/13b); sizing the
    # two exchange buffers independently halves all_to_all bytes on fan-in
    # or fan-out heavy graphs.
    c_x_pad = max(1, max(len(comb_send[i][j]) for i in range(k)
                         for j in range(k)))
    s_x_pad = max(1, max(len(scat_send[i][j]) for i in range(k)
                         for j in range(k)))
    c_x_pad = -(-c_x_pad // pad_multiple) * pad_multiple
    s_x_pad = -(-s_x_pad // pad_multiple) * pad_multiple

    def stack(lists, fill, width):
        out = np.full((k, k, width), fill, dtype=np.int32)
        for a in range(k):
            for b in range(k):
                v = np.asarray(lists[a][b], dtype=np.int32)
                out[a, b, :v.shape[0]] = v
        return out

    # src-sorted CSR over each partition's local edges (frontier compaction)
    num_slots = sink + 1
    csr_indptr = np.zeros((k, num_slots + 1), dtype=np.int32)
    csr_eidx = np.zeros((k, e_pad), dtype=np.int32)
    csr_max_deg = 0
    bucket_id = np.full((k, num_slots), -1, dtype=np.int32)
    bucket_sizes = bucket_max_deg = ()
    for i in range(k):
        csr_indptr[i], csr_eidx[i], deg = csr_layout(src[i], edge_mask[i],
                                                     num_slots)
        csr_max_deg = max(csr_max_deg, deg)
        bucket_id[i], sizes, max_degs = degree_buckets(csr_indptr[i],
                                                       num_slots)
        bucket_sizes = _merge_bucket_stats(bucket_sizes, sizes)
        bucket_max_deg = _merge_bucket_stats(bucket_max_deg, max_degs)

    return AgentGraph(
        k=k, num_vertices=V, cap=cap, s_pad=s_pad, c_pad=c_pad, e_pad=e_pad,
        s_x_pad=s_x_pad, c_x_pad=c_x_pad,
        src=src, dst=dst, edge_mask=edge_mask, edge_props=eprops,
        out_degree=out_degree, old2new=old2new, new2old=new2old,
        comb_send_slot=stack(comb_send, sink, c_x_pad),
        comb_recv_master=stack(comb_recv, sink, c_x_pad),  # identity-safe
        scat_send_master=stack(scat_send, 0, s_x_pad),
        scat_recv_slot=stack(scat_recv, sink, s_x_pad),
        num_scatter=num_scatter, num_combiner=num_combiner,
        num_edges=num_edges,
        csr_indptr=csr_indptr, csr_eidx=csr_eidx, csr_max_deg=csr_max_deg,
        bucket_id=bucket_id, bucket_sizes=bucket_sizes,
        bucket_max_deg=bucket_max_deg,
        partitioner=partitioner or "",
    )
