"""Distributed GRE engine: Scatter-Combine over Agent-Graph via shard_map.

Each device owns one agent-graph partition (masters + agents + edge shard).
A distributed superstep is (paper §4-5, adapted to TPU collectives):

  1. scatter refresh  — every master pushes (scatter_data, active) to its
     remote scatter agents: ONE message per (master, partition) pair, an
     `all_to_all` over static per-peer slot lists.
  2. local scatter-combine — the fused gather → message → segment-reduce
     over the local edge shard; destinations are local masters (direct) or
     combiner slots (pre-reduction of remote-bound messages).
  3. combine flush   — each combiner sends ONE ⊕-reduced message to its
     master: an `all_to_all` + a second segment-combine at the owner
     (exactness from ⊕ associativity, paper §2.2).
  4. apply           — masters fold combine_data into vertex_data and
     assert_to_halt.

Total network traffic per superstep = |V_s| + |V_c| messages — the paper's
§5.1 bound, strictly ≤ vertex-cut's 2R.  A dense fallback (`exchange=
"dense"`) implements the hash-partition/Pregel-style alternative: a psum
over the full relabeled vertex vector; it is used as the communication
baseline in benchmarks and rooflines.

Overlap (beyond-paper): `overlap=True` splits the local edge shard into
remote-destined and local-destined halves; the combine flush for the remote
half is issued before the local half computes, letting XLA overlap the
all_to_all with local compute (the TPU analogue of §6.2's "override network
communication with useful computation").
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.agent_graph import AgentGraph
from repro.core.engine import DevicePartition, EngineState, GREEngine
from repro.core.vertex_program import VertexProgram, segment_combine


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardTopology:
    """Device-local (inside shard_map) view of one AgentGraph partition."""

    part: DevicePartition          # local slots + edges
    comb_send_slot: jnp.ndarray    # [k, x_pad]
    comb_recv_master: jnp.ndarray  # [k, x_pad]
    scat_send_master: jnp.ndarray  # [k, x_pad]
    scat_recv_slot: jnp.ndarray    # [k, x_pad]


def refresh_scatter_agents(topo: "ShardTopology", scatter_data: jnp.ndarray,
                           active: jnp.ndarray, axes, identity,
                           dense: bool = False):
    """Exchange 1 (master → scatter agent): ONE message per (master, peer).

    Works for scalar or feature-vector `scatter_data` ([slots] or
    [slots, D...]).  Returns refreshed (scatter_data, active).  With
    `dense=True` (iterative programs: every vertex active) the activity
    payload is skipped — half the exchange ops.
    """
    vals = jnp.take(scatter_data, topo.scat_send_master, axis=0)   # [k, x, *F]
    rec_v = jax.lax.all_to_all(vals, axes, split_axis=0, concat_axis=0,
                               tiled=True)
    slots = topo.scat_recv_slot.reshape(-1)
    flat_v = rec_v.reshape((-1,) + rec_v.shape[2:])
    sd = scatter_data.at[slots].set(flat_v.astype(scatter_data.dtype),
                                    mode="drop")
    if dense:
        return sd, active
    acts = jnp.take(active, topo.scat_send_master, axis=0)         # [k, x]
    rec_a = jax.lax.all_to_all(acts, axes, split_axis=0, concat_axis=0,
                               tiled=True)
    act = active.at[slots].set(rec_a.reshape(-1), mode="drop")
    return sd, act


def flush_combiners(topo: "ShardTopology", combined: jnp.ndarray, axes,
                    monoid):
    """Exchange 2 (combiner → master): ONE ⊕-reduced value per agent.

    Returns a [num_slots, *F] array of remote contributions folded into
    local master slots (identity elsewhere).
    """
    vals = jnp.take(combined, topo.comb_send_slot, axis=0)          # [k, x, *F]
    rec = jax.lax.all_to_all(vals, axes, split_axis=0, concat_axis=0,
                             tiled=True)
    flat = rec.reshape((-1,) + rec.shape[2:])
    return segment_combine(flat.astype(combined.dtype),
                           topo.comb_recv_master.reshape(-1),
                           topo.part.num_slots, monoid)


class DistGREEngine:
    """Runs a VertexProgram over an AgentGraph on a device mesh."""

    def __init__(self, program: VertexProgram, mesh: Mesh,
                 axis_names: Tuple[str, ...] = ("graph",),
                 exchange: str = "agent", overlap: bool = False,
                 use_pallas: bool = False):
        assert exchange in ("agent", "dense")
        self.program = program
        self.mesh = mesh
        self.axes = axis_names
        self.exchange = exchange
        self.overlap = overlap
        self.local = GREEngine(program, use_pallas=use_pallas)

    # ----------------------------------------------------------- host → device
    def device_topology(self, ag: AgentGraph):
        """Stacked arrays [k, ...]; shard_map splits row i to device i."""
        part = DevicePartition(
            src=jnp.asarray(ag.src), dst=jnp.asarray(ag.dst),
            edge_mask=jnp.asarray(ag.edge_mask),
            num_masters=ag.cap, num_slots=ag.num_slots,
            edges_sorted_by_dst=True,
            edge_props={n: jnp.asarray(v) for n, v in ag.edge_props.items()},
            aux={"out_degree": jnp.asarray(ag.out_degree),
                 "global_id": jnp.asarray(
                     ag.new2old.reshape(ag.k, ag.cap).astype(np.float32))},
        )
        return ShardTopology(
            part=part,
            comb_send_slot=jnp.asarray(ag.comb_send_slot),
            comb_recv_master=jnp.asarray(ag.comb_recv_master),
            scat_send_master=jnp.asarray(ag.scat_send_master),
            scat_recv_slot=jnp.asarray(ag.scat_recv_slot),
        )

    def init_state(self, ag: AgentGraph, source: Optional[int] = None):
        """Stacked initial state [k, ...]; `source` is an ORIGINAL vertex id."""
        p = self.program
        k, cap, slots = ag.k, ag.cap, ag.num_slots
        aux = {"out_degree": jnp.asarray(ag.out_degree),   # [k, cap]
               "global_id": jnp.asarray(
                   ag.new2old.reshape(k, cap).astype(np.float32))}
        vd = jax.vmap(lambda a: p.init_vertex_data(cap, a))(aux)
        sd = jnp.full((k, slots), p.monoid.identity, p.msg_dtype)
        sd = sd.at[:, :cap].set(
            jax.vmap(lambda a: p.init_scatter_data(cap, a))(aux))
        act = jnp.zeros((k, slots), dtype=bool)
        act = act.at[:, :cap].set(
            jax.vmap(lambda a: p.init_active(cap, a))(aux))
        # mask padding masters (no original vertex)
        real = jnp.asarray(ag.new2old.reshape(k, cap) >= 0)
        act = act.at[:, :cap].set(act[:, :cap] & real)
        if source is not None:
            g = int(ag.old2new[source])
            i, s = g // cap, g % cap
            vd = vd.at[i, s].set(0.0)
            sd = sd.at[i, s].set(0.0)
            act = jnp.zeros_like(act).at[i, s].set(True)
        return EngineState(vd, sd, act, jnp.zeros((k,), jnp.int32))

    # -------------------------------------------------------- shard-local step
    def _refresh_scatter_agents(self, topo: ShardTopology, state: EngineState):
        """Exchange 1: master → scatter agent (value, active)."""
        sd, act = refresh_scatter_agents(topo, state.scatter_data,
                                         state.active_scatter, self.axes,
                                         self.program.monoid.identity,
                                         dense=self.local.dense_frontier)
        return EngineState(state.vertex_data, sd, act, state.step)

    def _flush_combiners(self, topo: ShardTopology, combined: jnp.ndarray):
        """Exchange 2: combiner → master, ONE ⊕-reduced value per agent."""
        return flush_combiners(topo, combined, self.axes, self.program.monoid)

    def _superstep_shard(self, topo: ShardTopology, state: EngineState
                         ) -> EngineState:
        p = self.program
        monoid = p.monoid
        state = self._refresh_scatter_agents(topo, state)
        if self.overlap:
            # remote-destined edges first; their flush overlaps local compute
            part = topo.part
            is_remote = part.dst >= part.num_masters + 0  # combiners live high
            remote_dst = jnp.where(is_remote, part.dst, part.num_slots - 1)
            local_dst = jnp.where(is_remote, part.num_slots - 1, part.dst)
            remote_part = dataclasses.replace(part, dst=remote_dst,
                                              edges_sorted_by_dst=False)
            local_part = dataclasses.replace(part, dst=local_dst,
                                             edges_sorted_by_dst=False)
            combined_remote = self.local.scatter_combine(remote_part, state)
            flushed = self._flush_combiners(topo, combined_remote)
            combined_local = self.local.scatter_combine(local_part, state)
            combined = monoid.op(combined_local, flushed)
        else:
            combined = self.local.scatter_combine(topo.part, state)
            flushed = self._flush_combiners(topo, combined)
            # master slots take direct local + flushed remote contributions
            combined = monoid.op(
                jnp.where(jnp.arange(combined.shape[0]) < topo.part.num_masters,
                          combined, monoid.identity),
                flushed)
        return self.local.apply(topo.part, state, combined)

    def _superstep_dense(self, topo: ShardTopology, state: EngineState,
                         my_row: jnp.ndarray) -> EngineState:
        """Baseline exchange: psum over the full relabeled vertex vector."""
        p = self.program
        state = self._refresh_scatter_agents(topo, state)
        k = jax.lax.psum(1, self.axes)
        cap = topo.part.num_masters
        combined_loc = self.local.scatter_combine(topo.part, state)
        # project local slots back to global master vector [k*cap]
        myslice = my_row * cap
        global_vec = jnp.full((k * cap,), p.monoid.identity, p.msg_dtype)
        global_vec = global_vec.at[myslice + jnp.arange(cap)].set(
            combined_loc[:cap])
        # combiner slots map to their global master id via recv lists? dense
        # mode instead scatters combiner values into the global vector.
        comb_vals = jnp.take(combined_loc, topo.comb_send_slot, axis=0,
                             fill_value=p.monoid.identity)   # [k, x]
        tgt = (jnp.arange(k)[:, None] * cap +
               jax.lax.all_to_all(topo.comb_recv_master, self.axes, 0, 0,
                                  tiled=True))
        sink_mask = jax.lax.all_to_all(
            topo.comb_recv_master, self.axes, 0, 0, tiled=True) >= cap
        tgt = jnp.where(sink_mask, k * cap, tgt)  # drop padding
        global_vec = segment_combine(
            jnp.concatenate([global_vec, comb_vals.reshape(-1)]),
            jnp.concatenate([jnp.arange(k * cap), tgt.reshape(-1)]),
            k * cap + 1, p.monoid)[:k * cap]
        if p.monoid.name == "sum":
            total = jax.lax.psum(global_vec, self.axes)
        elif p.monoid.name == "min":
            total = jax.lax.pmin(global_vec, self.axes)
        else:
            total = jax.lax.pmax(global_vec, self.axes)
        mine = jax.lax.dynamic_slice(total, (myslice,), (cap,))
        combined = jnp.full((topo.part.num_slots,), p.monoid.identity,
                            p.msg_dtype).at[:cap].set(mine)
        return self.local.apply(topo.part, state, combined)

    # ------------------------------------------------------------------- run
    def make_run(self, ag: AgentGraph, max_steps: int = 100):
        """Build the jitted distributed run function over the mesh."""
        topo = self.device_topology(ag)
        spec_leading = P(self.axes if len(self.axes) > 1 else self.axes[0])
        shard = partial(jax.shard_map, mesh=self.mesh,
                        in_specs=(spec_leading, spec_leading),
                        out_specs=spec_leading, check_vma=False)

        def squeeze0(tree):
            return jax.tree.map(lambda a: a[0] if hasattr(a, "ndim") and a.ndim > 0 else a, tree)

        def unsqueeze0(tree):
            return jax.tree.map(lambda a: a[None] if hasattr(a, "ndim") else a, tree)

        @shard
        def run_shard(topo_stack, state_stack):
            topo_l = squeeze0(topo_stack)
            state_l = squeeze0(state_stack)
            my_row = jax.lax.axis_index(self.axes)

            def cond(s):
                any_active = jnp.any(s.active_scatter)
                glob = jax.lax.pmax(any_active.astype(jnp.int32), self.axes)
                return (s.step < max_steps) & (glob > 0)

            def body(s):
                if self.exchange == "dense":
                    return self._superstep_dense(topo_l, s, my_row)
                return self._superstep_shard(topo_l, s)

            out = jax.lax.while_loop(cond, body, state_l)
            return unsqueeze0(out)

        return jax.jit(run_shard)

    def run(self, ag: AgentGraph, source: Optional[int] = None,
            max_steps: int = 100) -> Tuple[np.ndarray, EngineState]:
        """Execute; returns (vertex_data in ORIGINAL vertex order, state)."""
        topo = self.device_topology(ag)
        state = self.init_state(ag, source=source)
        fn = self.make_run(ag, max_steps=max_steps)
        out = fn(topo, state)
        out = jax.device_get(out)
        vd = np.asarray(out.vertex_data).reshape(ag.k * ag.cap, *out.vertex_data.shape[2:])
        result = np.empty((ag.num_vertices,) + vd.shape[1:], vd.dtype)
        result[:] = vd[ag.old2new]
        return result, out
