"""Distributed GRE engine: the canonical superstep under shard_map.

Each device owns one agent-graph partition (masters + agents + edge shard)
and runs `GREEngine.superstep` — the SAME code path as the single-shard
engine — with a pluggable ExchangeBackend supplying the communication:

  exchange="agent"  → AgentExchange: scatter refresh (ONE message per
      (master, peer) pair) before the local fused scatter-combine, combiner
      flush (ONE ⊕-reduced message per agent) after it.  Total traffic per
      superstep = |V_s| + |V_c| messages — the paper's §5.1 bound, strictly
      ≤ vertex-cut's 2R.  `overlap=True` issues the remote-destined flush
      before local-destined edges compute (§6.2's "override network
      communication with useful computation", as an XLA scheduling hint).
  exchange="dense"  → DenseExchange: hash-partition/Pregel baseline, a
      collective ⊕ over the full relabeled vertex vector; used as the
      communication baseline in benchmarks and rooflines.
  exchange="pipelined" → PipelinedAgentExchange: the Agent-Graph protocol
      over a static ingress-time remote/local edge split
      (`agent_graph.split_edge_tiles`) — the flush collective for superstep
      i is issued before the local-tile combine and merged at the top of
      superstep i+1 (double-buffered `Mailbox`), overlapping communication
      with computation (paper §6.2) at E edge-scans per superstep where
      `overlap=True` needs 2·E.
  exchange="async" → AsyncAgentExchange: bounded-staleness execution over
      the same split tiles — the Mailbox generalized to a `staleness=k`
      deep ring so remote partials cross shards only once per k supersteps
      (one refresh + one flush collective per WINDOW instead of per step)
      while local updates merge eagerly every step.  Monotone ⊕=min/max
      halting programs only (`VertexProgram.monotone`); sum-monoid
      programs refuse with ValueError at construction.

Every backend runs through the SAME driver loop: the engine's
`SuperstepPlan` (repro.core.plan) selects the exchange phase shape
("sync" vs "pipelined" vs "async") from the backend and
`plan.execute_plan` drives it per shard.  This module owns only backend/plan selection, host→device
topology layout, and state relabeling; all superstep logic lives in
engine.py/exchange.py/plan.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.agent_graph import AgentGraph, split_edge_tiles
from repro.core.engine import DevicePartition, EngineState, GREEngine
from repro.core.exchange import (AgentExchange, AsyncAgentExchange,
                                 DenseExchange, NullExchange,
                                 PipelinedAgentExchange, PipelineTiles,
                                 ShardTopology, flush_combiners,
                                 refresh_scatter_agents)
from repro.core.plan import execute_plan, execute_superstep
from repro.core.vertex_program import VertexProgram
from repro.dist.sharding import shard_map


def _squeeze0(tree):
    """Drop the leading stacked axis of a device-local shard_map operand."""
    return jax.tree.map(
        lambda a: a[0] if hasattr(a, "ndim") and a.ndim > 0 else a, tree)


def _unsqueeze0(tree):
    return jax.tree.map(lambda a: a[None] if hasattr(a, "ndim") else a, tree)

__all__ = ["DistGREEngine", "PipelineTiles", "PipelinedAgentExchange",
           "ShardTopology", "flush_combiners", "refresh_scatter_agents",
           "split_edge_tiles"]


def _check_async_eligible(program: VertexProgram) -> None:
    """Bounded staleness is sound only when delayed delivery cannot change
    the fixed point (`VertexProgram.monotone`): min/max messages are bounds
    that re-tighten on late arrival, but a sum-monoid message folded
    against a stale accumulator is double-counted."""
    if not program.monotone:
        raise ValueError(
            f"exchange='async' requires a monotone program (halting with "
            f"an idempotent min/max monoid); {program.name!r} uses "
            f"monoid={program.monoid.name!r}, halts={program.halts} — "
            f"bounded-staleness delivery would corrupt its fixed point. "
            f"Use exchange='agent' or 'pipelined' instead.")


class DistGREEngine:
    """Runs a VertexProgram over an AgentGraph on a device mesh."""

    EXCHANGES = ("agent", "dense", "null", "pipelined", "async")

    def __init__(self, program: VertexProgram, mesh: Mesh,
                 axis_names: Tuple[str, ...] = ("graph",),
                 exchange: str = "agent", overlap: bool = False,
                 use_pallas: bool = False, frontier: str = "auto",
                 frontier_cap: Optional[int] = None,
                 dynamic_table: bool = True, plan=None, plan_cache=None,
                 staleness: int = 2):
        assert exchange in self.EXCHANGES, exchange
        # NullExchange never communicates: correct only on a 1-device mesh
        # (useful to A/B the shard_map plumbing against GREEngine).
        assert exchange != "null" or mesh.size == 1, \
            "exchange='null' drops all cross-shard traffic; needs a 1-device mesh"
        if exchange == "async":
            _check_async_eligible(program)
            if staleness < 1:
                raise ValueError(
                    f"exchange='async' needs staleness >= 1, got {staleness}")
        self.staleness = staleness
        self.program = program
        self.mesh = mesh
        self.axes = axis_names
        self.exchange = exchange
        self.overlap = overlap
        # frontier/frontier_cap select the per-shard scatter strategy
        # (engine.py); the lax.cond is shard-local and branch bodies have no
        # collectives, so shards may diverge dense-vs-compact per superstep.
        self.local = GREEngine(program, use_pallas=use_pallas,
                               frontier=frontier, frontier_cap=frontier_cap,
                               dynamic_table=dynamic_table)
        # plan=SuperstepPlan adopts the composed mode now (its phase shape
        # picks between the Agent-Graph protocol's sync and pipelined
        # variants); plan="auto-tuned" defers to the persistent tuned-plan
        # cache, consulted — keyed by (agent-graph fingerprint, program
        # payload, MESH SIZE) — the first time an AgentGraph is in hand
        # (device_topology/init_state/make_run), before any topology or
        # trace bakes in the static shapes.  Misses keep the knobs above.
        self._plan_cache = plan_cache
        self._auto_plan_pending = False
        if plan is None:
            pass
        elif plan == "auto-tuned":
            self._auto_plan_pending = True
        else:
            self.adopt_plan(plan)

    def adopt_plan(self, plan) -> None:
        """Take a composed SuperstepPlan mesh-wide: the frontier/kernel
        stages land on the local engine (`GREEngine.adopt_plan`) and the
        phase shape selects the exchange variant — "pipelined" switches
        to the split-tile PipelinedAgentExchange, "async" to the k-deep
        AsyncAgentExchange (monotone programs only — refuses otherwise,
        so a tuned-cache plan can never smuggle staleness under a sum
        monoid), "sync" demotes either back to the sync AgentExchange
        (dense/null baselines are left alone: the plan tunes the
        Agent-Graph protocol, not the baseline)."""
        self.local.adopt_plan(plan)
        if plan.phases == "pipelined":
            self.exchange = "pipelined"
        elif plan.phases == "async":
            _check_async_eligible(self.program)
            self.exchange = "async"
            self.staleness = plan.staleness
        elif self.exchange in ("pipelined", "async"):
            self.exchange = "agent"

    def _resolve_auto_plan(self, ag: AgentGraph) -> None:
        """`plan="auto-tuned"` resolution against the persistent cache
        (see `GREEngine._consult_plan_cache`); the key folds in the mesh
        size, the agent graph's remote-destination edge fraction, and the
        partitioner that produced the placement (`AgentGraph.partitioner`,
        recorded when `build_agent_graph` is handed a partitioner name) —
        the fingerprint facets a single-shard tuning run can't see, and
        the facet that keeps a plan tuned on a greedy placement from
        answering for an HDRF one."""
        self._auto_plan_pending = False
        from repro.tuning import PlanCache, plan_cache_key
        cache = self._plan_cache
        if not isinstance(cache, PlanCache):
            cache = PlanCache(cache)
        key = plan_cache_key(agent_graph=ag, program=self.program,
                             mesh_size=self.mesh.size)
        plan = cache.lookup(key)
        if plan is not None:
            self.adopt_plan(plan)

    @property
    def plan(self):
        """The ONE mesh-uniform plan this engine executes (introspection:
        shard_map traces a single program, so frontier/kernel stages —
        like every static tile shape — are identical on every shard, and
        `phases` records the shape the selected backend's phase protocol
        will drive).  Rebuilt from the local engine on access so a
        `calibrate_frontier_cap` run between construction and `make_run`
        is honored (matching `GREEngine.make_plan`)."""
        if self.exchange == "async":
            return self.local.make_plan(phases="async",
                                        staleness=self.staleness)
        return self.local.make_plan(
            phases="pipelined" if self.exchange == "pipelined" else "sync")

    # ------------------------------------------------------ backend selection
    def make_exchange(self, topo: ShardTopology):
        """Instantiate the configured ExchangeBackend for one device's
        topology (called inside shard_map; `my_row` is the mesh position)."""
        if self.exchange == "null":
            return NullExchange()
        if self.exchange == "dense":
            return DenseExchange(topo, self.axes, self.program.monoid,
                                 my_row=jax.lax.axis_index(self.axes),
                                 dense_frontier=self.local.dense_frontier)
        if self.exchange == "pipelined":
            return PipelinedAgentExchange(topo, self.axes,
                                          self.program.monoid,
                                          dense_frontier=self.local.dense_frontier)
        if self.exchange == "async":
            return AsyncAgentExchange(topo, self.axes, self.program.monoid,
                                      dense_frontier=self.local.dense_frontier,
                                      staleness=self.staleness)
        return AgentExchange(topo, self.axes, self.program.monoid,
                             dense_frontier=self.local.dense_frontier,
                             overlap=self.overlap)

    # ----------------------------------------------------------- host → device
    def device_topology(self, ag: AgentGraph):
        """Stacked arrays [k, ...]; shard_map splits row i to device i.

        With `exchange="pipelined"` or `exchange="async"` every edge scan
        runs on the split tiles (`ShardTopology.tiles`); the canonical part
        then carries NO edge columns at all (`DevicePartition` edge columns
        are optional) — only the slot statics + aux that apply needs.
        Shipping the full columns twice would double per-device edge
        memory for arrays the split-tile paths never read.
        """
        if self._auto_plan_pending:
            self._resolve_auto_plan(ag)
        aux = {"out_degree": jnp.asarray(ag.out_degree),
               "global_id": jnp.asarray(
                   ag.new2old.reshape(ag.k, ag.cap).astype(np.float32))}
        if self.exchange in ("pipelined", "async"):
            part = DevicePartition(
                num_masters=ag.cap, num_slots=ag.num_slots,
                edges_sorted_by_dst=True, aux=aux,
            )
            tiles = self._pipeline_tiles(ag)
        else:
            part = DevicePartition(
                src=jnp.asarray(ag.src), dst=jnp.asarray(ag.dst),
                edge_mask=jnp.asarray(ag.edge_mask),
                num_masters=ag.cap, num_slots=ag.num_slots,
                edges_sorted_by_dst=True,
                edge_props={n: jnp.asarray(v)
                            for n, v in ag.edge_props.items()},
                aux=aux,
                csr_indptr=jnp.asarray(ag.csr_indptr),
                csr_eidx=jnp.asarray(ag.csr_eidx),
                csr_max_deg=ag.csr_max_deg,
                bucket_id=jnp.asarray(ag.bucket_id),
                bucket_sizes=ag.bucket_sizes,
                bucket_max_deg=ag.bucket_max_deg,
            )
            tiles = None
        return ShardTopology(
            part=part,
            comb_send_slot=jnp.asarray(ag.comb_send_slot),
            comb_recv_master=jnp.asarray(ag.comb_recv_master),
            scat_send_master=jnp.asarray(ag.scat_send_master),
            scat_recv_slot=jnp.asarray(ag.scat_recv_slot),
            tiles=tiles,
        )

    def _pipeline_tiles(self, ag: AgentGraph) -> PipelineTiles:
        """Stacked remote/local edge tiles + compact-space exchange indices.

        Exchange-index remapping rides the slot layout: combiner slots start
        at `cap + s_pad` and the padding fill is the sink
        (`cap + s_pad + c_pad`), so a uniform subtraction sends real slots
        to `[0, c_pad)` and fills to exactly `c_pad` — the remote tile's
        identity slot.  Receive-side master slots keep their index; sink
        fills clamp to `cap`, the local identity slot.
        """
        split = split_edge_tiles(ag)
        comb_base = ag.cap + ag.s_pad

        def tile_part(t):
            return DevicePartition(
                src=jnp.asarray(t.src), dst=jnp.asarray(t.dst),
                edge_mask=jnp.asarray(t.mask),
                num_masters=ag.cap, num_slots=ag.num_slots,
                edges_sorted_by_dst=True,
                edge_props={n: jnp.asarray(v) for n, v in t.props.items()},
                csr_indptr=jnp.asarray(t.csr_indptr),
                csr_eidx=jnp.asarray(t.csr_eidx),
                csr_max_deg=t.csr_max_deg,
                bucket_id=jnp.asarray(t.bucket_id),
                bucket_sizes=t.bucket_sizes,
                bucket_max_deg=t.bucket_max_deg,
            )

        return PipelineTiles(
            part_remote=tile_part(split.remote),
            part_local=tile_part(split.local),
            comb_send_compact=jnp.asarray(ag.comb_send_slot - comb_base),
            comb_recv_master=jnp.asarray(
                np.minimum(ag.comb_recv_master, ag.cap)),
            num_combiners=ag.c_pad,
        )

    def init_state(self, ag: AgentGraph, source=None,
                   lane_tracking: bool = False):
        """Stacked initial state [k, ...]; `source` is an ORIGINAL vertex id,
        or — for `payload_shape=(D,)` multi-source programs — a length-D
        sequence of original ids (source d seeds payload lane d; a `None`
        or negative entry leaves lane d empty for later admission).

        `lane_tracking=True` attaches the per-lane halt vector (replicated
        `[k, D]` bool, kept mesh-global by the serving tick's pmax) so the
        serving layer can retire converged lanes between supersteps."""
        if self._auto_plan_pending:
            self._resolve_auto_plan(ag)
        p = self.program
        k, cap, slots = ag.k, ag.cap, ag.num_slots
        aux = {"out_degree": jnp.asarray(ag.out_degree),   # [k, cap]
               "global_id": jnp.asarray(
                   ag.new2old.reshape(k, cap).astype(np.float32))}
        vd = jax.vmap(lambda a: p.init_vertex_data(cap, a))(aux)
        sd0 = jax.vmap(lambda a: jnp.asarray(p.init_scatter_data(cap, a),
                                             p.msg_dtype))(aux)
        sd = jnp.full((k, slots) + sd0.shape[2:], p.monoid.identity,
                      p.msg_dtype).at[:, :cap].set(sd0)
        act = jnp.zeros((k, slots), dtype=bool)
        act = act.at[:, :cap].set(
            jax.vmap(lambda a: p.init_active(cap, a))(aux))
        # mask padding masters (no original vertex)
        real = jnp.asarray(ag.new2old.reshape(k, cap) >= 0)
        act = act.at[:, :cap].set(act[:, :cap] & real)
        seeded = []
        if source is not None:
            multi = isinstance(source, (list, tuple, np.ndarray))
            act = jnp.zeros_like(act)
            for d, sv in enumerate(source if multi else [source]):
                ok = sv is not None and int(sv) >= 0
                seeded.append(ok)
                if not ok:
                    continue
                g = int(ag.old2new[int(sv)])
                i, s = g // cap, g % cap
                if multi:  # seed payload lane d only
                    if p.seed_sources is not None:
                        aux_i = {kk: v[i] for kk, v in aux.items()}
                        vd_i, sd_i = p.seed_sources(
                            vd[i], sd[i], jnp.array([s], jnp.int32),
                            jnp.array([d], jnp.int32), aux_i)
                        vd = vd.at[i].set(vd_i)
                        sd = sd.at[i].set(sd_i)
                    else:
                        vd = vd.at[i, s, d].set(0.0)
                        sd = sd.at[i, s, d].set(0.0)
                else:
                    vd = vd.at[i, s].set(0.0)
                    sd = sd.at[i, s].set(0.0)
                act = act.at[i, s].set(True)
        lane_active = None
        if lane_tracking:
            if p.lane_activates is None or not p.payload_shape:
                raise ValueError(
                    "lane_tracking needs a multi-source program with "
                    "lane_activates (per-lane halt rule)")
            D = p.payload_shape[0]
            if len(seeded) not in (0, D):
                raise ValueError(f"expected {D} source entries")
            row = np.zeros(D, dtype=bool) if not seeded else np.array(seeded)
            lane_active = jnp.broadcast_to(jnp.asarray(row)[None, :], (k, D))
        return EngineState(vd, sd, act, jnp.zeros((k,), jnp.int32),
                           lane_active)

    # ------------------------------------------------------------ incremental
    def warm_start_state(self, ag: AgentGraph, prev_state: EngineState,
                         report, source=None, lane_tracking: bool = False):
        """Distributed warm start (see `GREEngine.warm_start_state`): the
        invalidation/seeding passes run host-side in ORIGINAL vertex order
        — `old2new` maps master rows out of the stacked `[k, cap, ...]`
        state and back — so the policy logic (repro.core.incremental) is
        shared verbatim with the single-shard engine.  `ag` is the
        MUTATED agent graph (`agent_graph.apply_edge_delta` preserves
        master placement, so `prev_state`'s rows line up)."""
        from repro.core import incremental
        from repro.core.agent_graph import slot_to_original
        p = self.program
        incremental.check_supported(p, report)
        k, cap, V = ag.k, ag.cap, ag.num_vertices
        state0 = self.init_state(ag, source=source,
                                 lane_tracking=lane_tracking)
        if not p.halts:
            return dataclasses.replace(
                state0,
                vertex_data=prev_state.vertex_data,
                scatter_data=state0.scatter_data.at[:, :cap].set(
                    prev_state.scatter_data[:, :cap]))

        def to_orig(stacked):   # [k, cap, ...] master rows -> [V, ...]
            a = np.asarray(stacked)
            return a.reshape((k * cap,) + a.shape[2:])[ag.old2new]

        vd_prev = to_orig(prev_state.vertex_data)
        sd_prev = to_orig(np.asarray(prev_state.scatter_data)[:, :cap])
        s2o = slot_to_original(ag)
        lsrc, ldst, lprop = [], [], []
        for i in range(k):
            m = ag.edge_mask[i]
            lsrc.append(s2o[i][ag.src[i]][m])
            ldst.append(s2o[i][ag.dst[i]][m])
            if p.needs_edge_prop:
                lprop.append(ag.edge_props[p.needs_edge_prop][i][m])
        lsrc = np.concatenate(lsrc)
        ldst = np.concatenate(ldst)
        eprop = np.concatenate(lprop) if p.needs_edge_prop else None
        protected = incremental.source_mask(vd_prev.shape, source)
        tainted = incremental.compute_taint(p, V, lsrc, ldst, eprop,
                                            vd_prev, report, protected)
        vd = np.where(tainted, to_orig(state0.vertex_data), vd_prev)
        sd = np.where(tainted,
                      to_orig(np.asarray(state0.scatter_data)[:, :cap]),
                      sd_prev)
        tany = tainted if tainted.ndim == 1 else tainted.any(axis=-1)
        aux_orig = {
            "out_degree": jnp.asarray(
                np.asarray(ag.out_degree).reshape(k * cap)[ag.old2new]),
            "global_id": jnp.arange(V, dtype=jnp.float32)}
        init_act = np.asarray(p.init_active(V, aux_orig))
        act = incremental.warm_seed_active(V, lsrc, ldst, tany,
                                           report.added_src, init_act)
        # scatter the original-order columns back into the stacked layout
        vd_st = np.asarray(state0.vertex_data).reshape(
            (k * cap,) + vd.shape[1:]).copy()
        vd_st[ag.old2new] = vd
        vd_st = vd_st.reshape((k, cap) + vd.shape[1:])
        sd_full = np.asarray(state0.scatter_data).copy()
        sd_flat = sd_full[:, :cap].reshape((k * cap,) + sd.shape[1:]).copy()
        sd_flat[ag.old2new] = sd
        sd_full[:, :cap] = sd_flat.reshape((k, cap) + sd.shape[1:])
        act_flat = np.zeros(k * cap, dtype=bool)
        act_flat[ag.old2new] = act
        act_st = np.zeros((k, ag.num_slots), dtype=bool)
        act_st[:, :cap] = act_flat.reshape(k, cap)
        return dataclasses.replace(
            state0,
            vertex_data=jnp.asarray(vd_st, vd_prev.dtype),
            scatter_data=jnp.asarray(sd_full, p.msg_dtype),
            active_scatter=jnp.asarray(act_st))

    def rerun_incremental(self, ag: AgentGraph, prev_state: EngineState,
                          delta, *, source=None, max_steps: int = 100):
        """Apply an EdgeDelta to the agent graph and re-converge the mesh
        run from `prev_state`'s fixed point.  Returns
        ``(new_ag, result_in_original_order, final_state, report)`` —
        bitwise-equal to a cold `run` on the mutated graph for halting
        min-monoid programs (tests/test_conformance.py)."""
        from repro.core.agent_graph import apply_edge_delta
        new_ag, report = apply_edge_delta(ag, delta)
        state = self.warm_start_state(new_ag, prev_state, report,
                                      source=source)
        topo = self.device_topology(new_ag)
        fn = self.make_run(new_ag, max_steps=max_steps)
        out = jax.device_get(fn(topo, state))
        vd = np.asarray(out.vertex_data).reshape(
            (new_ag.k * new_ag.cap,) + out.vertex_data.shape[2:])
        result = np.empty((new_ag.num_vertices,) + vd.shape[1:], vd.dtype)
        result[:] = vd[new_ag.old2new]
        return new_ag, result, out, report

    # ------------------------------------------------------------------ tick
    def make_superstep(self, ag: AgentGraph, steps_per_tick: int = 1):
        """Build the jitted SERVING TICK: `steps_per_tick` supersteps over
        the mesh with NO convergence loop around them — the serving layer
        (repro.serving.graph_scheduler) owns the loop so it can retire and
        admit payload lanes between ticks at static shape.

        Each tick runs `plan.execute_superstep` per shard (per-tick merge:
        a Mailbox carried across ticks would hold partial combines of a
        retired query, so the pipelined backend still overlaps its flush
        with the local-tile combine INSIDE the tick but never defers the
        merge past it) and globalizes the per-lane halt vector with a
        pmax, keeping `lane_active` replicated and host-readable.

        `exchange="async"` cannot serve ticks: its ring holds remote
        partials for up to `staleness` supersteps, and dropping them at a
        tick boundary would lose messages outright (not merely defer
        them)."""
        if self.exchange == "async":
            raise ValueError(
                "exchange='async' cannot drive the serving tick: the "
                "staleness ring carries un-flushed remote partials across "
                "supersteps, and a per-tick merge would drop them. Use "
                "exchange='agent' or 'pipelined' for serving.")
        if self._auto_plan_pending:
            self._resolve_auto_plan(ag)
        spec_leading = P(self.axes if len(self.axes) > 1 else self.axes[0])

        def tick_shard(topo_stack, state_stack):
            topo_l = _squeeze0(topo_stack)
            s = _squeeze0(state_stack)
            backend = self.make_exchange(topo_l)
            for _ in range(steps_per_tick):
                s = execute_superstep(self.local, topo_l.part, s, backend)
            if s.lane_active is not None:
                la = jax.lax.pmax(s.lane_active.astype(jnp.int32),
                                  self.axes) > 0
                s = dataclasses.replace(s, lane_active=la)
            return _unsqueeze0(s)

        sharded = shard_map(tick_shard, mesh=self.mesh,
                            in_specs=(spec_leading, spec_leading),
                            out_specs=spec_leading)
        return jax.jit(sharded)

    # ------------------------------------------------------------------- run
    def make_run(self, ag: AgentGraph, max_steps: int = 100):
        """Build the jitted distributed run function over the mesh."""
        if self._auto_plan_pending:
            self._resolve_auto_plan(ag)
        spec_leading = P(self.axes if len(self.axes) > 1 else self.axes[0])
        squeeze0, unsqueeze0 = _squeeze0, _unsqueeze0

        def glob_any(local):
            # Globalizer over the shard-local liveness bool (frontier OR
            # in-flight exchange carry — see plan.execute_plan): the pmax
            # keeps the loop predicate mesh-uniform so collectives inside
            # the phase stay matched across shards.
            return jax.lax.pmax(local.astype(jnp.int32), self.axes) > 0

        def run_shard(topo_stack, state_stack):
            topo_l = squeeze0(topo_stack)
            state_l = squeeze0(state_stack)
            backend = self.make_exchange(topo_l)
            # the ONE driver loop (plan.execute_plan): the phase shape
            # rides the backend, the termination predicate is the
            # mesh-global pmax so collectives stay matched across shards
            out = execute_plan(self.local, topo_l.part, state_l, backend,
                               max_steps=max_steps, any_active=glob_any)
            return unsqueeze0(out)

        sharded = shard_map(run_shard, mesh=self.mesh,
                            in_specs=(spec_leading, spec_leading),
                            out_specs=spec_leading)
        return jax.jit(sharded)

    def run(self, ag: AgentGraph, source=None,
            max_steps: int = 100) -> Tuple[np.ndarray, EngineState]:
        """Execute; returns (vertex_data in ORIGINAL vertex order, state)."""
        topo = self.device_topology(ag)
        state = self.init_state(ag, source=source)
        fn = self.make_run(ag, max_steps=max_steps)
        out = fn(topo, state)
        out = jax.device_get(out)
        vd = np.asarray(out.vertex_data).reshape(ag.k * ag.cap, *out.vertex_data.shape[2:])
        result = np.empty((ag.num_vertices,) + vd.shape[1:], vd.dtype)
        result[:] = vd[ag.old2new]
        return result, out
