"""Superstep execution plans: ONE driver loop for every engine mode.

Before this layer the per-superstep decision logic was smeared across the
stack: `GREEngine._frontier_plan` picked the frontier strategy, `run` vs
`run_pipelined` were two hand-maintained loops for the two exchange phase
shapes, `_tile_combine` hard-coded the kernel route, and `DistGREEngine`
re-derived all three when selecting a backend.  A `SuperstepPlan` composes
the three orthogonal decisions into one static object, resolved once per
(engine, partition):

  frontier stage — `dense` every-edge scan, `flat` single-tile compaction,
      or degree-`bucketed` tiles, with the static capacity split
      (`resolve_frontier`, previously `GREEngine._frontier_plan`);
  phase shape    — `sync` (the whole reduce is one phase) or `pipelined`
      (local-phase / deferred merge, the double-buffered exchange); every
      ExchangeBackend speaks the same `local_phase`/`merge`/`carry_init`
      protocol, so ONE loop (`execute_plan`) drives both shapes;
  kernel stage   — XLA segment ops or the Pallas tile combine, and for
      Pallas whether the on-device `dynamic_block_table` pruning pass runs
      or the degenerate `full_block_table` fallback (`KernelPlan`).

`execute_plan` is the single BSP loop: the superstep is cut into
phase / merge+apply stages with the phase carry threaded across iterations,
so a pipelined backend's flush collective issued in superstep i overlaps
the local-tile combine and merges at the top of i+1 (paper §6.2), while a
sync backend's carry is simply its fully ⊕-reduced array and the same loop
degenerates to refresh → reduce → apply.  The apply count and final state
match the classic synchronous loop exactly (the same ⊕ folds happen, some
deferred one iteration), and the phase runs under a `lax.cond` on the
continuation predicate — computed ONCE post-apply, mesh-uniform when the
caller supplies the global `any_active` — so no trailing edge scan or
flush collective whose result would be discarded ever executes and the
collectives inside the phase stay matched across shards.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, NamedTuple, Optional

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids a cycle
    from repro.core.engine import DevicePartition, EngineState, GREEngine

PHASES = ("sync", "pipelined", "async")


class FrontierPlan(NamedTuple):
    """Static per-partition frontier resolution.

    `kind` is "dense" (caps None), "flat" (caps = the single tile capacity)
    or "bucketed" (caps = one capacity per degree bucket).  A NamedTuple so
    legacy call sites comparing against ``("flat", cap)`` tuples keep
    working.
    """

    kind: str
    caps: object = None


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """The combine-kernel stage of a plan.

    `use_pallas=False` is the XLA scatter-reduce (`segment_combine`).  With
    `use_pallas=True` gathered frontier tiles route through the Pallas tile
    combine; `dynamic_table` selects the on-device per-superstep
    `dynamic_block_table` pruning pass (default) vs the degenerate
    `full_block_table` fallback (every dst block visits every edge block —
    kept only as the documented escape hatch, see docs/kernels.md).
    """

    use_pallas: bool = False
    dynamic_table: bool = True


XLA_KERNEL = KernelPlan(use_pallas=False)


def resolve_frontier(strategy: str, frontier_cap: Optional[int],
                     dense_frontier: bool,
                     part: "DevicePartition") -> FrontierPlan:
    """Static (trace-time) frontier-strategy resolution for one partition.

    Returns kind "dense" (compile the dense path only), "flat" for the
    legacy single-tile compaction, or "bucketed" with one capacity per
    degree bucket.  Buckets kill the old `cap * max_deg >= E` hub gate:
    the bound compared against the dense scan is `sum_b cap_b * max_deg_b`,
    which stays small on power-law graphs because the hub bucket holds few
    members.
    """
    if strategy == "dense" or dense_frontier:
        return FrontierPlan("dense")  # iterative: frontier is everything
    if part.csr_indptr is None or part.csr_max_deg <= 0:
        return FrontierPlan("dense")
    from repro.core.frontier import bucket_caps, default_cap
    cap = min(frontier_cap or default_cap(part.num_slots), part.num_slots)
    bucketed = (strategy != "flat" and part.bucket_id is not None
                and len(part.bucket_max_deg) > 0
                and any(part.bucket_sizes))
    if not bucketed:
        if (strategy == "auto"
                and cap * part.csr_max_deg >= part.src.shape[0]):
            return FrontierPlan("dense")  # padded tile ≥ dense scan
        return FrontierPlan("flat", cap)
    caps = bucket_caps(part.bucket_sizes, cap)
    worst = sum(c * d for c, d in zip(caps, part.bucket_max_deg))
    if strategy == "auto" and worst >= part.src.shape[0]:
        return FrontierPlan("dense")  # full bucket tiles out-scan dense
    return FrontierPlan("bucketed", caps)


@dataclasses.dataclass(frozen=True)
class SuperstepPlan:
    """One engine mode, fully resolved: frontier strategy request, phase
    shape, and kernel stage.  Static/hashable so it can parameterize jitted
    drivers; the per-partition frontier resolution happens at trace time
    via `frontier(part)` (pipelined backends carry TWO edge-tile
    partitions, each resolving its own tile shapes).

    `bucket_bounds` is INGRESS metadata, not a runtime knob: the degree
    binning is baked into a partition when it is built
    (`graph.structures.degree_buckets`), so a plan carrying non-None
    bounds says "this plan was tuned against a partition binned with
    these bounds" — the autotuner's evaluator (repro.tuning) rebuilds
    partitions per candidate bounds, and engines adopting a tuned plan
    record the bounds so callers can rebuild matching partitions
    (`DevicePartition.from_graph(..., bucket_bounds=...)`).  None means
    "whatever the partition was built with" (the default bounds).
    """

    strategy: str = "auto"
    frontier_cap: Optional[int] = None
    dense_frontier: bool = False
    phases: str = "sync"
    kernel: KernelPlan = XLA_KERNEL
    bucket_bounds: Optional[tuple] = None
    # Bounded-staleness window k for phases="async" (the AsyncAgentExchange
    # ring depth; exchange collectives run once per k supersteps).  0 for
    # the synchronous shapes — a non-zero staleness on a sync/pipelined
    # plan would silently record a knob nothing executes.
    staleness: int = 0

    def __post_init__(self):
        assert self.phases in PHASES, self.phases
        if self.phases == "async" and self.staleness < 1:
            raise ValueError("phases='async' needs staleness >= 1 "
                             f"(got {self.staleness})")
        if self.phases != "async" and self.staleness != 0:
            raise ValueError(f"staleness={self.staleness} is only "
                             "meaningful with phases='async'")
        if self.bucket_bounds is not None:
            # normalize to a hashable int tuple (JSON round-trips lists)
            object.__setattr__(self, "bucket_bounds",
                               tuple(int(b) for b in self.bucket_bounds))

    # ---------------------------------------------------------- serialization
    def to_json(self) -> dict:
        """Plain-JSON form for the persistent plan cache
        (repro.tuning.cache).  Nested `kernel` keeps the kernel stage's
        fields grouped; `bucket_bounds` serializes as a list/None."""
        return {
            "strategy": self.strategy,
            "frontier_cap": self.frontier_cap,
            "dense_frontier": self.dense_frontier,
            "phases": self.phases,
            "kernel": {"use_pallas": self.kernel.use_pallas,
                       "dynamic_table": self.kernel.dynamic_table},
            "bucket_bounds": (None if self.bucket_bounds is None
                              else list(self.bucket_bounds)),
            "staleness": self.staleness,
        }

    @classmethod
    def from_json(cls, data: dict) -> "SuperstepPlan":
        """Inverse of `to_json`.  UNKNOWN fields are rejected, not
        ignored: a cache entry written by a future plan schema must fail
        loudly rather than silently execute with half its knobs dropped
        (the cache stores a schema version too, but field-level rejection
        catches hand-edited files)."""
        known = {"strategy", "frontier_cap", "dense_frontier", "phases",
                 "kernel", "bucket_bounds", "staleness"}
        unknown = set(data) - known
        if unknown:
            raise ValueError(f"SuperstepPlan.from_json: unknown field(s) "
                             f"{sorted(unknown)}")
        kdata = dict(data.get("kernel") or {})
        kunknown = set(kdata) - {"use_pallas", "dynamic_table"}
        if kunknown:
            raise ValueError(f"SuperstepPlan.from_json: unknown kernel "
                             f"field(s) {sorted(kunknown)}")
        kernel = KernelPlan(use_pallas=bool(kdata.get("use_pallas", False)),
                            dynamic_table=bool(kdata.get("dynamic_table",
                                                         True)))
        cap = data.get("frontier_cap")
        bounds = data.get("bucket_bounds")
        return cls(strategy=data.get("strategy", "auto"),
                   frontier_cap=None if cap is None else int(cap),
                   dense_frontier=bool(data.get("dense_frontier", False)),
                   phases=data.get("phases", "sync"),
                   kernel=kernel,
                   bucket_bounds=None if bounds is None else tuple(bounds),
                   staleness=int(data.get("staleness", 0)))

    def frontier(self, part: "DevicePartition") -> FrontierPlan:
        return resolve_frontier(self.strategy, self.frontier_cap,
                                self.dense_frontier, part)

    # ------------------------------------------------- scatter-combine stage
    def scatter_combine(self, engine: "GREEngine", part: "DevicePartition",
                        state: "EngineState",
                        num_segments: Optional[int] = None) -> jnp.ndarray:
        """The plan's scatter-combine stage: resolve the partition's
        frontier plan and dispatch dense scan vs compacted gather, with the
        kernel stage threaded through to the tile combine."""
        nseg = num_segments or part.num_slots
        fp = self.frontier(part)
        if fp.kind == "dense":
            return engine.dense_scatter_combine(part, state, nseg)
        from repro.core.frontier import frontier_scatter_combine
        return frontier_scatter_combine(
            engine.program, part, state, nseg, fp,
            dense_fn=lambda: engine.dense_scatter_combine(part, state, nseg),
            kernel=self.kernel)


def execute_superstep(engine: "GREEngine", part: "DevicePartition",
                      state: "EngineState", exchange) -> "EngineState":
    """ONE superstep through the phase protocol — the SERVING TICK.

    The continuous-batching scheduler (repro.serving.graph_scheduler)
    needs to stop BETWEEN supersteps, at static shape, to retire
    converged payload lanes and admit queued queries into the freed
    slots; `execute_plan`'s while-loop only stops at quiescence.  This is
    the single-superstep cut of the same stage decomposition:
    refresh → local_phase → merge → apply, for every backend.

    Sync backends are op-for-op `refresh → reduce → apply`.  For the
    pipelined backend the flush collective still overlaps the local-tile
    combine INSIDE the tick (that is the overlap window), but the merge
    is not deferred across ticks: a carried Mailbox would hold partial
    combines of a lane's RETIRED query at the moment the scheduler
    reseeds it, corrupting the admitted query — per-tick merge keeps the
    lane-recycling invariant (every ⊕ fold visible to a lane happened
    before the lane was reseeded) at the cost of the one-superstep
    deferral, and stays bitwise ⊕-equivalent to the deferred loop.

    Per-lane halt rides the state: when `EngineState.lane_active` is
    attached, `apply` refreshes it from the program's `lane_activates`,
    so after each tick the scheduler reads exactly which lanes still
    improve (False = that lane's query converged).
    """
    state = exchange.refresh(state)
    carry = exchange.local_phase(engine, part, state)
    return engine.apply(part, state, exchange.merge(carry))


def execute_plan(engine: "GREEngine", part: "DevicePartition",
                 state: "EngineState", exchange,
                 max_steps: int = 100, any_active=None) -> "EngineState":
    """THE driver loop: run `engine.program` to quiescence under the
    engine's SuperstepPlan.

    The plan is fully determined by its two inputs — the engine owns the
    frontier/kernel stages (`engine.make_plan`, reached through
    `engine.scatter_combine` inside every backend's phase) and the
    backend's `phases` attribute names the phase shape — so the executor
    takes no separate plan argument there could be a stale copy of.

    The classic synchronous loop is refresh → reduce → apply with the
    exchange's collective a barrier inside every superstep.  Here the
    superstep is cut into stages and re-seamed across iterations:

      carry_i = (state_i refreshed, phase carry of superstep i)
      body:    merge carry → apply_i → refresh_{i+1}
               → phase_{i+1} (under the continuation cond)

    For a sync backend the phase carry IS the fully ⊕-reduced combine
    array and `merge` is the identity — the loop is op-for-op the old
    `GREEngine.run`.  For a pipelined backend the carry is the two-slot
    `Mailbox` and the flush collective issued inside `local_phase` has the
    whole local-tile combine between it and its consumer (the merge at the
    top of the next iteration) — the largest legal overlap window, since
    `refresh_{i+1}` transitively depends on the flushed values through
    `apply_i`.  ⊕-equivalence is exact either way: the same partial
    combines are folded, only later.

    `any_active` GLOBALIZES the termination predicate: it receives the
    shard-local "still work here" bool (frontier non-empty OR the
    backend's carry still holds in-flight contributions,
    `exchange.carry_pending`) and returns the mesh-global verdict — the
    distributed engine passes a pmax so all shards exit together and the
    collectives inside the phase stay matched; None is the single-shard
    identity.  The predicate is computed once per iteration (post-apply,
    carried into the loop cond) and is mesh-uniform, so every shard takes
    the same branch.  Evaluating it on the pre-refresh state is sound:
    apply zeroes agent-slot activity, so the global any over masters is
    what refresh would mirror.  Counting the carry matters only for the
    async shape: its ring holds remote partials flushed once per k
    supersteps, and its `dirty` bit holds improvements the next refresh
    has yet to push — an empty frontier with either set is not
    quiescence.  (The landed/local slots never need counting: merge
    consumes them before the predicate runs.)
    """
    globalize = any_active or (lambda local: local)
    pending = getattr(exchange, "carry_pending",
                      lambda carry: jnp.zeros((), dtype=bool))

    def keep_going(s, carry):
        local = jnp.any(s.active_scatter) | pending(carry)
        return (s.step < max_steps) & globalize(local)

    def phase(s, carry):
        s = exchange.refresh(s)
        return s, exchange.local_phase(engine, part, s, carry)

    def phase_if(go, s, carry):
        return jax.lax.cond(go, phase, lambda ss, cc: (ss, cc), s, carry)

    def body(c):
        s, carry, _ = c
        s = engine.apply(part, s, exchange.merge(carry))
        go = keep_going(s, carry)
        return phase_if(go, s, carry) + (go,)

    carry_init = exchange.carry_init(engine, part)
    go0 = keep_going(state, carry_init)
    carry0 = phase_if(go0, state, carry_init) + (go0,)
    final, _, _ = jax.lax.while_loop(lambda c: c[2], body, carry0)
    return final
