"""Streaming Agent-Graph partitioning (paper §5.2, Eq. 7-8).

Host-side (numpy) graph ingress, as in the paper where partitioning happens
in the loader.  Implements:

  * `greedy_partition` — the paper's greedy heuristic Eq. 8: place edge
    (u, v) on the partition maximizing src/dst affinity + load balance.
    `batch_size=1` is the exact serial stream (GRE-S); larger batches give
    the parallel-loader approximation (GRE-P / PowerGraph-oblivious, where
    loaders don't exchange heuristic state mid-stream).  Loader state is
    PACKED — the `[k, V]` has_src/has_dst presence booleans live as
    `[k, ceil(V/64)]` uint64 bitsets (8× smaller; placements bitwise
    identical, since Eq. 8 only ever reads presence as 0/1).  The
    degree-aware HDRF alternative with O(V·k/8) state lives in
    `repro.core.partition_stream`.
  * `hash_partition` — the random-hash baseline (Pregel/GraphLab default).
  * `assign_owners` — master placement (most-incident-edges heuristic) and
    contiguous relabeling so each partition's masters form a dense block
    (paper §6.1.1 local renumbering, adapted to uniform XLA shapes).
  * `partition_quality` — agents/vertex, equivalent edge-cut, cut-factor,
    and the PowerGraph vertex-cut replica metrics for comparison (§7.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.graph.structures import Graph

DELTA = 1.0  # paper: Δ = 1.0 in Eq. 8


def _presence(bits: np.ndarray, cols: np.ndarray) -> np.ndarray:
    """Read packed presence bits: `bits` is `[k, ceil(V/64)]` uint64,
    `cols` a batch of vertex ids; returns `[k, b]` float64 0/1 — the f/g
    terms of Eq. 8, exactly what `.astype(float)` of the old bool rows
    produced."""
    return ((bits[:, cols >> 6] >> (cols & 63).astype(np.uint64))
            & np.uint64(1)).astype(np.float64)


def _set_presence(bits: np.ndarray, rows: np.ndarray,
                  cols: np.ndarray) -> None:
    """Set presence bit `cols[t]` on partition row `rows[t]` in place
    (duplicates within the batch OR harmlessly)."""
    np.bitwise_or.at(bits, (rows, cols >> 6),
                     np.uint64(1) << (cols & 63).astype(np.uint64))


def hash_partition(graph: Graph, k: int, seed: int = 0) -> np.ndarray:
    """Random vertex sharding: each vertex and its out-edges to one random
    partition (paper §1 'hash-mapping')."""
    rng = np.random.default_rng(seed)
    vertex_part = rng.integers(0, k, size=graph.num_vertices)
    return vertex_part[graph.src].astype(np.int32)


def hash_edge_cut(graph: Graph, k: int, seed: int = 0) -> float:
    """The paper's Fig. 11b red line: TRADITIONAL edge-cut rate of random
    vertex sharding — fraction of edges whose endpoints land on different
    partitions (≈ 1 − 1/k on any graph).  Agent-graph's equivalent edge-cut
    is compared against this."""
    rng = np.random.default_rng(seed)
    vp = rng.integers(0, k, size=graph.num_vertices)
    return float(np.mean(vp[graph.src] != vp[graph.dst]))


def greedy_partition(graph: Graph, k: int, batch_size: int = 256,
                     seed: int = 0, num_loaders: int = 1,
                     sync_every: int = 0) -> np.ndarray:
    """Greedy streaming edge placement, Eq. 8:

      idx = argmax_i { f(u,i) + g(v,i) + (Max - Ne(i)) / (Δ + Max - Min) }

    f(u,i)=1 iff partition i already has an edge with source u; g(v,i)
    likewise for target v; the last term balances edge load.

    Modes (paper §5.2):
      num_loaders=1, batch_size=1      — exact serial stream (GRE-S);
      num_loaders=1, batch_size>1      — batched approximation;
      num_loaders>1, sync_every=0      — OBLIVIOUS: loaders never exchange
                                         heuristic state (PowerGraph-P);
      num_loaders>1, sync_every=N      — COORDINATED: loaders merge their
                                         has_src/has_dst/load state every N
                                         local batches (PowerGraph-S-like).
    """
    V, E = graph.num_vertices, graph.num_edges
    part = np.zeros(E, dtype=np.int32)
    # split the edge stream across loaders (contiguous ranges, as when each
    # machine reads its own file chunk)
    bounds = np.linspace(0, E, num_loaders + 1).astype(np.int64)
    words = (V + 63) >> 6      # packed presence: 1 bit per (partition, vertex)
    states = [dict(has_src=np.zeros((k, words), dtype=np.uint64),
                   has_dst=np.zeros((k, words), dtype=np.uint64),
                   ne=np.zeros(k, dtype=np.int64)) for _ in range(num_loaders)]
    rngs = [np.random.default_rng(seed + i) for i in range(num_loaders)]
    cursors = [int(bounds[i]) for i in range(num_loaders)]
    # coordinated mode: the load state already replicated into every loader
    # at the last sync — subtracted at the next merge so replicas are not
    # double-counted (each loader's ne = last merged global + its own new
    # placements; summing L copies holds the merged baseline L times).
    merged_ne = np.zeros(k, dtype=np.int64)
    n_batch = 0
    active = True
    while active:
        active = False
        for li in range(num_loaders):
            lo, hi_bound = cursors[li], int(bounds[li + 1])
            if lo >= hi_bound:
                continue
            active = True
            hi = min(lo + batch_size, hi_bound)
            st = states[li]
            u = graph.src[lo:hi]
            v = graph.dst[lo:hi]
            f = _presence(st["has_src"], u)                # [k, b]
            g = _presence(st["has_dst"], v)                # [k, b]
            ne = st["ne"]
            mx, mn = ne.max(), ne.min()
            balance = (mx - ne) / (DELTA + mx - mn)        # [k]
            score = f + g + balance[:, None]
            score += rngs[li].random(score.shape) * 1e-9   # tiebreak
            idx = np.argmax(score, axis=0).astype(np.int32)
            part[lo:hi] = idx
            _set_presence(st["has_src"], idx, u)
            _set_presence(st["has_dst"], idx, v)
            np.add.at(st["ne"], idx, 1)
            cursors[li] = hi
        n_batch += 1
        if sync_every and num_loaders > 1 and n_batch % sync_every == 0:
            merged_ne = merge_loader_states(states, merged_ne, num_loaders)
    return part


def merge_loader_states(states, merged_ne: np.ndarray,
                        num_loaders: int) -> np.ndarray:
    """Coordinated-mode sync point: merge the loaders' greedy heuristic
    state in place and return the new merged load baseline.

    The OR-merge of has_src/has_dst (bitwise on the packed uint64 rows;
    identical semantics on legacy bool arrays) is idempotent, but the load
    term must
    recover the TRUE global per-partition edge count: each loader's `ne`
    is the baseline replicated at the previous sync plus its own new
    placements, so summing the copies holds the baseline `num_loaders`
    times — subtract the surplus.  (The old `sum // num_loaders` shortcut
    instead shrank the counts L-fold, compressing the balance term's
    (Max - Ne) spread and mis-weighting it against edge affinity.)
    """
    hs = np.bitwise_or.reduce([s["has_src"] for s in states])
    hd = np.bitwise_or.reduce([s["has_dst"] for s in states])
    ne = (np.sum([s["ne"] for s in states], axis=0)
          - (num_loaders - 1) * merged_ne)
    for s in states:
        s["has_src"], s["has_dst"] = hs.copy(), hd.copy()
        s["ne"] = ne.copy()
    return ne


def accumulate_owner_counts(counts: np.ndarray, src: np.ndarray,
                            dst: np.ndarray, edge_part: np.ndarray) -> None:
    """Fold one edge batch into the `[k, V]` incidence counts that master
    placement argmaxes over — the chunked ingress calls this once per
    chunk, so streaming and monolithic owners agree exactly."""
    np.add.at(counts, (edge_part, src), 1)
    np.add.at(counts, (edge_part, dst), 1)


def owners_from_counts(counts: np.ndarray) -> np.ndarray:
    """Master placement from accumulated incidence counts: each vertex is
    owned by the partition holding most of its incident edges (ties →
    lowest id); isolated vertices hash (`v % k`)."""
    k, V = counts.shape
    owner = np.argmax(counts, axis=0).astype(np.int32)
    isolated = counts.sum(axis=0) == 0
    owner[isolated] = (np.arange(V)[isolated] % k).astype(np.int32)
    return owner


def assign_owners(graph: Graph, edge_part: np.ndarray, k: int) -> np.ndarray:
    """Master placement: each vertex is owned by the partition holding most
    of its incident edges (ties → lowest id); isolated vertices hash."""
    counts = np.zeros((k, graph.num_vertices), dtype=np.int64)
    accumulate_owner_counts(counts, graph.src, graph.dst, edge_part)
    return owners_from_counts(counts)


def rebalance_owners(owner: np.ndarray, k: int, cap: int) -> np.ndarray:
    """Cap masters per partition at `cap` by moving overflow vertices to the
    least-loaded partitions (keeps XLA shapes uniform).

    Infeasible inputs (more vertices than `k * cap` total capacity) raise a
    clear ValueError up front instead of crashing mid-move on an exhausted
    receiver list; with feasible inputs an over-cap partition always implies
    some partition below cap, so the move loop cannot strand.  Ties among
    equally-loaded receivers break to the lowest partition id.
    """
    owner = owner.copy()
    counts = np.bincount(owner, minlength=k)
    if int(counts.sum()) > k * cap:
        raise ValueError(
            f"cannot rebalance {int(counts.sum())} vertices into {k} "
            f"partitions of cap {cap} ({k * cap} total slots)")
    over = [i for i in range(k) if counts[i] > cap]
    # ascending order → `min` ties break to the lowest partition id
    under = [i for i in range(k) if counts[i] < cap]
    for i in over:
        vs = np.flatnonzero(owner == i)[cap:]
        for v in vs:
            j = min(under, key=lambda x: counts[x])
            owner[v] = j
            counts[j] += 1
            if counts[j] >= cap:
                under.remove(j)
        counts[i] = cap
    return owner


@dataclasses.dataclass
class PartitionQuality:
    k: int
    num_vertices: int
    num_edges: int
    num_scatters: int
    num_combiners: int
    edge_balance: float            # max partition edges / mean (1+ε of Eq. 7)
    agents_per_vertex: float       # cut-factor for Agent-Graph (Fig. 12b/13b)
    equivalent_edge_cut: float     # agents / E (Fig. 11b)
    scatter_rate: float            # scatters / (scatters + combiners) skew
    remote_dst_edge_fraction: float  # edges terminating at a combiner agent:
    # the ⊕ partials the pipelined exchange overlaps with local compute
    # (exchange="pipelined"; see agent_graph.split_edge_tiles)
    vertexcut_replicas: int        # PowerGraph replicas R for same placement
    vertexcut_cut_factor: float    # 2 * (R - V) / V (paper §7.2)
    replication_factor: float      # R / V — the streaming-partitioner
    # objective (HDRF et al. report RF; lower RF = fewer combiner/scatter
    # agents = less exchange traffic)
    vertexcut_comm: int            # 2 * (R - V) messages per superstep
    agent_comm: int                # |Vs| + |Vc| messages per superstep (§5.1)
    local_max_out_degree: int      # max LOCAL out-degree over partitions —
    # the value that poisons a flat [cap, max_deg] frontier tile
    degree_skew: float             # local max / mean local out-degree
    # Worst-case compacted-gather work as a fraction of the partition's
    # edge scan, at the default frontier capacity: >= 1.0 means that
    # compaction strategy can never beat the dense path on this placement
    # (the flat factor >= 1 is the old static dense fallback; the bucketed
    # factor staying < 1 on skewed placements is what degree buckets buy —
    # see repro.core.frontier).
    flat_tile_scan_factor: float
    bucket_tile_scan_factor: float
    # Visited fraction of the ingress-time Pallas block table over the
    # worst partition's dst-sorted, locally-DENSIFIED dst ids
    # (kernels.segment_combine.build_block_table over unique-rank
    # relabeled dsts — the ingress approximation of the per-device
    # relabeled slot space the engine's real table is built from): the
    # share of (dst block, edge block) pairs the dense-path kernel
    # computes; 1.0 would be the degenerate full table.  The
    # per-superstep DYNAMIC table's occupancy at a live frontier is
    # measured by benchmarks/bench_frontier.py.
    block_table_occupancy: float
    # Peak loader-heuristic state of the partitioner that PRODUCED this
    # placement, in bytes (0 when unknown — e.g. hash keeps none).  Passed
    # in by the caller: quality is computed from the placement alone, but
    # the bound (O(V·k/8) packed bitsets vs the old O(k·V) bools) is part
    # of the ingress-memory story bench_memory tracks.
    partitioner_state_bytes: int = 0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def partition_quality(graph: Graph, edge_part: np.ndarray,
                      owner: Optional[np.ndarray] = None,
                      k: Optional[int] = None,
                      partitioner_state_bytes: int = 0) -> PartitionQuality:
    k = k or int(edge_part.max()) + 1
    if owner is None:
        owner = assign_owners(graph, edge_part, k)
    V, E = graph.num_vertices, graph.num_edges

    # scatter agents: (u, i) pairs where partition i has edges with source u
    # but does not own u; combiners likewise for targets (paper §5.1 defs).
    src_key = edge_part.astype(np.int64) * V + graph.src
    dst_key = edge_part.astype(np.int64) * V + graph.dst
    src_pairs, local_deg = np.unique(src_key, return_counts=True)
    dst_pairs = np.unique(dst_key)
    s_part, s_v = src_pairs // V, src_pairs % V
    c_part, c_v = dst_pairs // V, dst_pairs % V
    n_scatter = int(np.sum(owner[s_v] != s_part))
    n_combiner = int(np.sum(owner[c_v] != c_part))

    # PowerGraph vertex-cut replicas for the SAME edge placement: a replica
    # of v exists on every partition touching v (master included in R).
    all_pairs = np.unique(np.concatenate([src_pairs, dst_pairs]))
    replicas = int(all_pairs.shape[0])
    # partitions with no edge of a vertex but owning it still host the master
    touched = np.zeros(V, dtype=bool)
    touched_part_of_owner = np.zeros(V, dtype=bool)
    av_part, av_v = all_pairs // V, all_pairs % V
    touched[av_v] = True
    touched_part_of_owner[av_v[av_part == owner[av_v]]] = True
    replicas += int(np.sum(touched & ~touched_part_of_owner))
    mirrors = replicas - int(np.sum(touched))

    ne = np.bincount(edge_part, minlength=k).astype(np.float64)
    agents = n_scatter + n_combiner

    # Frontier-compaction viability of this placement: local out-degrees
    # per (partition, source) pair — `local_deg` counts each src_pairs
    # entry, so `s_part` is already its partition — binned like the
    # engine's ingress.
    from repro.core.frontier import bucket_caps, default_cap
    from repro.graph.structures import DEFAULT_BUCKET_BOUNDS
    from repro.kernels.segment_combine import (block_table_occupancy,
                                               build_block_table)
    deg_part = s_part
    local_max_deg = int(local_deg.max()) if local_deg.size else 0
    skew = (local_max_deg / local_deg.mean()) if local_deg.size else 0.0
    cap = default_cap(int(-(-V // k)))
    flat_factor = bucket_factor = occupancy = 0.0
    bounds = np.asarray(DEFAULT_BUCKET_BOUNDS, dtype=np.int64)
    for i in range(k):
        degs = local_deg[deg_part == i]
        if degs.size == 0 or ne[i] == 0:
            continue
        flat_factor = max(flat_factor, cap * int(degs.max()) / ne[i])
        b = np.searchsorted(bounds, degs, side="left")
        sizes = tuple(int(np.sum(b == j)) for j in range(bounds.size + 1))
        maxd = tuple(int(degs[b == j].max()) if np.any(b == j) else 0
                     for j in range(bounds.size + 1))
        caps = bucket_caps(sizes, cap)
        bucket_factor = max(
            bucket_factor,
            sum(c * d for c, d in zip(caps, maxd)) / ne[i])
        # ingress-table sparsity skipping on this partition's dst-sorted
        # edges.  The engine builds its table over RELABELED local slot
        # ids (dense per device), not global ids — a locality-aware
        # placement packs a partition's global dsts into a narrow band of
        # [0, V) and would fake near-zero occupancy — so densify the
        # partition's dst ids (unique-rank relabel) as the ingress
        # approximation of its local slot space.
        _, inv = np.unique(graph.dst[edge_part == i], return_inverse=True)
        dst_sorted = np.sort(inv).astype(np.int32)
        table = build_block_table(dst_sorted, int(inv.max()) + 1,
                                  block_e=256, block_v=256)
        n_e = -(-dst_sorted.shape[0] // 256)
        occupancy = max(occupancy, block_table_occupancy(table, n_e))

    return PartitionQuality(
        k=k, num_vertices=V, num_edges=E,
        num_scatters=n_scatter, num_combiners=n_combiner,
        edge_balance=float(ne.max() / max(ne.mean(), 1.0)),
        agents_per_vertex=agents / V,
        equivalent_edge_cut=agents / max(E, 1),
        scatter_rate=n_scatter / max(agents, 1),
        remote_dst_edge_fraction=float(
            np.mean(owner[graph.dst] != edge_part)) if E else 0.0,
        vertexcut_replicas=replicas,
        vertexcut_cut_factor=2.0 * mirrors / V,
        replication_factor=replicas / max(V, 1),
        vertexcut_comm=2 * mirrors,
        agent_comm=agents,
        local_max_out_degree=local_max_deg,
        degree_skew=float(skew),
        flat_tile_scan_factor=float(flat_factor),
        bucket_tile_scan_factor=float(bucket_factor),
        block_table_occupancy=float(occupancy),
        partitioner_state_bytes=int(partitioner_state_bytes),
    )
