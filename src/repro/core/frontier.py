"""Frontier-compacted scatter-combine with degree-bucketed tiles.

The dense scatter path scans EVERY edge each superstep and masks by
`active_scatter[src]` — on a scale-free graph a BFS superstep with a 1%
frontier wastes 99% of its gather bandwidth (the inactive-vertex overhead
that dominates vertex-centric runtimes).  This module compacts instead:

  1. `jnp.nonzero(active, size=cap)` extracts at most `cap` active slots
     (fixed capacity keeps the shape static for jit);
  2. CSR `indptr` (built at ingress, `graph.structures.csr_layout`) gives
     each frontier slot's out-edge range; ranges are gathered into a padded
     edge tile via the src-sorted position index `csr_eidx` — destinations
     and edge props still read the canonical (dst-sorted) columns, so
     callers that rewrite `dst` (the overlap exchange's remote/local split)
     stay consistent;
  3. tile messages feed the SAME `segment_combine` ⊕ as the dense path.

A single `[cap, max_deg]` tile (`compact_scatter_combine`, kept as the
"flat" ablation strategy) pads every frontier slot to the partition's max
out-degree — ONE power-law hub inflates every row, to the point where the
padded tile out-scans the dense path and compaction had to be statically
gated off (`cap * max_deg >= E`).  The default path is therefore
DEGREE-BUCKETED (`bucketed_scatter_combine`): ingress bins slots by local
out-degree (`graph.structures.degree_buckets`, bounds ≈ ⌈log2 d⌉ collapsed
to ≤8/≤32/≤128/≤512/rest), and each bucket gathers its own
`[cap_b, max_deg_b]` tile.  Hub buckets hold few members, so their tile degrades to a per-hub
edge-range scan instead of poisoning `max_deg` for everyone — the static
hub gate disappears for power-law graphs.

Strategy selection is a `lax.cond` per superstep on the live frontier
count: dense above the density crossover, compacted below.  OVERFLOW is
guarded per bucket: a bucket whose live members exceed `cap_b` (a hub
activating every leaf of a star in one step) degrades to a dense scan
RESTRICTED to that bucket's sources — the other buckets stay compact, and
no vertex is ever dropped.

The compacted combine's kernel route is the plan's kernel stage
(`repro.core.plan.KernelPlan`): the XLA scatter-reduce by default; with
`use_pallas` the Pallas tile combine
(`kernels.segment_combine.tile_segment_combine_pallas`, interpret-mode on
CPU), which re-prunes its (dst block, edge block) prefetch table ON DEVICE
each superstep (`dynamic_block_table` — the tile's `dst` is data-dependent,
so the ingress-time static table cannot apply) unless the plan disables the
pruning pass (`dynamic_table=False`, the documented full-table fallback).
Invalid tile lanes carry the `num_segments` destination sentinel, which
every route drops: XLA scatter-reduces drop out-of-range indices, and the
pruning pass sorts sentinels past every real destination.

Edge tiles compose with the exchange layer's edge splits: a
`DevicePartition` whose columns hold only a destination CLASS — the
pipelined exchange's per-destination-shard remote tile or master-local tile
(`agent_graph.split_edge_tiles`), or the in-superstep `dst`-rewrite of
`AgentExchange(overlap=True)` — flows through unchanged, because
`gather_frontier_edge_tile` resolves CSR positions via `csr_eidx` into
whatever `dst`/`edge_props` columns the partition carries, and the ⊕
segment space is the caller's `num_segments` (compact combiner/master
spaces for the split tiles, full slot space otherwise).
"""
from __future__ import annotations

import functools
from typing import TYPE_CHECKING, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.plan import XLA_KERNEL, KernelPlan
from repro.core.vertex_program import segment_combine

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids a cycle
    from repro.core.engine import DevicePartition, EngineState
    from repro.core.plan import FrontierPlan
    from repro.core.vertex_program import VertexProgram

# Density threshold for auto strategy selection: compact below ~6% active
# (the literature's crossover for frontier-aware traversal sits at 5-10%).
FRONTIER_DENSITY = 1.0 / 16.0

# Calibrated capacity head-room: cap = GROWTH x the largest frontier
# observed during the probe supersteps (frontiers grow superstep over
# superstep; the overflow guard keeps larger-than-expected ones correct).
CAP_GROWTH = 4


def default_cap(num_slots: int,
                frontier_hist: Optional[Sequence[int]] = None) -> int:
    """Default frontier capacity, rounded up to a multiple of 8.

    With `frontier_hist` — live frontier sizes observed on the first
    superstep(s) (`GREEngine.calibrate_frontier_cap`) — the capacity is
    `CAP_GROWTH x` the largest observed size: a single-source traversal on
    a large shard starts from a handful of active slots, and sizing off the
    LIVE density instead of `num_slots` avoids compiling (and gathering
    into) a tile orders of magnitude wider than any real frontier.
    Without a histogram, falls back to the density threshold as a fixed
    fraction of `num_slots`.
    """
    if frontier_hist:
        cap = max(8, CAP_GROWTH * int(max(frontier_hist)))
    else:
        cap = max(8, int(num_slots * FRONTIER_DENSITY))
    return min(num_slots, -(-cap // 8) * 8)


def bucket_caps(sizes: Sequence[int], cap: int) -> tuple:
    """Split the global frontier capacity across buckets proportionally to
    membership.

    A frontier of ≤ `cap` live slots mixed like the degree distribution
    then fits every bucket's quota, and the worst-case tile work
    `sum_b cap_b * max_deg_b` stays ~`cap * mean_deg` instead of
    `cap * max_deg` per bucket (cap-sized tiles for two live hubs are how
    a bucketed gather quietly degenerates back to the dense scan).  Each
    nonempty bucket keeps a small floor so hubs always fit a few members;
    quotas are lane-rounded and clamped to the bucket size.  A bucket
    whose LIVE count exceeds its quota degrades to its restricted dense
    scan (`bucketed_scatter_combine`) — capacity skew costs performance,
    never correctness.
    """
    total = sum(sizes)
    if total == 0:
        return tuple(0 for _ in sizes)
    caps = []
    for s in sizes:
        if s == 0:
            caps.append(0)
            continue
        quota = -(-cap * s // total)            # ceil, proportional share
        quota = -(-quota // 8) * 8              # lane-friendly
        caps.append(min(s, max(quota, 8)))
    return tuple(caps)


def gather_frontier_edge_tile(part: "DevicePartition", frontier: jnp.ndarray,
                              cap: int, max_deg: Optional[int] = None):
    """Gather the frontier slots' out-edge ranges into a padded edge tile.

    `frontier` is the fixed-capacity active-slot list (`[cap]`, fill value
    `part.num_slots` — its `indptr` lookup clamps to a zero-length range).
    `max_deg` bounds the tile width (default: the partition-wide
    `csr_max_deg`; bucketed callers pass their bucket's own bound).
    Returns `(eid, valid)`: `eid [cap, max_deg]` are POSITIONS into the
    partition's canonical edge columns (`part.dst[eid]`,
    `part.edge_props[...][eid]`), `valid` masks the ragged lanes.  Because
    positions — not copies — are returned, the tile follows whatever
    destination columns the partition carries: the full dst-sorted slot
    space, the pipelined exchange's compact per-destination-class tiles,
    or the overlap exchange's in-superstep `dst` rewrite.
    """
    slots = part.num_slots
    if max_deg is None:
        max_deg = part.csr_max_deg
    start = part.csr_indptr[frontier]                    # clamped gather
    end = part.csr_indptr[jnp.minimum(frontier + 1, slots)]
    deg = end - start                                    # [cap], 0 on fills
    col = jnp.arange(max_deg, dtype=jnp.int32)
    valid = col[None, :] < deg[:, None]                  # [cap, max_deg]
    pos = jnp.where(valid, start[:, None] + col[None, :], 0)
    return part.csr_eidx[pos], valid


def _tile_combine(program: "VertexProgram", msgs: jnp.ndarray,
                  dst: jnp.ndarray, num_segments: int,
                  kernel: KernelPlan = XLA_KERNEL) -> jnp.ndarray:
    """⊕-reduce a gathered tile's messages through the plan's kernel stage.

    `dst` carries the `num_segments` sentinel on invalid lanes (both
    routes drop them).  The tile's `dst` is data-dependent, so the Pallas
    route re-prunes its block table ON DEVICE each superstep
    (`dynamic_block_table`) instead of using the ingress-time static table
    of the dense path; `kernel.dynamic_table=False` falls back to the
    degenerate full table."""
    p = program
    if not kernel.use_pallas:
        return segment_combine(msgs, dst, num_segments, p.monoid,
                               indices_are_sorted=False)
    from repro.kernels.segment_combine import tile_segment_combine_pallas
    payload = msgs.shape[1:]
    flat = msgs.reshape(msgs.shape[0], -1).astype(jnp.float32)
    out = tile_segment_combine_pallas(flat, dst.astype(jnp.int32),
                                      num_segments, p.monoid.name,
                                      dynamic=kernel.dynamic_table)
    return out.reshape((num_segments,) + payload).astype(p.msg_dtype)


def compact_scatter_combine(program: "VertexProgram", part: "DevicePartition",
                            state: "EngineState", num_segments: int,
                            cap: int, max_deg: Optional[int] = None,
                            frontier_mask: Optional[jnp.ndarray] = None,
                            kernel: KernelPlan = XLA_KERNEL) -> jnp.ndarray:
    """⊕-combine emitted only from the ≤ `cap` live slots' out-edges.

    `frontier_mask` restricts the frontier beyond `active_scatter` (the
    bucketed path passes `active & (bucket_id == b)`).  Bitwise-equal to
    the dense masked scan whenever the live mask fits in `cap` (for min/max
    monoids exactly; sum monoids up to float reorder of the segment
    reduction).  Callers must guard `|frontier| <= cap`.
    """
    p = program
    if max_deg is None:
        max_deg = part.csr_max_deg
    mask = state.active_scatter if frontier_mask is None else frontier_mask
    (frontier,) = jnp.nonzero(mask, size=cap, fill_value=part.num_slots)
    eid, valid = gather_frontier_edge_tile(part, frontier, cap, max_deg)
    # invalid lanes carry identity msgs AND the out-of-range dst sentinel:
    # XLA scatter-reduces drop them, and the Pallas dynamic pruning pass
    # sorts them past every real destination so their blocks prune away
    dst = jnp.where(valid, part.dst[eid], num_segments)
    gathered = jnp.take(state.scatter_data, frontier, axis=0,
                        fill_value=p.monoid.identity)    # [cap, *S]
    tile = jnp.broadcast_to(gathered[:, None],
                            (cap, max_deg) + gathered.shape[1:])
    flat = tile.reshape((cap * max_deg,) + gathered.shape[1:])
    eprop = (part.edge_props[p.needs_edge_prop][eid].reshape(-1)
             if p.needs_edge_prop else None)
    msgs = p.scatter_msg(flat, eprop)
    vmask = valid.reshape((-1,) + (1,) * (msgs.ndim - 1))
    msgs = jnp.where(vmask, msgs.astype(p.msg_dtype), p.monoid.identity)
    return _tile_combine(program, msgs, dst.reshape(-1), num_segments,
                         kernel=kernel)


def dense_masked_combine(program: "VertexProgram", part: "DevicePartition",
                         state: "EngineState", num_segments: int,
                         src_mask: jnp.ndarray) -> jnp.ndarray:
    """Dense every-edge scan with an explicit source-activity mask.

    The per-bucket OVERFLOW path: when bucket b's live members exceed its
    capacity, its contribution is recomputed as a dense scan restricted to
    `active & (bucket_id == b)` — all other buckets stay compact.
    """
    p = program
    eprop = (part.edge_props[p.needs_edge_prop]
             if p.needs_edge_prop else None)
    gathered = jnp.take(state.scatter_data, part.src, axis=0,
                        fill_value=p.monoid.identity)
    msgs = p.scatter_msg(gathered, eprop)
    live = jnp.take(src_mask, part.src, axis=0,
                    fill_value=False) & part.edge_mask
    live = live.reshape(live.shape + (1,) * (msgs.ndim - live.ndim))
    msgs = jnp.where(live, msgs.astype(p.msg_dtype), p.monoid.identity)
    return segment_combine(msgs, part.dst, num_segments, p.monoid,
                           indices_are_sorted=part.edges_sorted_by_dst)


def bucketed_scatter_combine(program: "VertexProgram",
                             part: "DevicePartition", state: "EngineState",
                             num_segments: int, caps: Sequence[int],
                             kernel: KernelPlan = XLA_KERNEL) -> jnp.ndarray:
    """Degree-bucketed compacted ⊕ over the live frontier.

    `bucket_id` partitions slots with out-edges, so summing the per-bucket
    partial combines touches every active out-edge exactly once.  Each
    bucket either gathers its own `[cap_b, max_deg_b]` tile (live members
    fit) or — per-bucket `lax.cond` — degrades to a bucket-restricted
    dense scan (overflow).  Degree-0 slots carry `bucket_id == -1`: they
    can never emit a message, so no bucket spends capacity on them.
    """
    p = program
    partials = []
    for b, (cap_b, max_deg_b) in enumerate(zip(caps, part.bucket_max_deg)):
        if cap_b <= 0 or max_deg_b <= 0:
            continue  # statically empty bucket
        mask_b = state.active_scatter & (part.bucket_id == b)
        n_b = jnp.sum(mask_b)
        partials.append(jax.lax.cond(
            n_b <= cap_b,
            lambda m, c=cap_b, d=max_deg_b: compact_scatter_combine(
                program, part, state, num_segments, c, max_deg=d,
                frontier_mask=m, kernel=kernel),
            lambda m: dense_masked_combine(program, part, state,
                                           num_segments, m),
            mask_b))
    return functools.reduce(p.monoid.op, partials)


def bucketed_tile_occupancy(part: "DevicePartition", active: jnp.ndarray,
                            caps: Sequence[int],
                            num_segments: Optional[int] = None,
                            block_e: int = 256, block_v: int = 256) -> tuple:
    """Measured dynamic-block-table occupancy for a live frontier.

    Replays the bucketed gather for `active` (each bucket's `[cap_b,
    max_deg_b]` tile, invalid lanes sentineled) and builds each tile's
    per-superstep `dynamic_block_table`, returning ``(visited, total)``
    (dst block, edge block) pair counts summed over buckets — `total` is
    what the degenerate full table would visit.  Diagnostic only (eager;
    `benchmarks/bench_frontier.py` emits `visited / total` as
    `block_table_occupancy`); the in-graph pruning pass inside the kernel
    route computes the same tables.
    """
    from repro.kernels.segment_combine import dynamic_block_table
    nseg = num_segments or part.num_slots
    visited = total = 0
    for b, (cap_b, max_deg_b) in enumerate(zip(caps, part.bucket_max_deg)):
        if cap_b <= 0 or max_deg_b <= 0:
            continue
        mask_b = active & (part.bucket_id == b)
        (frontier,) = jnp.nonzero(mask_b, size=cap_b,
                                  fill_value=part.num_slots)
        eid, valid = gather_frontier_edge_tile(part, frontier, cap_b,
                                               max_deg_b)
        dst = jnp.sort(jnp.where(valid, part.dst[eid], nseg).reshape(-1))
        table = dynamic_block_table(dst, nseg, block_e, block_v)
        n_e = table.shape[1]
        visited += int(jnp.sum(table < n_e))
        total += table.shape[0] * n_e
    return visited, total


def frontier_scatter_combine(program: "VertexProgram",
                             part: "DevicePartition", state: "EngineState",
                             num_segments: int, plan: "FrontierPlan",
                             dense_fn,
                             kernel: KernelPlan = XLA_KERNEL) -> jnp.ndarray:
    """Per-superstep strategy selection with capacity/overflow guards.

    `plan` is the static per-partition resolution
    (`repro.core.plan.resolve_frontier`, kind "flat" or "bucketed" — the
    dense kind never reaches here).  `dense_fn()` must produce the dense
    masked combine over the same `num_segments`; it is taken whenever the
    live frontier exceeds the total compacted capacity (density crossover
    AND whole-frontier overflow protection in one predicate — per-bucket
    skew overflow is guarded inside the bucketed branch).  `kernel` is the
    plan's combine-kernel stage, threaded into the tile combines.
    """
    kind, caps = plan
    n_active = jnp.sum(state.active_scatter)
    if kind == "flat":
        return jax.lax.cond(
            n_active <= caps,
            lambda _: compact_scatter_combine(program, part, state,
                                              num_segments, caps,
                                              kernel=kernel),
            lambda _: dense_fn(),
            operand=None)
    total_cap = sum(caps)
    return jax.lax.cond(
        n_active <= total_cap,
        lambda _: bucketed_scatter_combine(program, part, state,
                                           num_segments, caps,
                                           kernel=kernel),
        lambda _: dense_fn(),
        operand=None)
