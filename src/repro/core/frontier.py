"""Frontier-compacted scatter-combine (ROADMAP item 1).

The dense scatter path scans EVERY edge each superstep and masks by
`active_scatter[src]` — on a scale-free graph a BFS superstep with a 1%
frontier wastes 99% of its gather bandwidth (the inactive-vertex overhead
that dominates vertex-centric runtimes).  This module compacts instead:

  1. `jnp.nonzero(active, size=cap)` extracts at most `cap` active slots
     (fixed capacity keeps the shape static for jit);
  2. CSR `indptr` (built at ingress, `graph.structures.csr_layout`) gives
     each frontier slot's out-edge range; ranges are gathered into a padded
     `[cap, max_deg]` edge tile via the src-sorted position index
     `csr_eidx` — destinations and edge props still read the canonical
     (dst-sorted) columns, so callers that rewrite `dst` (the overlap
     exchange's remote/local split) stay consistent;
  3. tile messages feed the SAME `segment_combine` ⊕ as the dense path.

Per-superstep strategy selection is a `lax.cond` on the live frontier
count: dense above the density threshold, compacted below.  The predicate
doubles as the OVERFLOW GUARD — a frontier larger than `cap` (e.g. a hub
activating every leaf of a star in one step) falls back to the dense scan
instead of silently dropping vertices.

The compacted combine always takes the XLA scatter-reduce: its `dst` tile
is data-dependent (gathered per superstep), and the Pallas kernel needs the
static ingress-time block table (`kernels.segment_combine`).

Edge tiles compose with the exchange layer's edge splits: a
`DevicePartition` whose columns hold only a destination CLASS — the
pipelined exchange's per-destination-shard remote tile or master-local tile
(`agent_graph.split_edge_tiles`), or the in-superstep `dst`-rewrite of
`AgentExchange(overlap=True)` — flows through unchanged, because
`gather_frontier_edge_tile` resolves CSR positions via `csr_eidx` into
whatever `dst`/`edge_props` columns the partition carries, and the ⊕
segment space is the caller's `num_segments` (compact combiner/master
spaces for the split tiles, full slot space otherwise).
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.core.vertex_program import segment_combine

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids a cycle
    from repro.core.engine import DevicePartition, EngineState
    from repro.core.vertex_program import VertexProgram

# Density threshold for auto strategy selection: compact below ~6% active
# (the literature's crossover for frontier-aware traversal sits at 5-10%).
FRONTIER_DENSITY = 1.0 / 16.0


def default_cap(num_slots: int) -> int:
    """Default frontier capacity: the density threshold as a slot count,
    rounded up to a multiple of 8 (lane-friendly)."""
    cap = max(8, int(num_slots * FRONTIER_DENSITY))
    return min(num_slots, -(-cap // 8) * 8)


def gather_frontier_edge_tile(part: "DevicePartition", frontier: jnp.ndarray,
                              cap: int):
    """Gather the frontier slots' out-edge ranges into a padded edge tile.

    `frontier` is the fixed-capacity active-slot list (`[cap]`, fill value
    `part.num_slots` — its `indptr` lookup clamps to a zero-length range).
    Returns `(eid, valid)`: `eid [cap, max_deg]` are POSITIONS into the
    partition's canonical edge columns (`part.dst[eid]`,
    `part.edge_props[...][eid]`), `valid` masks the ragged lanes.  Because
    positions — not copies — are returned, the tile follows whatever
    destination columns the partition carries: the full dst-sorted slot
    space, the pipelined exchange's compact per-destination-class tiles,
    or the overlap exchange's in-superstep `dst` rewrite.
    """
    slots = part.num_slots
    max_deg = part.csr_max_deg
    start = part.csr_indptr[frontier]                    # clamped gather
    end = part.csr_indptr[jnp.minimum(frontier + 1, slots)]
    deg = end - start                                    # [cap], 0 on fills
    col = jnp.arange(max_deg, dtype=jnp.int32)
    valid = col[None, :] < deg[:, None]                  # [cap, max_deg]
    pos = jnp.where(valid, start[:, None] + col[None, :], 0)
    return part.csr_eidx[pos], valid


def compact_scatter_combine(program: "VertexProgram", part: "DevicePartition",
                            state: "EngineState", num_segments: int,
                            cap: int) -> jnp.ndarray:
    """⊕-combine emitted only from the ≤ `cap` active slots' out-edges.

    Bitwise-equal to the dense masked scan whenever the frontier fits in
    `cap` (for min/max monoids exactly; sum monoids up to float reorder of
    the segment reduction).  Callers must guard `|frontier| <= cap`.
    """
    p = program
    max_deg = part.csr_max_deg
    (frontier,) = jnp.nonzero(state.active_scatter, size=cap,
                              fill_value=part.num_slots)
    eid, valid = gather_frontier_edge_tile(part, frontier, cap)
    dst = part.dst[eid]                 # invalid lanes carry identity msgs
    gathered = jnp.take(state.scatter_data, frontier, axis=0,
                        fill_value=p.monoid.identity)    # [cap, *S]
    tile = jnp.broadcast_to(gathered[:, None],
                            (cap, max_deg) + gathered.shape[1:])
    flat = tile.reshape((cap * max_deg,) + gathered.shape[1:])
    eprop = (part.edge_props[p.needs_edge_prop][eid].reshape(-1)
             if p.needs_edge_prop else None)
    msgs = p.scatter_msg(flat, eprop)
    vmask = valid.reshape((-1,) + (1,) * (msgs.ndim - 1))
    msgs = jnp.where(vmask, msgs.astype(p.msg_dtype), p.monoid.identity)
    return segment_combine(msgs, dst.reshape(-1), num_segments, p.monoid,
                           indices_are_sorted=False)


def frontier_scatter_combine(program: "VertexProgram", part: "DevicePartition",
                             state: "EngineState", num_segments: int,
                             cap: int, dense_fn) -> jnp.ndarray:
    """Per-superstep strategy selection with the capacity/overflow guard.

    `dense_fn()` must produce the dense masked combine over the same
    `num_segments`; it is taken whenever the frontier exceeds `cap` (density
    crossover AND overflow protection in one predicate).
    """
    n_active = jnp.sum(state.active_scatter)
    return jax.lax.cond(
        n_active <= cap,
        lambda _: compact_scatter_combine(program, part, state,
                                          num_segments, cap),
        lambda _: dense_fn(),
        operand=None)
