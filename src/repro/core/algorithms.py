"""Benchmark vertex programs (paper Fig. 3): PageRank, SSSP, CC (+ BFS).

Each is a direct transcription of the paper's C++ Scatter-Combine code into
the functional `VertexProgram` API.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.vertex_program import MONOIDS, VertexProgram

DAMPING = 0.85


def pagerank_program() -> VertexProgram:
    """Paper Fig. 3a / Eq. 6.

    scatter: msg = pr[src] / outdeg[src]   (scatter_data holds pr/outdeg)
    combine: pr_combine[dst] += msg        (⊕ = sum)
    apply:   pr = 0.15 + 0.85 * pr_combine; reset accumulator.
    Iterative: every vertex stays active; run a fixed number of supersteps.
    """

    def scatter_msg(src_scatter, _eprop):
        return src_scatter  # scatter_data already holds pr/outdeg

    def apply_fn(vertex_data, combined, aux):
        pr = (1.0 - DAMPING) + DAMPING * combined
        outdeg = jnp.maximum(aux["out_degree"], 1.0)
        return pr, pr / outdeg, jnp.ones_like(pr, dtype=bool)

    return VertexProgram(
        name="pagerank", monoid=MONOIDS["sum"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=lambda n, aux: jnp.ones(n, jnp.float32),
        # First superstep scatters pr0/outdeg = 1/outdeg (paper Eq. 6a).
        init_scatter_data=lambda n, aux: 1.0 / jnp.maximum(aux["out_degree"], 1.0),
        init_active=lambda n, aux: jnp.ones(n, dtype=bool),
        halts=False,
    )


def sssp_program(num_sources: Optional[int] = None) -> VertexProgram:
    """Paper Fig. 3b: Bellman-Ford label correcting.

    scatter: msg = oldDistance[src] + weight(e)
    combine: distance[dst] = min(distance[dst], msg); activate if improved
    apply:   oldDistance = distance; activate_scatter
    assert_to_halt: deactivate after scattering (frontier semantics).

    `num_sources=D` batches D roots INTO the payload: states become
    `[slots, D]`, ⊕ is elementwise min, and a vertex stays active while ANY
    lane improves — one traversal pass serves all D sources, amortizing the
    topology traffic (seed lane d with `init_state(part, source=[s_0..s_D])`).
    """
    D = num_sources

    def scatter_msg(src_scatter, weight):
        return src_scatter + (weight if D is None else weight[:, None])

    def combine_activates(old_vd, combined):
        improved = combined < old_vd  # strictly improving messages only
        return improved if D is None else jnp.any(improved, axis=-1)

    def apply_fn(vertex_data, combined, _aux):
        dist = jnp.minimum(vertex_data, combined)
        return dist, dist, jnp.ones(dist.shape[0], dtype=bool)

    shape = (lambda n: (n,)) if D is None else (lambda n: (n, D))
    return VertexProgram(
        name="sssp" if D is None else f"sssp_x{D}", monoid=MONOIDS["min"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=lambda n, aux: jnp.full(shape(n), jnp.inf, jnp.float32),
        init_scatter_data=lambda n, aux: jnp.full(shape(n), jnp.inf, jnp.float32),
        init_active=lambda n, aux: jnp.zeros(n, dtype=bool),  # source set via engine
        combine_activates=combine_activates,
        halts=True, needs_edge_prop="weight", invalidation="path",
        payload_shape=() if D is None else (D,),
        # per-lane improvement = the min-fold actually lowering a distance;
        # a lane with no improvement anywhere has converged (label
        # correcting is monotone, so a quiet lane stays quiet)
        lane_activates=None if D is None else (lambda vd, c: c < vd),
    )


def cc_program() -> VertexProgram:
    """Paper Fig. 3c: label propagation on undirected graphs.

    Every vertex starts labeled with its own id and active; labels propagate
    by min-combine until no label changes.
    """

    def scatter_msg(src_scatter, _eprop):
        return src_scatter

    def combine_activates(old_vd, combined):
        return combined < old_vd

    def apply_fn(vertex_data, combined, _aux):
        label = jnp.minimum(vertex_data, combined)
        return label, label, jnp.ones_like(label, dtype=bool)

    def init_labels(n, aux):
        # labels are GLOBAL vertex ids (aux carries them so distributed
        # shards label by original id, not local slot index)
        if "global_id" in aux:
            gid = aux["global_id"]
            return jnp.where(gid >= 0, gid, jnp.inf).astype(jnp.float32)
        return jnp.arange(n, dtype=jnp.float32)

    return VertexProgram(
        name="cc", monoid=MONOIDS["min"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=init_labels,
        init_scatter_data=init_labels,
        init_active=lambda n, aux: jnp.ones(n, dtype=bool),
        # label propagation's support is CYCLIC (a split-off component's
        # stale labels certify each other), so removals invalidate by
        # forward reachability, not the path worklist (repro.core.incremental)
        combine_activates=combine_activates, halts=True,
        invalidation="component",
    )


def bfs_program(num_sources: Optional[int] = None) -> VertexProgram:
    """BFS depth = SSSP with unit weights (paper §4.2 traversal family).

    `num_sources=D` is the multi-source batched variant: payload `(D,)`,
    ⊕ = elementwise min, one pass for D roots (see `sssp_program`).
    """
    D = num_sources

    def scatter_msg(src_scatter, _eprop):
        return src_scatter + 1.0

    def combine_activates(old_vd, combined):
        improved = combined < old_vd
        return improved if D is None else jnp.any(improved, axis=-1)

    def apply_fn(vertex_data, combined, _aux):
        depth = jnp.minimum(vertex_data, combined)
        return depth, depth, jnp.ones(depth.shape[0], dtype=bool)

    shape = (lambda n: (n,)) if D is None else (lambda n: (n, D))
    return VertexProgram(
        name="bfs" if D is None else f"bfs_x{D}", monoid=MONOIDS["min"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=lambda n, aux: jnp.full(shape(n), jnp.inf, jnp.float32),
        init_scatter_data=lambda n, aux: jnp.full(shape(n), jnp.inf, jnp.float32),
        init_active=lambda n, aux: jnp.zeros(n, dtype=bool),
        combine_activates=combine_activates, halts=True,
        invalidation="path",
        payload_shape=() if D is None else (D,),
        lane_activates=None if D is None else (lambda vd, c: c < vd),
    )


def ppr_push_program(num_sources: int, alpha: float = 0.15,
                     eps: float = 1e-4) -> VertexProgram:
    """Personalized PageRank by monotone forward push (Andersen-Chung-Lang),
    batched over D payload lanes — the third traversal family the serving
    layer (repro.serving.graph_scheduler) answers.

    Per (vertex, lane) the state is an (estimate p, held residual r) pair:
    `vertex_data` is `[n, D, 2]`.  A vertex whose total residual in lane d
    exceeds `eps` PUSHES: p += α·r, and (1-α)·r/outdeg is scattered along
    its out-edges (⊕ = sum accumulates incoming residual mass); sub-`eps`
    residual is held until new mass arrives.  Active messages ARE the
    pushes, so the frontier is exactly the above-threshold vertices and a
    lane with no push anywhere has converged (`lane_activates`) —
    monotonicity (p only grows, residual mass only moves or shrinks) gives
    the same quiet-stays-quiet guarantee as the min-monoid traversals.

    Seeding (`seed_sources`) performs the source's OWN first push at
    admission time — p[s] = α, scatter share (1-α)/outdeg(s) staged — so
    the very next superstep delivers it; lanes evolve independently
    (pushes are decided per lane), which is what makes lane recycling
    bitwise-safe for this program despite the sum monoid.
    """
    D = num_sources

    def scatter_msg(src_scatter, _eprop):
        return src_scatter  # scatter_data already holds (1-α)·r/outdeg

    def combine_activates(_old_vd, combined):
        return jnp.any(combined > 0.0, axis=-1)  # received any mass

    def apply_fn(vertex_data, combined, aux):
        p_est, r_hold = vertex_data[..., 0], vertex_data[..., 1]
        r_total = r_hold + combined
        push = r_total > eps
        new_p = p_est + jnp.where(push, alpha * r_total, 0.0)
        deg = jnp.maximum(aux["out_degree"], 1.0)[:, None]
        new_sd = jnp.where(push, (1.0 - alpha) * r_total / deg, 0.0)
        new_r = jnp.where(push, 0.0, r_total)
        new_vd = jnp.stack([new_p, new_r], axis=-1)
        return new_vd, new_sd, jnp.any(push, axis=-1)

    def lane_activates(vertex_data, combined):
        return (vertex_data[..., 1] + combined) > eps  # a push will happen

    def seed_sources(vd, sd, src, lanes, aux):
        deg = jnp.maximum(aux["out_degree"], 1.0)
        n = deg.shape[0]
        share = (1.0 - alpha) / jnp.take(deg, jnp.minimum(src, n - 1))
        vd = vd.at[src, lanes, 0].set(alpha, mode="drop")
        vd = vd.at[src, lanes, 1].set(0.0, mode="drop")
        sd = sd.at[src, lanes].set(share, mode="drop")
        return vd, sd

    return VertexProgram(
        name=f"ppr_x{D}", monoid=MONOIDS["sum"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=lambda n, aux: jnp.zeros((n, D, 2), jnp.float32),
        init_scatter_data=lambda n, aux: jnp.zeros((n, D), jnp.float32),
        init_active=lambda n, aux: jnp.zeros(n, dtype=bool),
        combine_activates=combine_activates, halts=True,
        payload_shape=(D,),
        lane_activates=lane_activates, seed_sources=seed_sources,
        lane_view=lambda vd, lane: vd[:, lane, 0],
    )


def gnn_aggregate_program(d_feat: int,
                          edge_weighted: bool = False) -> VertexProgram:
    """One-superstep neighborhood aggregation with feature-vector payloads.

    The GNN layer propagation h' = A·h IS the Scatter-Combine primitive with
    payload_shape = (D,): scatter the [slots, D] feature rows, ⊕ = sum at
    the destinations (optionally edge-weighted, e.g. GCN's symmetric
    normalization via the "edge_norm" edge property).  Running it through
    the engine gives full-batch GNN aggregation the same exchange backends
    and the Pallas MXU combine as every other workload.
    """

    def scatter_msg(src_scatter, edge_norm):
        if edge_norm is None:
            return src_scatter
        return src_scatter * edge_norm[:, None]

    def apply_fn(vertex_data, combined, _aux):
        return combined, combined, jnp.zeros(combined.shape[0], dtype=bool)

    return VertexProgram(
        name="gnn_aggregate", monoid=MONOIDS["sum"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=lambda n, aux: jnp.zeros((n, d_feat), jnp.float32),
        init_scatter_data=lambda n, aux: jnp.zeros((n, d_feat), jnp.float32),
        init_active=lambda n, aux: jnp.ones(n, dtype=bool),
        halts=True, payload_shape=(d_feat,),
        needs_edge_prop="edge_norm" if edge_weighted else None,
    )


def degree_program() -> VertexProgram:
    """In-degree via one superstep of sum-combine (sanity workload)."""

    def scatter_msg(src_scatter, _eprop):
        return jnp.ones_like(src_scatter)

    def apply_fn(vertex_data, combined, _aux):
        return combined, combined, jnp.zeros_like(combined, dtype=bool)

    return VertexProgram(
        name="degree", monoid=MONOIDS["sum"],
        scatter_msg=scatter_msg, apply_fn=apply_fn,
        init_vertex_data=lambda n, aux: jnp.zeros(n, jnp.float32),
        init_scatter_data=lambda n, aux: jnp.zeros(n, jnp.float32),
        init_active=lambda n, aux: jnp.ones(n, dtype=bool),
        halts=True,
    )
