"""Warm-start invalidation for incremental re-convergence (docs/incremental.md).

After a batch of edge mutations, `rerun_incremental` re-converges from the
previous fixed point instead of from scratch.  Added edges are easy: under a
min monoid, re-delivering a fixed-point value is idempotent, so activating
the add endpoints and letting the normal frontier machinery run is both safe
and exact.  Removals are the hard half — a min-monoid fixed point can hold
values that were only attainable THROUGH a removed edge, and min cannot
retract — so this module computes the set of (vertex, lane) entries whose
values are no longer certified by the surviving graph and resets them to the
program's initial values before re-seeding.

Two invalidation policies (`VertexProgram.invalidation`):

* ``"path"`` (BFS/SSSP) — support-based worklist invalidation in the
  Ramalingam–Reps tradition: entry ``(x, d)`` keeps its value iff some live
  in-edge ``(w, x)`` from an untainted ``w`` reproduces it BITWISE
  (``scatter_msg(val[w], prop) == val[x]``), or ``x`` is lane ``d``'s
  source.  Uncertified entries taint, and entries they were supporting are
  re-examined, wave by wave — work proportional to the affected region, not
  the graph.  Sound because these programs' messages are strictly
  increasing (``+1`` / positive weights), so stale values cannot support
  each other around a cycle.

* ``"component"`` (CC) — label propagation has CYCLIC support (two stale
  labels in a split-off component certify each other), so the worklist
  under-taints.  Instead, taint everything forward-reachable from the
  removed edges' destinations over the PRE-delta edge set — the region
  whose in-reachable set (and hence min label) the removal could have
  changed.

All passes run host-side in numpy on the master-vertex id space; the message
check goes through the program's own ``scatter_msg`` on f32 inputs, so the
certificate is bitwise-identical to what the device superstep would deliver.
"""
from __future__ import annotations

import numpy as np


def check_supported(program, report) -> None:
    """Raise unless `program` can warm-start over this delta.

    Iterative programs (halts=False, e.g. PageRank) always can — they
    recompute from whatever state they hold.  Halting traversals need the
    min monoid (idempotent re-delivery), and removals additionally need an
    invalidation policy.
    """
    if not program.halts:
        return
    if program.monoid.name != "min":
        raise ValueError(
            f"{program.name}: incremental warm start needs an idempotent "
            f"(min) monoid or an iterative program; a halting "
            f"{program.monoid.name}-monoid traversal cannot reuse a prior "
            "fixed point (already-delivered mass does not re-deliver)")
    if report.num_removed and program.invalidation is None:
        raise ValueError(
            f"{program.name}: edge removals need an invalidation policy "
            "(VertexProgram.invalidation = 'path' or 'component')")


def source_mask(shape, source) -> np.ndarray:
    """Protected entries the invalidation pass must never taint: lane d's
    source vertex holds the seeded 0.0 by definition, not by edge support.
    `source` follows `init_state` conventions (scalar, or a per-lane
    sequence with None/negative = unseeded)."""
    out = np.zeros(shape, dtype=bool)
    if source is None:
        return out
    if np.ndim(source) == 0:
        out[int(source)] = True
        return out
    for d, sv in enumerate(source):
        if sv is not None and int(sv) >= 0:
            out[int(sv), d] = True
    return out


def support_taint(program, num_vertices, src, dst, eprop, values,
                  suspect, protected) -> np.ndarray:
    """The "path" policy: worklist certification over the NEW live edges.

    `values` is the previous fixed point (`[V]` or `[V, D]` f32, original
    vertex order); `suspect` seeds the worklist (destinations of removed
    edges); `protected` entries (sources) never taint.  Returns the tainted
    mask, same shape as `values`.
    """
    import jax.numpy as jnp
    finite = np.isfinite(values)
    eligible = finite & ~protected
    if src.shape[0] == 0:
        return suspect & eligible
    msgs = np.asarray(program.scatter_msg(
        jnp.asarray(values[src]),
        None if eprop is None else jnp.asarray(eprop)))
    # bitwise certificate: edge (w, x) supports val[x] iff re-scattering
    # w's value reproduces it exactly (same f32 ops as the device path)
    support_edge = msgs == values[dst]
    tainted = np.zeros_like(suspect)
    pending = suspect & eligible
    while True:
        supported = np.zeros_like(tainted)
        np.logical_or.at(supported, dst, support_edge & ~tainted[src])
        newly = pending & ~supported & ~tainted
        if not newly.any():
            return tainted
        tainted |= newly
        # entries whose certificate ran through a newly tainted supporter
        # must be re-examined against the shrunken untainted set
        child = np.zeros_like(tainted)
        np.logical_or.at(child, dst, support_edge & newly[src])
        pending |= child & eligible


def reach_taint(num_vertices, src, dst, seeds) -> np.ndarray:
    """The "component" policy: forward reachability from `seeds` over the
    given edge set (pre-delta: survivors + removed).  Returns `[V]` bool."""
    tainted = np.zeros(num_vertices, dtype=bool)
    if seeds.shape[0] == 0:
        return tainted
    tainted[seeds] = True
    if src.shape[0] == 0:
        return tainted
    while True:
        reach = np.zeros(num_vertices, dtype=bool)
        np.logical_or.at(reach, dst, tainted[src])
        new = reach & ~tainted
        if not new.any():
            return tainted
        tainted |= new


def compute_taint(program, num_vertices, live_src, live_dst, live_prop,
                  values, report, protected) -> np.ndarray:
    """Dispatch on `program.invalidation`; returns a mask shaped like
    `values` (all-False when the delta removed nothing)."""
    if report.num_removed == 0:
        return np.zeros(values.shape, dtype=bool)
    if program.invalidation == "component":
        old_src = np.concatenate([live_src, report.removed_src])
        old_dst = np.concatenate([live_dst, report.removed_dst])
        t = reach_taint(num_vertices, old_src, old_dst, report.removed_dst)
        t = np.broadcast_to(
            t.reshape((num_vertices,) + (1,) * (values.ndim - 1)),
            values.shape).copy()
        return t & np.isfinite(values) & ~protected
    suspect = np.zeros(values.shape, dtype=bool)
    suspect[report.removed_dst] = True
    return support_taint(program, num_vertices, live_src, live_dst,
                         live_prop, values, suspect, protected)


def warm_seed_active(num_vertices, live_src, live_dst, tainted_any,
                     added_src, init_active) -> np.ndarray:
    """The warm-start activity seeds (`[V]` bool, master space):

    * sources of ADDED edges — their (possibly finite) values must travel
      the new edges;
    * in-neighbors of tainted vertices — they re-deliver the surviving
      certified values into the reset region (min idempotence makes the
      re-delivery a no-op everywhere it is not needed);
    * tainted vertices the program itself seeds active (`init_active`,
      e.g. CC re-scatters its reset self-labels).

    An empty delta yields an empty seed set: the warm run terminates at
    superstep 0 with the previous fixed point intact.
    """
    act = np.zeros(num_vertices, dtype=bool)
    if added_src.shape[0]:
        act[added_src] = True
    if tainted_any.any():
        if live_src.shape[0]:
            into_taint = tainted_any[live_dst]
            act[live_src[into_taint]] = True
        act |= tainted_any & init_active
    return act
