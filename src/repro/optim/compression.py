"""int8 error-feedback gradient compression (beyond-paper distributed trick).

Before the cross-replica gradient reduce, each shard quantizes (grad +
error_carry) to int8 with a per-tensor scale; the dequantization error is
carried to the next step (error feedback keeps SGD/Adam convergence, cf.
1-bit SGD / EF-SGD literature).  Cuts DP gradient all-reduce bytes 4×
(fp32) or 2× (bf16).

Used by `launch/train.py --grad-compression`: gradients are compressed,
psum'd in int32, and dequantized — all inside the jitted step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(tree, error):
    """Returns (quantized int8 tree, scales, new_error)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_e = g - q.astype(jnp.float32) * scale
        return q, scale, new_e

    flat, treedef = jax.tree.flatten(tree)
    eflat = jax.tree.leaves(error)
    qs, scales, errs = zip(*[one(g, e) for g, e in zip(flat, eflat)])
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, errs))


def decompress(qtree, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qtree, scales)


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(tree, error, axis_name):
    """Error-feedback compressed all-reduce over `axis_name` (inside
    shard_map): int8 quantize -> int32 psum -> dequant with mean scale."""
    q, scales, new_error = compress(tree, error)
    summed = jax.tree.map(
        lambda x: jax.lax.psum(x.astype(jnp.int32), axis_name), q)
    n = jax.lax.psum(1, axis_name)
    mean_scale = jax.tree.map(
        lambda s: jax.lax.psum(s, axis_name) / n, scales)
    out = jax.tree.map(lambda x, s: x.astype(jnp.float32) * s,
                       summed, mean_scale)
    return out, new_error
