"""AdamW with global-norm clipping and schedules (functional, pytree state).

Moments are kept in fp32 regardless of param dtype (mixed-precision
training); the update casts back to the param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gn = global_norm(g32)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        step = state.step + 1
        lr = self.lr * (self.schedule(step) if self.schedule else 1.0)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        m = jax.tree.map(lambda mm, g: self.b1 * mm + (1 - self.b1) * g,
                         state.m, g32)
        v = jax.tree.map(lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g,
                         state.v, g32)

        def upd(p, mm, vv):
            mhat = mm / b1c
            vhat = vv / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v)


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def cosine_warmup(warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return sched
