"""Checkpoint/restart (paper §6.3, generalized).

GRE checkpoints ONLY native vertex runtime states + the active bitmap,
"abandoning all agent data and temporal messages" — agents are rebuilt
deterministically from (seed, k).  We keep that contract:

  * graph engine: snapshot = {vertex_data, scatter_data[:cap], active[:cap],
    step} per shard — agent slots are dropped on save and re-derived on load;
  * ML training: snapshot = params + optimizer state + step + data cursor.

Features for 1000+-node deployments:
  * column-oriented flat .npz blobs (fast dump/restore, like the paper's COS);
  * async writer thread (training never blocks on disk);
  * ELASTIC restore: the snapshot stores the logical array; restore reshards
    onto whatever mesh the new job has (different k is fine for the graph
    engine because ownership is a pure function of (V, k));
  * retention of the newest `keep` snapshots + atomic `latest` marker.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz-safe (lossless upcast)
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, metadata: Optional[Dict[str, Any]] = None):
        """Snapshot a pytree.  Device arrays are fetched synchronously (cheap
        — they are already sharded); the disk write happens on the writer
        thread when async."""
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        treedef = jax.tree_util.tree_structure(tree)
        payload = (step, host_tree, str(treedef), metadata or {})
        if self.async_write:
            self._q.put(payload)
        else:
            self._write(payload)

    def wait(self):
        """Barrier: all queued snapshots durable."""
        self._q.join() if self.async_write else None

    def _drain(self):
        while True:
            payload = self._q.get()
            try:
                self._write(payload)
            finally:
                self._q.task_done()

    def _write(self, payload):
        step, host_tree, treedef_str, metadata = payload
        tmp = self.dir / f".tmp-{step}"
        final = self.dir / f"step-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        flat = _flatten(host_tree)
        np.savez(tmp / "state.npz", **flat)
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, "treedef": treedef_str, "metadata": metadata,
             "time": time.time()}))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        (self.dir / "latest.tmp").write_text(str(step))
        os.replace(self.dir / "latest.tmp", self.dir / "latest")
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step-{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        return [int(p.name.split("-")[1]) for p in self.dir.glob("step-*")]

    def latest_step(self) -> Optional[int]:
        f = self.dir / "latest"
        if not f.exists():
            return None
        s = int(f.read_text())
        return s if (self.dir / f"step-{s}").exists() else None

    def restore(self, like_tree, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of `like_tree`.  With `shardings`
        (a matching tree of NamedShardings) arrays are placed directly onto
        the TARGET mesh — elastic restore onto a different topology."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        blob = np.load(self.dir / f"step-{step}" / "state.npz")
        leaves_path = jax.tree_util.tree_flatten_with_path(like_tree)[0]
        out_leaves = []
        for path, like in leaves_path:
            key = "/".join(str(p) for p in path)
            arr = blob[key]
            assert arr.shape == tuple(like.shape), (key, arr.shape, like.shape)
            out_leaves.append(arr.astype(like.dtype))  # bf16 via ml_dtypes
        treedef = jax.tree_util.tree_structure(like_tree)
        tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step


def graph_engine_snapshot(state, cap: int):
    """Paper §6.3: keep master states + active bitmap only (agent slots and
    in-flight messages are temporal and rebuilt)."""
    return {
        "vertex_data": state.vertex_data,
        "scatter_data": state.scatter_data[..., :cap],
        "active": state.active_scatter[..., :cap],
        "step": state.step,
    }


def graph_engine_restore(snapshot, num_slots: int, identity: float):
    """Rebuild a full EngineState from a master-only snapshot (agent slots
    reinitialized to the monoid identity / inactive)."""
    import jax.numpy as jnp
    from repro.core.engine import EngineState
    sd_shape = snapshot["scatter_data"].shape
    lead = sd_shape[:-1]
    sd = jnp.full(lead + (num_slots,), identity,
                  snapshot["scatter_data"].dtype)
    sd = sd.at[..., :sd_shape[-1]].set(snapshot["scatter_data"])
    act = jnp.zeros(lead + (num_slots,), bool)
    act = act.at[..., :sd_shape[-1]].set(snapshot["active"])
    return EngineState(snapshot["vertex_data"], sd, act, snapshot["step"])
