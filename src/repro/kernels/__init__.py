# Pallas TPU kernels for the Scatter-Combine hot paths:
#   segment_combine  — the paper's active-message combine (⊕ over dst-sorted
#                      edges) as block-local one-hot MXU matmuls;
#   flash_attention  — blocked online-softmax attention for the LM archs.
# ops.py holds the jit'd wrappers; ref.py the pure-jnp oracles.
