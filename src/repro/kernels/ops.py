"""jit'd wrappers dispatching to the Pallas kernels (TPU) with automatic
fallback to the jnp reference path (useful on CPU where only interpret mode
exists).  These are the call sites models use via `use_pallas` flags.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.segment_combine import build_block_table, segment_combine_pallas


def segment_combine(msgs: jnp.ndarray, dst: jnp.ndarray, num_segments: int,
                    op: str = "sum", table: Optional[jnp.ndarray] = None,
                    interpret: bool = True, block_e: int = 256,
                    block_v: int = 256) -> jnp.ndarray:
    """Scatter-combine ⊕ along dst-sorted edges.

    `table` is the ingress-time block index (see
    segment_combine.build_block_table); when absent (or ids are traced) we
    fall back to the jnp oracle — the Pallas path needs static topology,
    which graph workloads have (topology is built once at ingress).
    """
    squeeze = msgs.ndim == 1
    m2 = msgs[:, None] if squeeze else msgs
    if table is None:
        try:
            dst_np = np.asarray(dst)
        except Exception:
            out = kref.segment_combine_ref(m2, dst, num_segments, op)
            return out[:, 0] if squeeze else out
        table = jnp.asarray(build_block_table(dst_np, num_segments,
                                              block_e, block_v))
    out = segment_combine_pallas(m2.astype(jnp.float32), dst, table,
                                 num_segments, op, block_e=block_e,
                                 block_v=block_v, interpret=interpret)
    out = out.astype(msgs.dtype)
    return out[:, 0] if squeeze else out


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 512, interpret: bool = True
                    ) -> jnp.ndarray:
    """GQA wrapper: q [B, Sq, Kv, G, H], k/v [B, Sk, Kv, H] — expands kv
    heads across the group dim and flattens (B, Kv, G) into the kernel's
    batch axis."""
    B, Sq, Kv, G, H = q.shape
    Sk = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * Kv * G, Sq, H)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Kv, G, Sk, H)).reshape(B * Kv * G, Sk, H)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, Kv, G, Sk, H)).reshape(B * Kv * G, Sk, H)
    o = flash_attention_pallas(qf, kf, vf, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return o.reshape(B, Kv, G, Sq, H).transpose(0, 3, 1, 2, 4)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray, bag_ids: jnp.ndarray,
                  num_bags: int, weights=None, seg_table=None,
                  interpret: bool = True) -> jnp.ndarray:
    """EmbeddingBag = XLA gather (vocab-scale tables stay in HBM; TPU has no
    VMEM-resident gather for 10⁷-row tables) + Pallas segment-combine for the
    bag reduction (the hot ⊕)."""
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    return segment_combine(rows, bag_ids, num_bags, "sum", table=seg_table,
                           interpret=interpret)
