"""Pallas TPU kernel for the Scatter-Combine ⊕ (paper §4's combine).

TPU adaptation of the paper's active-message combine: instead of per-message
atomic updates behind vLock (CPU), the irregular scatter becomes **block-local
one-hot matmuls on the MXU** over dst-sorted edges:

  * edges are sorted by destination (done once at graph ingress, like the
    paper's CSR build, §6.1.1);
  * the grid is (dst-row blocks × edge blocks); an SMEM prefetch table maps
    each dst block to the edge blocks whose dst range intersects it, so empty
    intersections are never visited (the CSR row-index analogue);
  * each visit computes onehotᵀ @ msgs (sum ⊕, MXU-aligned [BE, BV] × [BE, D])
    or a masked VPU reduction (min/max ⊕) and accumulates into the VMEM
    output block.

VMEM working set per step: BE·D (messages) + BE (ids) + BV·D (out block).
Defaults BE=256, BV=256, D ≤ 512 keep this well under 16 MB VMEM and the
matmul dims multiples of the 128-lane MXU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_OP_IDENTITY = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}


def _kernel(table_ref, dst_ref, msgs_ref, out_ref, *, op: str, block_v: int,
            n_edge_blocks: int):
    iv = pl.program_id(0)
    jj = pl.program_id(1)

    @pl.when(jj == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _OP_IDENTITY[op])

    eb = table_ref[iv, jj]  # real edge-block id or n_edge_blocks (padding)

    @pl.when(eb < n_edge_blocks)
    def _accumulate():
        v0 = iv * block_v
        dst = dst_ref[...]                                  # [BE]
        msgs = msgs_ref[...]                                # [BE, D]
        local = dst - v0
        onehot = (local[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (dst.shape[0], block_v), 1))         # [BE, BV]
        if op == "sum":
            acc = jax.lax.dot_general(
                onehot.astype(msgs.dtype), msgs,
                (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)          # [BV, D] on MXU
            out_ref[...] += acc.astype(out_ref.dtype)
        else:
            ident = _OP_IDENTITY[op]
            expanded = jnp.where(onehot[:, :, None], msgs[:, None, :], ident)
            red = expanded.min(0) if op == "min" else expanded.max(0)
            cur = out_ref[...]
            out_ref[...] = (jnp.minimum(cur, red) if op == "min"
                            else jnp.maximum(cur, red))


def build_block_table(dst_sorted: np.ndarray, num_segments: int,
                      block_e: int, block_v: int) -> np.ndarray:
    """Host-side ingress step: for each dst block, the list of edge blocks
    whose (sorted) dst range intersects it, padded with n_edge_blocks."""
    e = dst_sorted.shape[0]
    n_e = -(-e // block_e)
    n_v = -(-num_segments // block_v)
    pad = n_e * block_e - e
    d = np.concatenate([dst_sorted, np.full(pad, 2**31 - 1, dst_sorted.dtype)])
    first = d.reshape(n_e, block_e).min(axis=1)
    last = d.reshape(n_e, block_e).max(axis=1)
    # padded tail edges carry sentinel dst; clip to real values present
    last = np.minimum(last, num_segments * 2)
    rows = []
    for i in range(n_v):
        lo, hi = i * block_v, (i + 1) * block_v
        hits = np.flatnonzero((last >= lo) & (first < hi))
        rows.append(hits)
    width = max(1, max(len(r) for r in rows))
    table = np.full((n_v, width), n_e, np.int32)
    for i, r in enumerate(rows):
        table[i, :len(r)] = r
    return table


def full_block_table(num_edges: int, num_segments: int, block_e: int,
                     block_v: int) -> np.ndarray:
    """Degenerate block table for DATA-DEPENDENT destinations: every dst
    block visits every edge block.

    The ingress-time `build_block_table` prunes (dst block, edge block)
    pairs by intersecting static dst ranges — impossible for the
    frontier-compacted tiles, whose `dst` column is gathered per superstep.
    This table keeps the same kernel machinery (grid, prefetch indexing,
    accumulation) while degenerating the pruning to "visit everything":
    rows whose dst falls outside the current block contribute all-zero
    one-hot lanes.  First step toward the ROADMAP dynamic block table,
    which would re-prune on-device each superstep.
    """
    n_e = -(-num_edges // block_e)
    n_v = -(-num_segments // block_v)
    return np.broadcast_to(np.arange(n_e, dtype=np.int32), (n_v, n_e)).copy()


def tile_segment_combine_pallas(msgs: jnp.ndarray, dst: jnp.ndarray,
                                num_segments: int, op: str = "sum",
                                block_e: int = 256, block_v: int = 256,
                                interpret: bool = True) -> jnp.ndarray:
    """Segment-combine a gathered frontier tile (msgs [E, D] float32,
    dst [E] int32, BOTH data-dependent) via the full block table.  Shapes
    are static under jit, so the table is built at trace time."""
    table = jnp.asarray(full_block_table(msgs.shape[0], num_segments,
                                         block_e, block_v))
    return segment_combine_pallas(msgs, dst, table, num_segments, op,
                                  block_e=block_e, block_v=block_v,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_segments", "op", "block_e",
                                             "block_v", "interpret"))
def segment_combine_pallas(msgs: jnp.ndarray, dst: jnp.ndarray,
                           table: jnp.ndarray, num_segments: int,
                           op: str = "sum", block_e: int = 256,
                           block_v: int = 256, interpret: bool = True
                           ) -> jnp.ndarray:
    """msgs [E, D] (dst-sorted), dst [E] int32, table from build_block_table.
    Returns [num_segments, D]."""
    e, d_feat = msgs.shape
    n_e = -(-e // block_e)
    n_v = -(-num_segments // block_v)
    v_pad = n_v * block_v
    e_pad = n_e * block_e
    # pad edges with an out-of-range dst so their one-hot rows are all-zero
    msgs = jnp.pad(msgs, ((0, e_pad - e), (0, 0)))
    dst = jnp.pad(dst.astype(jnp.int32), (0, e_pad - e),
                  constant_values=jnp.int32(2**31 - 1))
    # append one dummy zero edge block for padded table entries
    msgs = jnp.concatenate([msgs, jnp.zeros((block_e, d_feat), msgs.dtype)])
    dst = jnp.concatenate([dst, jnp.full((block_e,), 2**31 - 1, jnp.int32)])

    width = table.shape[1]
    grid = (n_v, width)
    out = pl.pallas_call(
        functools.partial(_kernel, op=op, block_v=block_v, n_edge_blocks=n_e),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_e,), lambda i, j, t: (t[i, j],)),
                pl.BlockSpec((block_e, d_feat), lambda i, j, t: (t[i, j], 0)),
            ],
            out_specs=pl.BlockSpec((block_v, d_feat), lambda i, j, t: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((v_pad, d_feat), jnp.float32),
        interpret=interpret,
    )(table, dst, msgs)
    return out[:num_segments]
