"""Pallas TPU kernel for the Scatter-Combine ⊕ (paper §4's combine).

TPU adaptation of the paper's active-message combine: instead of per-message
atomic updates behind vLock (CPU), the irregular scatter becomes **block-local
one-hot matmuls on the MXU** over dst-sorted edges:

  * edges are sorted by destination (done once at graph ingress, like the
    paper's CSR build, §6.1.1);
  * the grid is (dst-row blocks × edge blocks); an SMEM prefetch table maps
    each dst block to the edge blocks whose dst range intersects it, so empty
    intersections are never visited (the CSR row-index analogue);
  * each visit computes onehotᵀ @ msgs (sum ⊕, MXU-aligned [BE, BV] × [BE, D])
    or a masked VPU reduction (min/max ⊕) and accumulates into the VMEM
    output block.

THREE block tables drive the same kernel (see docs/kernels.md):

  build_block_table    — host-side ingress pruning over the STATIC dst-sorted
                         edge columns (the dense-path table);
  dynamic_block_table  — the same pruning computed IN-GRAPH each superstep
                         from a data-dependent (gathered, then dst-sorted)
                         tile: per-edge-block dst min/max via blocked
                         reductions, then the sentinel-padded intersection
                         table.  This is the default for the frontier-
                         compacted tile combine;
  full_block_table     — the degenerate every-pair fallback, kept only for
                         `dynamic=False` (the documented escape hatch when
                         the pruning pass is disabled).

All three speak the same sentinel semantics: a table row is padded with
`n_edge_blocks`, which indexes one appended all-identity dummy edge block;
`@pl.when(eb < n_edge_blocks)` skips the visit entirely, so padded entries
cost a (cache-resident) dummy block fetch and no compute.

VMEM working set per step: BE·D (messages) + BE (ids) + BV·D (out block).
Defaults BE=256, BV=256, D ≤ 512 keep this well under 16 MB VMEM and the
matmul dims multiples of the 128-lane MXU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_OP_IDENTITY = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}

# Out-of-range destination sentinel: padded edges (and invalid tile lanes)
# carry a dst no real segment block can intersect, so both the pruning pass
# and the in-kernel one-hot drop them.
_DST_SENTINEL = np.int32(2**31 - 1)


def _kernel(table_ref, dst_ref, msgs_ref, out_ref, *, op: str, block_v: int,
            n_edge_blocks: int):
    iv = pl.program_id(0)
    jj = pl.program_id(1)

    @pl.when(jj == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _OP_IDENTITY[op])

    eb = table_ref[iv, jj]  # real edge-block id or n_edge_blocks (padding)

    @pl.when(eb < n_edge_blocks)
    def _accumulate():
        v0 = iv * block_v
        dst = dst_ref[...]                                  # [BE]
        msgs = msgs_ref[...]                                # [BE, D]
        local = dst - v0
        onehot = (local[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (dst.shape[0], block_v), 1))         # [BE, BV]
        if op == "sum":
            acc = jax.lax.dot_general(
                onehot.astype(msgs.dtype), msgs,
                (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)          # [BV, D] on MXU
            out_ref[...] += acc.astype(out_ref.dtype)
        else:
            ident = _OP_IDENTITY[op]
            expanded = jnp.where(onehot[:, :, None], msgs[:, None, :], ident)
            red = expanded.min(0) if op == "min" else expanded.max(0)
            cur = out_ref[...]
            out_ref[...] = (jnp.minimum(cur, red) if op == "min"
                            else jnp.maximum(cur, red))


def build_block_table(dst_sorted: np.ndarray, num_segments: int,
                      block_e: int, block_v: int) -> np.ndarray:
    """Host-side ingress step: for each dst block, the list of edge blocks
    whose (sorted) dst range intersects it, padded with n_edge_blocks."""
    e = dst_sorted.shape[0]
    n_e = -(-e // block_e)
    n_v = -(-num_segments // block_v)
    pad = n_e * block_e - e
    d = np.concatenate([dst_sorted, np.full(pad, _DST_SENTINEL,
                                            dst_sorted.dtype)])
    first = d.reshape(n_e, block_e).min(axis=1)
    last = d.reshape(n_e, block_e).max(axis=1)
    # padded tail edges carry sentinel dst; clip to real values present
    last = np.minimum(last, num_segments * 2)
    rows = []
    for i in range(n_v):
        lo, hi = i * block_v, (i + 1) * block_v
        hits = np.flatnonzero((last >= lo) & (first < hi))
        rows.append(hits)
    width = max(1, max(len(r) for r in rows))
    table = np.full((n_v, width), n_e, np.int32)
    for i, r in enumerate(rows):
        table[i, :len(r)] = r
    return table


def dynamic_block_table(dst: jnp.ndarray, num_segments: int, block_e: int,
                        block_v: int) -> jnp.ndarray:
    """ON-DEVICE per-superstep pruning pass for DATA-DEPENDENT destinations.

    `dst [E] int32` is a gathered tile's destination column, SORTED
    ascending, with invalid lanes carrying a sentinel `>= num_segments`
    (they sort past every real destination).  The same intersection test as
    the ingress-time `build_block_table` runs in-graph with blocked
    reductions:

      1. reshape the (sentinel-padded) dst column to `[n_e, block_e]` and
         reduce each edge block to its dst `[first, last]` range;
      2. a (dst block, edge block) pair is visited iff the ranges intersect
         (`last >= lo & first < hi`); the sentinel makes all-invalid blocks
         intersect nothing;
      3. each row's hits compact to the left via a sort of
         `where(hit, block_id, n_e)` — rows stay padded with `n_e`, the
         kernel's skip sentinel, and entries stay in ascending edge-block
         order (the same layout the host-side table produces).

    The table width is the STATIC worst case `n_e` (every edge block hits),
    so the shape is jit-stable; pruning shows up as sentinel-padded rows the
    kernel's `@pl.when` skips, not as a smaller grid.  Returns
    `[n_v, n_e] int32`.
    """
    e = dst.shape[0]
    n_e = -(-e // block_e)
    n_v = -(-num_segments // block_v)
    d = jnp.pad(dst.astype(jnp.int32), (0, n_e * block_e - e),
                constant_values=_DST_SENTINEL).reshape(n_e, block_e)
    real = d < num_segments
    first = d.min(axis=1)                         # [n_e]; sentinel if empty
    last = jnp.where(real, d, -1).max(axis=1)     # [n_e] tightest real dst
    lo = jnp.arange(n_v, dtype=jnp.int32) * block_v         # [n_v]
    # All-sentinel blocks are excluded by the MASKED `last` (= -1, below
    # every `lo`), not by `first`: the tile sentinel `num_segments` can
    # still fall inside the last dst block's padded range when
    # num_segments is not a multiple of block_v.
    hit = ((last[None, :] >= lo[:, None])
           & (first[None, :] < (lo + block_v)[:, None]))    # [n_v, n_e]
    ids = jnp.arange(n_e, dtype=jnp.int32)
    return jnp.sort(jnp.where(hit, ids[None, :], n_e), axis=1)


def block_table_occupancy(table, n_edge_blocks: int) -> float:
    """Visited-block fraction of a prefetch table vs the FULL table: the
    share of the `n_v * n_edge_blocks` (dst block, edge block) pairs the
    kernel actually computes (table entries below the `n_edge_blocks`
    skip sentinel).  The denominator is the full pair count, not the
    table width — `build_block_table` rows are already narrower than
    `n_edge_blocks`.  1.0 is the degenerate `full_block_table`; the
    pruning diagnostics in `partition_quality` and `bench_frontier`
    report this number."""
    table = np.asarray(table)
    visited = int(np.sum(table < n_edge_blocks))
    return visited / (table.shape[0] * max(n_edge_blocks, 1))


def full_block_table(num_edges: int, num_segments: int, block_e: int,
                     block_v: int) -> np.ndarray:
    """Degenerate block table: every dst block visits every edge block.

    DEPRECATED as a public entry point — the frontier tile combine now
    routes through the plan's kernel stage (`repro.core.plan.KernelPlan`),
    which builds the on-device `dynamic_block_table` by default.  This
    table remains only as the documented fallback when the dynamic pruning
    pass is disabled (`KernelPlan(dynamic_table=False)` /
    `tile_segment_combine_pallas(.., dynamic=False)`): same kernel
    machinery (grid, prefetch indexing, accumulation), no skipping — rows
    whose dst falls outside the current block contribute all-zero one-hot
    lanes.
    """
    n_e = -(-num_edges // block_e)
    n_v = -(-num_segments // block_v)
    return np.broadcast_to(np.arange(n_e, dtype=np.int32), (n_v, n_e)).copy()


def tile_segment_combine_pallas(msgs: jnp.ndarray, dst: jnp.ndarray,
                                num_segments: int, op: str = "sum",
                                block_e: int = 256, block_v: int = 256,
                                interpret: bool = True,
                                dynamic: bool = True) -> jnp.ndarray:
    """Segment-combine a gathered frontier tile (msgs [E, D] float32,
    dst [E] int32, BOTH data-dependent).

    With `dynamic=True` (default) the tile is dst-sorted on device and the
    kernel runs over the per-superstep `dynamic_block_table` — restoring
    the ingress-style sparsity skipping for tiles whose dst is gathered per
    superstep.  Invalid lanes must carry `dst >= num_segments` so the sort
    pushes them past every real destination and the pruning drops their
    blocks.  `dynamic=False` falls back to the degenerate
    `full_block_table` (every pair visited; no sort) — the escape hatch
    when the pruning pass itself is under test or disabled.

    The dst-sort re-orders messages within a segment: min/max ⊕ stay
    bitwise-identical to the XLA scatter-reduce; sums agree to float
    tolerance (the same reorder caveat every compacted strategy already
    carries).
    """
    dst = dst.astype(jnp.int32)
    if dynamic:
        order = jnp.argsort(dst)
        dst = dst[order]
        msgs = msgs[order]
        table = dynamic_block_table(dst, num_segments, block_e, block_v)
    else:
        table = jnp.asarray(full_block_table(msgs.shape[0], num_segments,
                                             block_e, block_v))
    return segment_combine_pallas(msgs, dst, table, num_segments, op,
                                  block_e=block_e, block_v=block_v,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_segments", "op", "block_e",
                                             "block_v", "interpret"))
def segment_combine_pallas(msgs: jnp.ndarray, dst: jnp.ndarray,
                           table: jnp.ndarray, num_segments: int,
                           op: str = "sum", block_e: int = 256,
                           block_v: int = 256, interpret: bool = True
                           ) -> jnp.ndarray:
    """msgs [E, D] (dst-sorted), dst [E] int32, table from any of the
    block-table builders above.  Returns [num_segments, D]."""
    e, d_feat = msgs.shape
    n_e = -(-e // block_e)
    n_v = -(-num_segments // block_v)
    v_pad = n_v * block_v
    e_pad = n_e * block_e
    # pad edges with an out-of-range dst so their one-hot rows are all-zero
    msgs = jnp.pad(msgs, ((0, e_pad - e), (0, 0)))
    dst = jnp.pad(dst.astype(jnp.int32), (0, e_pad - e),
                  constant_values=_DST_SENTINEL)
    # append one dummy zero edge block for padded table entries
    msgs = jnp.concatenate([msgs, jnp.zeros((block_e, d_feat), msgs.dtype)])
    dst = jnp.concatenate([dst, jnp.full((block_e,), _DST_SENTINEL,
                                         jnp.int32)])

    width = table.shape[1]
    grid = (n_v, width)
    out = pl.pallas_call(
        functools.partial(_kernel, op=op, block_v=block_v, n_edge_blocks=n_e),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_e,), lambda i, j, t: (t[i, j],)),
                pl.BlockSpec((block_e, d_feat), lambda i, j, t: (t[i, j], 0)),
            ],
            out_specs=pl.BlockSpec((block_v, d_feat), lambda i, j, t: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((v_pad, d_feat), jnp.float32),
        interpret=interpret,
    )(table, dst, msgs)
    return out[:num_segments]
