"""Pallas TPU flash attention (forward) for the LM architectures.

Blocked online-softmax (FlashAttention, arXiv:2205.14135, adapted to the TPU
memory hierarchy): grid (batch·kv_head·group, q blocks, kv blocks); the kv
dimension is the innermost (sequential) grid axis so the output block and the
running (m, l) statistics live in VMEM scratch across kv steps.  Causal
masking skips fully-masked kv blocks via `pl.when` (no wasted MXU work).

Block sizes default to (128, 512): q/k tiles are multiples of the 128-lane
MXU; VMEM per step = Bq·D + Bk·D + Bq·Bk floats ≪ 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (iq * block_q + block_q - 1 >= ik * block_k)

    @pl.when(run)
    def _step():
        q = q_ref[0]                                        # [Bq, D]
        k = k_ref[0]                                        # [Bk, D]
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 512, interpret: bool = True
                           ) -> jnp.ndarray:
    """q [BH, Sq, D], k/v [BH, Sk, D] (heads flattened into batch; GQA is
    handled by the ops.py wrapper which expands kv heads)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    n_q, n_k = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(d)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, n_k=n_k),
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
