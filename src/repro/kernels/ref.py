"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def segment_combine_ref(msgs: jnp.ndarray, dst: jnp.ndarray,
                        num_segments: int, op: str = "sum") -> jnp.ndarray:
    """msgs [E, D], dst [E] -> [num_segments, D]."""
    if op == "sum":
        return jax.ops.segment_sum(msgs, dst, num_segments)
    if op == "min":
        return jax.ops.segment_min(msgs, dst, num_segments)
    if op == "max":
        return jax.ops.segment_max(msgs, dst, num_segments)
    raise ValueError(op)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True) -> jnp.ndarray:
    """q [BH, Sq, D], k/v [BH, Sk, D]."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / np.sqrt(d)
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v)


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray,
                      bag_ids: jnp.ndarray, num_bags: int,
                      weights=None) -> jnp.ndarray:
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    return jax.ops.segment_sum(rows, bag_ids, num_bags)
