"""gin-tu [arXiv:1810.00826; paper]
5-layer GIN, d_hidden 64, sum aggregation, learnable eps."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gin-tu", family="gin", n_layers=5, d_hidden=64,
    aggregator="sum", eps_learnable=True, n_classes=2,
)

FAMILY = "gnn"
