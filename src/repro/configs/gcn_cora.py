"""gcn-cora [arXiv:1609.02907; paper]
2-layer GCN, d_hidden 16, mean/sym-norm aggregation."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gcn-cora", family="gcn", n_layers=2, d_hidden=16,
    aggregator="mean", norm="sym", n_classes=7,
)

FAMILY = "gnn"
