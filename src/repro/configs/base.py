"""Config dataclasses for all architecture families + shape sets."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    activation: str = "silu"
    gated: bool = True
    rope_theta: float = 10000.0
    moe: Optional[MoESpec] = None
    dtype: str = "bfloat16"
    attention_impl: str = "chunked"   # reference | chunked
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: bool = True
    remat_block: int = 1         # >1: layers per outer remat block (2-level)
    seq_shard_activations: bool = True
    tie_embeddings: bool = False

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a 128-lane multiple (Megatron-style padding) so
        the vocab axis shards evenly on any tp degree up to 128; padded
        logit columns are masked to -inf in the forward pass."""
        return -(-self.vocab // 128) * 128

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        qkv = d * self.n_heads * self.d_head + 2 * d * self.n_kv * self.d_head
        attn = qkv + self.n_heads * self.d_head * d
        if self.moe:
            e = self.moe
            ff = e.n_experts * e.d_ff_expert * d * (3 if self.gated else 2)
            ff += d * e.n_experts  # router
        else:
            ff = d * f * (3 if self.gated else 2)
        per_layer = attn + ff + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d = self.d_model
        e = self.moe
        dense_ff = e.top_k * e.d_ff_expert * d * (3 if self.gated else 2)
        full_ff = e.n_experts * e.d_ff_expert * d * (3 if self.gated else 2)
        return self.param_count() - self.n_layers * (full_ff - dense_ff)


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES: Tuple[LMShape, ...] = (
    LMShape("train_4k", "train", 4096, 256),
    LMShape("prefill_32k", "prefill", 32768, 32),
    LMShape("decode_32k", "decode", 32768, 128),
    LMShape("long_500k", "decode", 524288, 1),
)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    family: str          # gcn | gin | dimenet | mace
    n_layers: int
    d_hidden: int
    # family-specific knobs
    aggregator: str = "sum"
    norm: str = "none"            # gcn: sym
    eps_learnable: bool = False   # gin
    n_bilinear: int = 8           # dimenet
    n_spherical: int = 7
    n_radial: int = 6
    l_max: int = 2                # mace
    correlation_order: int = 3
    n_rbf: int = 8
    d_out: int = 1
    n_classes: int = 16
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str            # full_graph | minibatch | molecule
    n_nodes: int
    n_edges: int
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    batch_graphs: int = 0


GNN_SHAPES: Tuple[GNNShape, ...] = (
    GNNShape("full_graph_sm", "full_graph", 2708, 10556, d_feat=1433),
    GNNShape("minibatch_lg", "minibatch", 232965, 114615892, d_feat=602,
             batch_nodes=1024, fanout=(15, 10)),
    GNNShape("ogb_products", "full_graph", 2449029, 61859140, d_feat=100),
    GNNShape("molecule", "molecule", 30, 64, batch_graphs=128),
)


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    n_sparse: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    n_dense: int = 0
    # per-field vocab sizes (criteo-like long tail)
    vocab_sizes: Tuple[int, ...] = ()
    mlp_dims: Tuple[int, ...] = (256, 128)
    dtype: str = "float32"

    def total_rows(self) -> int:
        return sum(self.vocab_sizes)


@dataclasses.dataclass(frozen=True)
class RecSysShape:
    name: str
    kind: str            # train | serve | retrieval
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES: Tuple[RecSysShape, ...] = (
    RecSysShape("train_batch", "train", 65536),
    RecSysShape("serve_p99", "serve", 512),
    RecSysShape("serve_bulk", "serve", 262144),
    RecSysShape("retrieval_cand", "retrieval", 1, n_candidates=1_000_000),
)


@dataclasses.dataclass(frozen=True)
class GraphWorkloadConfig:
    """The paper's own workload family: vertex programs on R-MAT graphs."""
    name: str
    algorithm: str       # pagerank | sssp | cc | bfs
    scale: int           # log2 |V| (Graph500)
    edge_factor: int = 16
    max_steps: int = 30
    exchange: str = "agent"
