"""autoint [arXiv:1810.11921; paper]
Self-attention feature interaction: 39 sparse fields, embed 16, 3 attention
layers (2 heads, d_attn 32).  Criteo-like long-tail vocab (~37M total rows)."""
from repro.configs.base import RecSysConfig

VOCABS = tuple([10_000_000] * 3 + [1_000_000] * 6 + [100_000] * 10
               + [1_000] * 20)
assert len(VOCABS) == 39

CONFIG = RecSysConfig(
    name="autoint", n_sparse=39, embed_dim=16, n_attn_layers=3,
    n_heads=2, d_attn=32, vocab_sizes=VOCABS,
)

FAMILY = "recsys"
