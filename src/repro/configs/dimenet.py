"""dimenet [arXiv:2003.03123; unverified]
Directional message passing: 6 blocks, d_hidden 128, 8 bilinear,
7 spherical, 6 radial."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="dimenet", family="dimenet", n_layers=6, d_hidden=128,
    n_bilinear=8, n_spherical=7, n_radial=6, d_out=1,
)

FAMILY = "gnn"
