"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Any, Dict, Tuple

from repro.configs.base import (GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES,
                                GNNConfig, GNNShape, LMConfig, LMShape,
                                MoESpec, RecSysConfig, RecSysShape)

_MODULES = {
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "smollm-135m": "repro.configs.smollm_135m",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "dimenet": "repro.configs.dimenet",
    "gcn-cora": "repro.configs.gcn_cora",
    "gin-tu": "repro.configs.gin_tu",
    "mace": "repro.configs.mace",
    "autoint": "repro.configs.autoint",
}

ALL_ARCHS = tuple(_MODULES)

_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}


def get_config(arch: str):
    """Returns (config, family) for an architecture id."""
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG, mod.FAMILY


def get_shapes(arch: str):
    """The arch's own input-shape set (assignment pairs shapes per family)."""
    _, family = get_config(arch)
    return _SHAPES[family]


def get_shape(arch: str, shape_name: str):
    for s in get_shapes(arch):
        if s.name == shape_name:
            return s
    raise KeyError(f"{arch} has no shape {shape_name!r}")


def all_cells():
    """All 40 (arch × shape) dry-run cells."""
    for arch in ALL_ARCHS:
        for s in get_shapes(arch):
            yield arch, s.name
