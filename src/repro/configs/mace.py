"""mace [arXiv:2206.07697; paper]
Higher-order E(3)-equivariant message passing: 2 layers, d_hidden 128,
l_max 2, correlation order 3, 8 radial Bessel functions."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="mace", family="mace", n_layers=2, d_hidden=128,
    l_max=2, correlation_order=3, n_rbf=8, d_out=1,
)

FAMILY = "gnn"
