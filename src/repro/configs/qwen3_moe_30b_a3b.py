"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf]
MoE decoder: 48L, d_model 2048, 32 heads (kv=4, d_head 128), 128 experts
top-8 with expert d_ff 768, vocab 151936."""
from repro.configs.base import LMConfig, MoESpec

CONFIG = LMConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48, d_model=2048, n_heads=32, n_kv=4, d_head=128,
    d_ff=768, vocab=151936, activation="silu", gated=True,
    moe=MoESpec(n_experts=128, top_k=8, d_ff_expert=768),
    dtype="bfloat16", attention_impl="chunked", q_chunk=512, kv_chunk=1024,
)

FAMILY = "lm"
