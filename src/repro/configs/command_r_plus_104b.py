"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-v01; unverified]
Dense GQA decoder: 64L, d_model 12288, 96 heads (kv=8), d_ff 33792,
vocab 256000, no biases."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="command-r-plus-104b",
    n_layers=64, d_model=12288, n_heads=96, n_kv=8, d_head=128,
    d_ff=33792, vocab=256000, activation="silu", gated=True,
    dtype="bfloat16", attention_impl="chunked", q_chunk=512, kv_chunk=1024,
)

FAMILY = "lm"
