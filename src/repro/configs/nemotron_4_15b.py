"""nemotron-4-15b [arXiv:2402.16819; unverified]
Dense GQA decoder with squared-ReLU MLP (no gating): 32L, d_model 6144,
48 heads (kv=8), d_ff 24576, vocab 256000."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-15b",
    n_layers=32, d_model=6144, n_heads=48, n_kv=8, d_head=128,
    d_ff=24576, vocab=256000, activation="squared_relu", gated=False,
    dtype="bfloat16", attention_impl="chunked", q_chunk=512, kv_chunk=1024,
)

FAMILY = "lm"
