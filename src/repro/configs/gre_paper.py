"""The paper's own workloads: PageRank / SSSP / CC on Graph500 R-MAT graphs
(§7: a=0.57, b=c=0.19, edge factor 16)."""
from repro.configs.base import GraphWorkloadConfig

PAGERANK = GraphWorkloadConfig("gre-pagerank", "pagerank", scale=14,
                               max_steps=30)
SSSP = GraphWorkloadConfig("gre-sssp", "sssp", scale=14, max_steps=100)
CC = GraphWorkloadConfig("gre-cc", "cc", scale=14, max_steps=100)

FAMILY = "graph"
