"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
MoE decoder: 24L, d_model 1024, 16 heads (kv=8, d_head 64), 32 experts
top-8 with expert d_ff 512, vocab 49155."""
from repro.configs.base import LMConfig, MoESpec

CONFIG = LMConfig(
    name="granite-moe-1b-a400m",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, d_head=64,
    d_ff=512, vocab=49155, activation="silu", gated=True,
    moe=MoESpec(n_experts=32, top_k=8, d_ff_expert=512),
    dtype="bfloat16", attention_impl="chunked", q_chunk=512, kv_chunk=1024,
    # §Perf iteration 4: at d_model=1024 the between-layer sequence sharding
    # costs more in per-layer all-gathers than the 134 MiB/layer boundary
    # memory it saves — keep activations batch-sharded only.
    seq_shard_activations=False,
)

FAMILY = "lm"
