"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf]
Llama-arch small model: 30L, d_model 576, 9 heads (kv=3), d_ff 1536,
vocab 49152."""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="smollm-135m",
    n_layers=30, d_model=576, n_heads=9, n_kv=3, d_head=64,
    d_ff=1536, vocab=49152, activation="silu", gated=True,
    dtype="bfloat16", attention_impl="chunked", q_chunk=512, kv_chunk=1024,
)

FAMILY = "lm"
