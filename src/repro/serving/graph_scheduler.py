"""Multi-tenant traversal serving: continuous query batching over payload
lanes (docs/serving.md).

The engine's multi-source programs already answer D roots in one pass by
batching them into the `[slots, D]` payload lanes — but a STATIC batch runs
until its slowest query converges, so mixed short/long traffic pays the
worst lane's supersteps for every admission.  `GraphQueryBatcher` turns the
lanes into a continuously-batched serving pool instead:

  admit   — a queued query is seeded into a free lane by ONE jitted
            static-shape call (`[D]`-wide index arrays with out-of-bounds
            sentinels, `mode="drop"`), so admission never recompiles;
  tick    — `steps_per_tick` supersteps advance ALL resident lanes through
            the one canonical superstep (`plan.execute_superstep`, any
            exchange backend, single-shard or mesh);
  retire  — between ticks the host reads `EngineState.lane_active` (per-lane
            halt, reduced by `apply` from `VertexProgram.lane_activates`),
            fetches converged lanes' results, and recycles their lanes for
            the next queued queries.  Budget-exceeded queries are EVICTED:
            the lane is reset without reseeding and the query marked failed.

Recycling is bitwise-safe: a reset lane holds monoid-identity scatter state,
so vertices still active on behalf of OTHER lanes deliver identity values
into it (`min(x, inf) = x`; `x + 0.0 = x`) — a recycled lane's answer is
bit-identical to a fresh single-query batch (tests/test_serving.py proves
this on the null, agent, and pipelined backends).

The jitted tick and admit functions see ONE pytree structure (lane_active
always `[D]` bool, index operands always `[D]` int32), so an arbitrarily
long query stream triggers exactly two compilations, total.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GraphQueryBatcher", "Query", "ServingFrontend", "poisson_ticks"]


@dataclasses.dataclass
class Query:
    """One traversal request riding a payload lane.

    Lifecycle: queued → running → done | evicted.  Timing fields are wall
    clock (`time.perf_counter`); `supersteps_used` counts supersteps from
    admission — the scheduler-level SLO latency that is independent of
    machine speed.
    """

    uid: int
    source: int
    kind: str = "bfs"
    max_supersteps: Optional[int] = None   # budget; None = run to convergence
    status: str = "queued"
    result: Optional[np.ndarray] = None
    lane: Optional[int] = None
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    finished_at: float = 0.0
    supersteps_used: int = 0

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at

    @property
    def wait_s(self) -> float:
        return self.admitted_at - self.submitted_at


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile over an ALREADY-SORTED sequence
    (numpy's default ``method="linear"``).  The nearest-rank shortcut this
    replaces rounded `q*(n-1)` to an index, which collapses p95 to the max
    for n ≲ 20 samples and misreports it at most other sizes."""
    if not sorted_vals:
        return float("nan")
    pos = q * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return float(sorted_vals[lo]) * (1.0 - frac) + float(sorted_vals[hi]) * frac


def poisson_ticks(num_queries: int, rate_per_tick: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Arrival tick for each of `num_queries` queries under a Poisson
    process with `rate_per_tick` expected arrivals per serving tick
    (exponential inter-arrival gaps, cumulated and floored)."""
    gaps = rng.exponential(scale=1.0 / rate_per_tick, size=num_queries)
    return np.floor(np.cumsum(gaps)).astype(np.int64)


class GraphQueryBatcher:
    """Continuous batching of traversal queries over one engine's lanes.

    `engine` is a `GREEngine` (with a `DevicePartition` target) or a
    `DistGREEngine` (with an `AgentGraph` target); the program must be a
    multi-source variant exposing `lane_activates` (e.g.
    `bfs_program(D)`, `sssp_program(D)`, `ppr_push_program(D)`).

    Public protocol: `submit()` enqueues; `pump()` retires/evicts/admits
    (host-side, between ticks); `tick()` advances every resident lane by
    `steps_per_tick` supersteps; `run()` loops pump/tick until drained.
    """

    def __init__(self, engine, target, *, steps_per_tick: int = 1,
                 default_budget: Optional[int] = None,
                 clock=time.perf_counter):
        p = engine.program
        if not p.payload_shape or p.lane_activates is None:
            raise ValueError(
                "serving needs a multi-source program with lane_activates "
                f"(got {p.name!r} with payload_shape={p.payload_shape})")
        self.engine = engine
        self.program = p
        self.num_lanes = p.payload_shape[0]
        self.steps_per_tick = steps_per_tick
        self.default_budget = default_budget
        self.clock = clock
        self._dist = hasattr(engine, "mesh")   # DistGREEngine
        if self._dist:
            self._ag = target
            self._topo = engine.device_topology(target)
            self._tick_fn = engine.make_superstep(
                target, steps_per_tick=steps_per_tick)
            self._admit_fn = self._make_dist_admit(target)
            self.state = engine.init_state(
                target, source=[None] * self.num_lanes, lane_tracking=True)
        else:
            self._part = target
            self._tick_fn = self._make_tick(target)
            self._admit_fn = self._make_admit(target)
            self.state = engine.init_state(
                target, source=[None] * self.num_lanes, lane_tracking=True)
        # After init_state/device_topology: any plan="auto-tuned" cache hit
        # has been adopted by now, and the jitted tick/admit fns above trace
        # lazily on first call — so the clamp below still lands before any
        # trace reads the frontier knobs.
        self._clamp_sum_monoid_plan()
        self.queue: deque = deque()
        self.finished: List[Query] = []
        self._lane_query: List[Optional[Query]] = [None] * self.num_lanes
        self._pending_deltas: List = []   # "finish"-policy deltas awaiting swap
        self._uid = 0
        self.ticks = 0
        self.supersteps = 0
        self._busy_lane_ticks = 0
        self._first_submit: Optional[float] = None

    def _clamp_sum_monoid_plan(self) -> None:
        """Pin sum-monoid programs (PPR et al.) to the dense-frontier plan.

        Recycled-lane bitwise equality for fp sums needs an
        ORDER-INDEPENDENT schedule: the dense every-edge scan visits edges
        in one fixed order every superstep, so a recycled lane accumulates
        the exact float sequence a fresh batch would.  A compacted frontier
        reorders message delivery by frontier occupancy — which depends on
        which OTHER queries share the batch — silently breaking that
        equality.  The engine's own default pins this, but a
        `plan="auto-tuned"` cache hit or an explicit `adopt_plan` call can
        hand the batcher a compacted plan (tuned on some sparse-frontier
        scenario); clamp it back before any tick function traces.

        Only the frontier STRATEGY is clamped — the masked dense scan is
        already order-fixed.  `dense_frontier` (skip activity masks
        entirely) is a semantic knob owned by the program: forcing it on a
        halting program like PPR push breaks lane retirement, so it is
        reset to the program's own default instead."""
        if self.program.monoid.name != "sum":
            return
        local = self.engine.local if self._dist else self.engine
        local.frontier = "dense"
        local.frontier_cap = None
        local.dense_frontier = not self.program.halts

    # ------------------------------------------------------------ jitted fns
    def _make_tick(self, part):
        engine, steps = self.engine, self.steps_per_tick

        def tick(state):
            for _ in range(steps):
                state = engine.superstep(part, state)
            return state

        return jax.jit(tick)

    def _make_admit(self, part):
        """ONE static-shape admission/eviction/reset call.

        Operands are `[D]`-wide: `lanes[i]` names the lane to reset (sentinel
        D = no-op), `src[i]` the root to seed into it (sentinel `num_slots`
        = reset WITHOUT seeding, i.e. eviction), `flags[i]` the lane's new
        `lane_active` bit.  Sentinels are out-of-bounds-HIGH so
        `mode="drop"` discards them (negative indices would wrap).
        """
        p, D = self.program, self.num_lanes
        n, slots = part.num_masters, part.num_slots
        identity = p.monoid.identity

        def admit(state, src, lanes, flags):
            mask = jnp.zeros(D, dtype=bool).at[lanes].set(True, mode="drop")
            init_vd = p.init_vertex_data(n, part.aux)
            vd = state.vertex_data
            bmask = mask.reshape((1, D) + (1,) * (vd.ndim - 2))
            vd = jnp.where(bmask, init_vd, vd)
            sd0 = jnp.asarray(p.init_scatter_data(n, part.aux), p.msg_dtype)
            sd_init = jnp.full((slots,) + sd0.shape[1:], identity,
                               p.msg_dtype).at[:n].set(sd0)
            sd = jnp.where(mask[None, :], sd_init, state.scatter_data)
            # Activating the seed vertex makes it scatter EVERY lane of its
            # row next superstep.  An inactive vertex's row is stale — its
            # values were already delivered (sum monoids would double-count
            # them) — so normalize it to the identity; an ACTIVE vertex's
            # row was rewritten by the last apply and is still undelivered,
            # so it must be kept.
            rows = jnp.take(sd, src, axis=0, mode="fill",
                            fill_value=identity)
            keep = jnp.take(state.active_scatter, src, mode="fill",
                            fill_value=False)
            rows = jnp.where(keep.reshape((D,) + (1,) * (rows.ndim - 1)),
                             rows, identity)
            sd = sd.at[src].set(rows, mode="drop")
            if p.seed_sources is not None:
                vd, sd = p.seed_sources(vd, sd, src, lanes, part.aux)
            else:
                vd = vd.at[src, lanes].set(0.0, mode="drop")
                sd = sd.at[src, lanes].set(0.0, mode="drop")
            active = state.active_scatter.at[src].set(True, mode="drop")
            lane_active = state.lane_active.at[lanes].set(flags, mode="drop")
            return dataclasses.replace(
                state, vertex_data=vd, scatter_data=sd,
                active_scatter=active, lane_active=lane_active)

        return jax.jit(admit)

    def _make_dist_admit(self, ag):
        """Distributed admission: same contract, stacked `[k, ...]` state.

        `src` here is `[k, D]` — a seeded lane's root appears as a LOCAL
        slot on exactly the shard that masters it (sentinel `num_slots`
        everywhere else), so the vmapped per-shard body is identical to the
        single-shard one.  `lane_active` stays replicated: row 0 is updated
        and broadcast.
        """
        p, D = self.program, self.num_lanes
        cap, slots = ag.cap, ag.num_slots
        identity = p.monoid.identity
        aux = {"out_degree": jnp.asarray(ag.out_degree),
               "global_id": jnp.asarray(
                   ag.new2old.reshape(ag.k, cap).astype(np.float32))}

        def one_shard(vd, sd, act, aux_i, src_i, lanes, mask):
            init_vd = p.init_vertex_data(cap, aux_i)
            bmask = mask.reshape((1, D) + (1,) * (vd.ndim - 2))
            vd = jnp.where(bmask, init_vd, vd)
            sd0 = jnp.asarray(p.init_scatter_data(cap, aux_i), p.msg_dtype)
            sd_init = jnp.full((slots,) + sd0.shape[1:], identity,
                               p.msg_dtype).at[:cap].set(sd0)
            sd = jnp.where(mask[None, :], sd_init, sd)
            # same stale-row normalization as the single-shard admit (an
            # inactive seed vertex's row was already delivered)
            rows = jnp.take(sd, src_i, axis=0, mode="fill",
                            fill_value=identity)
            keep = jnp.take(act, src_i, mode="fill", fill_value=False)
            rows = jnp.where(keep.reshape((D,) + (1,) * (rows.ndim - 1)),
                             rows, identity)
            sd = sd.at[src_i].set(rows, mode="drop")
            if p.seed_sources is not None:
                vd, sd = p.seed_sources(vd, sd, src_i, lanes, aux_i)
            else:
                vd = vd.at[src_i, lanes].set(0.0, mode="drop")
                sd = sd.at[src_i, lanes].set(0.0, mode="drop")
            act = act.at[src_i].set(True, mode="drop")
            return vd, sd, act

        def admit(state, src, lanes, flags):
            mask = jnp.zeros(D, dtype=bool).at[lanes].set(True, mode="drop")
            vd, sd, act = jax.vmap(
                lambda v, s, a, x, si: one_shard(v, s, a, x, si, lanes, mask)
            )(state.vertex_data, state.scatter_data, state.active_scatter,
              aux, src)
            row = state.lane_active[0].at[lanes].set(flags, mode="drop")
            la = jnp.broadcast_to(row[None, :], state.lane_active.shape)
            return dataclasses.replace(
                state, vertex_data=vd, scatter_data=sd, active_scatter=act,
                lane_active=la)

        return jax.jit(admit)

    # --------------------------------------------------------------- serving
    def submit(self, source: int, *, kind: Optional[str] = None,
               max_supersteps: Optional[int] = None) -> Query:
        q = Query(uid=self._uid, source=int(source),
                  kind=kind or self.program.name,
                  max_supersteps=(max_supersteps if max_supersteps is not None
                                  else self.default_budget),
                  submitted_at=self.clock())
        self._uid += 1
        if self._first_submit is None:
            self._first_submit = q.submitted_at
        self.queue.append(q)
        return q

    @property
    def busy(self) -> bool:
        return any(q is not None for q in self._lane_query)

    @property
    def idle(self) -> bool:
        return not self.busy and not self.queue

    def _lane_active_host(self) -> np.ndarray:
        la = np.asarray(jax.device_get(self.state.lane_active))
        return la[0] if la.ndim == 2 else la

    def _vertex_data_host(self) -> np.ndarray:
        vd = np.asarray(jax.device_get(self.state.vertex_data))
        if not self._dist:
            return vd
        ag = self._ag
        flat = vd.reshape(ag.k * ag.cap, *vd.shape[2:])
        return flat[ag.old2new]   # back to ORIGINAL vertex order

    def _lane_result(self, vd_host: np.ndarray, lane: int) -> np.ndarray:
        if self.program.lane_view is not None:
            return np.asarray(self.program.lane_view(vd_host, lane))
        return vd_host[:, lane].copy()

    def pump(self) -> List[Query]:
        """Retire converged lanes, evict over-budget ones, land any pending
        graph delta once the lanes drain, admit from the queue — host-side,
        between ticks; ends with at most ONE jitted static-shape admit call
        covering every lane transition."""
        D = self.num_lanes
        finished: List[Query] = []
        la = self._lane_active_host()
        vd_host = None
        ops: Dict[int, int] = {}   # lane -> src (sentinel = reset only)
        sentinel_src = (self._ag.num_slots if self._dist
                        else self._part.num_slots)
        now = self.clock()
        for d in range(D):
            q = self._lane_query[d]
            if q is None:
                continue
            if not la[d]:            # converged: fetch result, free the lane
                if vd_host is None:
                    vd_host = self._vertex_data_host()
                q.result = self._lane_result(vd_host, d)
                q.status, q.finished_at = "done", now
                finished.append(q)
                self._lane_query[d] = None
            elif (q.max_supersteps is not None
                  and q.supersteps_used >= q.max_supersteps):
                q.status, q.finished_at = "evicted", now   # budget exceeded
                finished.append(q)
                self._lane_query[d] = None
                ops[d] = sentinel_src        # reset the lane, seed nothing
        # "finish"-policy deltas land here: every resident lane has drained
        # (their results above were fetched from the pre-delta snapshot),
        # so the swap is between ticks by construction — never torn.  A
        # still-pending delta holds admissions so it lands in bounded time.
        if self._pending_deltas and not self.busy:
            self._swap_target()
            ops = {}   # stale resets target the replaced state; drop them
        for d in range(D):
            if self._pending_deltas:
                break                # hold admissions until the delta lands
            if self._lane_query[d] is None and self.queue:
                q = self.queue.popleft()
                q.status, q.lane, q.admitted_at = "running", d, now
                q.supersteps_used = 0
                self._lane_query[d] = q
                ops[d] = self._local_src(q.source)   # admit overrides evict
        if ops:
            self._apply_ops(ops)
        self.finished.extend(finished)
        return finished

    def _apply_ops(self, ops: Dict[int, int]) -> None:
        """ONE jitted admit call applying `lane -> src` transitions
        (sentinel src = reset without seeding)."""
        D = self.num_lanes
        sentinel_src = (self._ag.num_slots if self._dist
                        else self._part.num_slots)
        lanes = np.full(D, D, np.int32)              # sentinel lane = D
        flags = np.zeros(D, dtype=bool)
        src = (np.full((self._ag.k, D), sentinel_src, np.int32)
               if self._dist else np.full(D, sentinel_src, np.int32))
        for i, (d, s) in enumerate(ops.items()):
            lanes[i] = d
            if isinstance(s, tuple):                 # dist admit: seed on
                shard, slot = s                      # the mastering shard
                src[shard, i] = slot
                flags[i] = True
            elif s != sentinel_src:                  # single-shard admit
                src[i] = s
                flags[i] = True
        self.state = self._admit_fn(self.state, jnp.asarray(src),
                                    jnp.asarray(lanes), jnp.asarray(flags))

    # ------------------------------------------------------- graph mutation
    def apply_delta(self, delta, *, policy: str = "finish") -> None:
        """Land an `EdgeDelta` on a live batcher (docs/incremental.md).

        Ticks are whole-state jitted calls over an immutable topology
        snapshot, so a delta NEVER lands mid-tick — a torn read (a query
        observing half the mutation) cannot exist by construction.  The
        policy decides what happens to queries resident in lanes:

          "finish" — residents run to completion on the pre-delta
              snapshot; the swap happens at the first `pump()` after the
              last resident drains.  Admissions are HELD while a delta is
              pending, bounding the wait by the slowest resident.
          "reseed" — the swap happens now; residents are re-seeded from
              superstep 0 on the mutated graph in their lanes (fresh
              init values, so no invalidation pass is needed — any
              program the batcher can serve supports this).  Their
              `supersteps_used` keeps accumulating toward the budget.

        Either way, queries admitted after this call run on the mutated
        graph, and recycled-lane results stay bitwise-equal to fresh runs
        (tests/test_serving.py).
        """
        assert policy in ("finish", "reseed"), policy
        self._pending_deltas.append(delta)
        if policy == "finish":
            if not self.busy:
                self._swap_target()
            return
        residents = [(d, q) for d, q in enumerate(self._lane_query)
                     if q is not None]
        self._swap_target()
        if residents:
            self._apply_ops({d: self._local_src(q.source)
                             for d, q in residents})

    def _swap_target(self) -> None:
        """Apply every pending delta to the topology and rebuild the jitted
        tick/admit functions + a fresh lane state.  Callers guarantee no
        lane holds a query whose state must survive (drained, or about to
        be re-seeded)."""
        deltas, self._pending_deltas = self._pending_deltas, []
        if self._dist:
            from repro.core.agent_graph import apply_edge_delta
            for delta in deltas:
                self._ag, _ = apply_edge_delta(self._ag, delta)
            self._topo = self.engine.device_topology(self._ag)
            self._tick_fn = self.engine.make_superstep(
                self._ag, steps_per_tick=self.steps_per_tick)
            self._admit_fn = self._make_dist_admit(self._ag)
            self.state = self.engine.init_state(
                self._ag, source=[None] * self.num_lanes,
                lane_tracking=True)
        else:
            for delta in deltas:
                self._part, _ = self._part.apply_edge_delta(delta)
            # stale-PlanCache fix: a mutated partition re-keys the tuned
            # plan before the new tick function traces
            self.engine.refresh_plan(self._part)
            self._tick_fn = self._make_tick(self._part)
            self._admit_fn = self._make_admit(self._part)
            self.state = self.engine.init_state(
                self._part, source=[None] * self.num_lanes,
                lane_tracking=True)
        # refresh_plan / re-keyed cache hits can adopt a compacted plan for
        # the mutated graph; sum-monoid lanes must stay dense (see
        # `_clamp_sum_monoid_plan`).
        self._clamp_sum_monoid_plan()

    def _local_src(self, source: int):
        """Original vertex id → admit-operand encoding: the local slot
        (single shard) or a (shard, local_slot) pair (distributed)."""
        if not self._dist:
            return int(source)
        g = int(self._ag.old2new[int(source)])
        return (g // self._ag.cap, g % self._ag.cap)

    def tick(self) -> None:
        """Advance every resident lane by `steps_per_tick` supersteps."""
        self._busy_lane_ticks += sum(
            q is not None for q in self._lane_query)
        if self._dist:
            self.state = self._tick_fn(self._topo, self.state)
        else:
            self.state = self._tick_fn(self.state)
        self.ticks += 1
        self.supersteps += self.steps_per_tick
        for q in self._lane_query:
            if q is not None:
                q.supersteps_used += self.steps_per_tick

    def run(self, max_ticks: int = 100_000) -> List[Query]:
        """Pump/tick until queue and lanes drain; returns queries finished
        during this call (done or evicted), in completion order."""
        out = list(self.pump())
        while self.busy and self.ticks < max_ticks:
            self.tick()
            out.extend(self.pump())
        return out

    # --------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        """SLO metrics over everything finished so far (docs/serving.md)."""
        done = [q for q in self.finished if q.status == "done"]
        lat = sorted(q.latency_s for q in done)
        steps = sorted(float(q.supersteps_used) for q in done)
        waits = [q.wait_s for q in done]
        span = (max(q.finished_at for q in done) - self._first_submit
                if done and self._first_submit is not None else 0.0)
        cap = self.ticks * self.num_lanes
        return {
            "queries_done": float(len(done)),
            "queries_evicted": float(
                sum(q.status == "evicted" for q in self.finished)),
            "ticks": float(self.ticks),
            "supersteps": float(self.supersteps),
            "lane_occupancy": self._busy_lane_ticks / cap if cap else 0.0,
            "qps": len(done) / span if span > 0 else float("nan"),
            "latency_p50_s": _percentile(lat, 0.50),
            "latency_p95_s": _percentile(lat, 0.95),
            "latency_mean_s": float(np.mean(lat)) if lat else float("nan"),
            "queue_wait_mean_s": (float(np.mean(waits)) if waits
                                  else float("nan")),
            "supersteps_p50": _percentile(steps, 0.50),
            "supersteps_p95": _percentile(steps, 0.95),
        }


class ServingFrontend:
    """Routes a mixed-kind query stream to per-kind batchers.

    Payload lanes batch queries of ONE program, so a deployment serving
    BFS + SSSP + PPR runs one `GraphQueryBatcher` per kind; the frontend
    owns submission routing and a fair round-robin tick loop (each busy
    batcher advances one tick per round)."""

    def __init__(self, batchers: Dict[str, GraphQueryBatcher]):
        self.batchers = batchers

    def submit(self, kind: str, source: int, **kw) -> Query:
        return self.batchers[kind].submit(source, kind=kind, **kw)

    @property
    def idle(self) -> bool:
        return all(b.idle for b in self.batchers.values())

    def step(self) -> List[Query]:
        """One round: pump every batcher, tick the busy ones."""
        out: List[Query] = []
        for b in self.batchers.values():
            out.extend(b.pump())
            if b.busy:
                b.tick()
        return out

    def run(self, max_rounds: int = 100_000) -> List[Query]:
        out: List[Query] = []
        for _ in range(max_rounds):
            out.extend(self.step())
            if self.idle:
                break
        for b in self.batchers.values():
            out.extend(b.pump())
        return out

    def metrics(self) -> Dict[str, Dict[str, float]]:
        return {kind: b.metrics() for kind, b in self.batchers.items()}
