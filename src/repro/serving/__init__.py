"""Serving layer: graph-query continuous batching + LM continuous batching.

The graph side (`graph_scheduler`) depends only on the core engine and is
imported eagerly.  The LM `ContinuousBatcher` pulls in the transformer
stack (`repro.models`), which not every deployment ships — those names are
resolved lazily on first attribute access so `import repro.serving` works
without the models extras.
"""
from repro.serving.graph_scheduler import (GraphQueryBatcher, Query,
                                           ServingFrontend, poisson_ticks)

__all__ = ["GraphQueryBatcher", "Query", "ServingFrontend", "poisson_ticks",
           "ContinuousBatcher", "Request"]

_LM_EXPORTS = ("ContinuousBatcher", "Request")


def __getattr__(name):
    if name in _LM_EXPORTS:
        from repro.serving import scheduler
        return getattr(scheduler, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
