"""Continuous-batching serving scheduler (slot-based, vLLM-style at the
batch level): a fixed decode batch of B slots over a static KV cache;
incoming requests prefill into free slots while other slots keep decoding —
no decode step ever waits for a long prompt, and the jitted step functions
never recompile (static shapes).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [plen] int32
    max_new: int = 32
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, params, cfg: LMConfig, batch_slots: int,
                 max_len: int):
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.max_len = max_len
        self.cache = tfm.init_cache(cfg, batch_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self._next_tok = np.zeros(batch_slots, np.int32)

        self._prefill = jax.jit(lambda p, t: tfm.prefill(p, t, cfg,
                                                         max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t: tfm.decode_step(p, c, t, cfg),
            donate_argnums=(1,))

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request):
        assert req.prompt.shape[0] < self.max_len
        self.queue.append(req)

    def _admit(self):
        """Fill free slots from the queue: prefill the prompt and splice its
        KV into the slot's rows of the batch cache."""
        for b in range(self.B):
            if self.slot_req[b] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, pc = self._prefill(self.params, jnp.asarray(
                req.prompt[None, :]))
            plen = req.prompt.shape[0]
            self.cache = {
                "k": self.cache["k"].at[:, b].set(pc["k"][:, 0]),
                "v": self.cache["v"].at[:, b].set(pc["v"][:, 0]),
                "len": self.cache["len"].at[b].set(plen),
            }
            tok = int(jnp.argmax(logits[0]))
            req.out.append(tok)
            self._next_tok[b] = tok
            self.slot_req[b] = req

    def _retire(self, b: int):
        self.slot_req[b].done = True
        self.slot_req[b] = None
        self.cache = {**self.cache, "len": self.cache["len"].at[b].set(0)}

    # ------------------------------------------------------------------ step
    def step(self) -> int:
        """Admit waiting requests, run ONE decode step for every active
        slot, harvest finished requests.  Returns #active slots."""
        self._admit()
        active = [b for b in range(self.B) if self.slot_req[b] is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(self._next_tok))
        toks = np.asarray(jnp.argmax(logits, -1), np.int32)
        for b in active:
            req = self.slot_req[b]
            tok = int(toks[b])
            req.out.append(tok)
            self._next_tok[b] = tok
            length = int(self.cache["len"][b])
            if (len(req.out) >= req.max_new
                    or (req.eos_id is not None and tok == req.eos_id)
                    or length >= self.max_len - 1):
                self._retire(b)
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
