"""Feed-forward blocks: gated (SwiGLU / LLaMA-style) and plain MLP
(Nemotron squared-ReLU, Cohere)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import ACTIVATIONS, dense_init


def ffn_init(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d_model, d_ff, dtype),
         "w_out": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def ffn_apply(params, x: jnp.ndarray, activation: str = "silu") -> jnp.ndarray:
    act = ACTIVATIONS[activation]
    h = x @ params["w_in"]
    if "w_gate" in params:
        h = act(x @ params["w_gate"]) * h
    else:
        h = act(h)
    return h @ params["w_out"]
