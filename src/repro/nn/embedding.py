"""Embedding lookup / EmbeddingBag built from gather + segment-sum.

JAX has no native EmbeddingBag; we build it from `jnp.take` +
`jax.ops.segment_sum` (the same scatter-combine primitive as the graph
engine).  The row-sharded distributed lookup follows the combiner-agent
pattern: every shard computes masked partial bags from its local rows, then
ONE `psum` merges them (instead of per-id network gathers).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def embedding_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.05).astype(dtype)


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray, bag_ids: jnp.ndarray,
                  num_bags: int, mode: str = "sum",
                  weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Multi-hot bag reduce: ids [N] (flattened bag members), bag_ids [N]
    (which bag each id belongs to), → [num_bags, D]."""
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, bag_ids, num_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, table.dtype), bag_ids,
                                  num_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def sharded_embedding_lookup(table_local: jnp.ndarray, ids: jnp.ndarray,
                             shard_index: jnp.ndarray, rows_per_shard: int,
                             axis_name) -> jnp.ndarray:
    """Row-sharded lookup under shard_map (combiner-agent pattern).

    table_local: [rows_per_shard, D] — this shard's rows
    ids: [...]: GLOBAL row ids (replicated across the table axis)
    Returns [..., D] psum'd over `axis_name`.
    """
    lo = shard_index * rows_per_shard
    local = ids - lo
    hit = (local >= 0) & (local < rows_per_shard)
    rows = jnp.take(table_local, jnp.clip(local, 0, rows_per_shard - 1),
                    axis=0)
    rows = jnp.where(hit[..., None], rows, 0)
    return jax.lax.psum(rows, axis_name)
