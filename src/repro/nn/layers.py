"""Basic functional layers (params are plain pytrees; no framework)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"gamma": jnp.ones((d,), dtype), "beta": jnp.zeros((d,), dtype)}


def layernorm(x: jnp.ndarray, p, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * p["gamma"].astype(dt) + p["beta"].astype(dt)


def mlp_init(key, dims, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return [{"w": dense_init(k, a, b, dtype), "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp_apply(params, x, act=jax.nn.silu, final_act=False):
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def squared_relu(x: jnp.ndarray) -> jnp.ndarray:
    """Nemotron-4's activation (arXiv:2402.16819): relu(x)**2."""
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
}
