"""Grouped-query attention with RoPE: reference, chunked (flash-style), and
KV-cache decode paths.

`impl="chunked"` is the memory-bounded path used by the dry-run/training at
scale: a `lax.scan` over query blocks with an inner online-softmax scan over
KV blocks, so no [S, S] score tensor ever materializes (the pure-XLA
equivalent of the Pallas flash kernel in `repro.kernels.flash_attention`,
which replaces it on real TPUs via `use_pallas`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def rope_freqs(d_head: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., S, H] with positions [..., S] (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # [H/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, H/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def _gqa_scores_ref(q, k, v, causal: bool, q_offset: int = 0):
    """Reference full-matrix attention.  q:[B,Sq,Kv,G,H] k,v:[B,Sk,Kv,H]."""
    B, Sq, Kv, G, H = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(H)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(q.dtype), v)
    return o


def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, impl: str = "reference",
                  q_chunk: int = 512, kv_chunk: int = 1024) -> jnp.ndarray:
    """q: [B, Sq, n_kv, group, d_head]; k, v: [B, Sk, n_kv, d_head]."""
    if impl == "reference":
        return _gqa_scores_ref(q, k, v, causal)
    if impl == "chunked":
        return flash_attention_jax(q, k, v, causal, q_chunk, kv_chunk)
    raise ValueError(impl)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_jax(q, k, v, causal: bool, q_chunk: int, kv_chunk: int):
    """Memory-exact flash attention with a hand-written backward.

    Forward: blocked online softmax (nothing O(S·S) materializes).
    Backward: recomputes s/p per (q, kv) block pair from the saved
    (q, k, v, o, m, l) — the FlashAttention-2 recipe — so the residuals are
    O(S·D), not O(S²).  This is what lets a 104B train_4k step fit HBM; the
    Pallas kernel provides the same forward on real TPUs.
    """
    o, _, _ = _flash_fwd_stats(q, k, v, causal, q_chunk, kv_chunk)
    return o


def _flash_fwd_stats(q, k, v, causal, q_chunk, kv_chunk):
    o, m, l = _gqa_chunked(q, k, v, causal, q_chunk, kv_chunk,
                           return_stats=True)
    return o, m, l


def _flash_fwd_rule(q, k, v, causal, q_chunk, kv_chunk):
    o, m, l = _flash_fwd_stats(q, k, v, causal, q_chunk, kv_chunk)
    return o, (q, k, v, o, m, l)


def _flash_bwd_rule(causal, q_chunk, kv_chunk, res, do):
    q, k, v, o, m, l = res
    B, Sq, Kv, G, H = q.shape
    Sk = k.shape[1]
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)
    scale = 1.0 / np.sqrt(H)
    pad_q = nq * qc - Sq
    pad_k = nk * kc - Sk

    def padq(x):
        return jnp.pad(x, ((0, 0), (0, pad_q)) + ((0, 0),) * (x.ndim - 2))

    def padk(x):
        return jnp.pad(x, ((0, 0), (0, pad_k)) + ((0, 0),) * (x.ndim - 2))

    qp, op, dop = padq(q), padq(o), padq(do)
    kp, vp = padk(k), padk(v)
    # stats in [B, Kv, G, Sq]
    mp = jnp.pad(m, ((0, 0),) * 3 + ((0, pad_q),), constant_values=0.0)
    lp = jnp.pad(l, ((0, 0),) * 3 + ((0, pad_q),), constant_values=1.0)
    delta = jnp.einsum("bqkgh,bqkgh->bkgq", dop.astype(jnp.float32),
                       op.astype(jnp.float32))                   # [B,Kv,G,Sq]

    qb = qp.reshape(B, nq, qc, Kv, G, H).transpose(1, 0, 2, 3, 4, 5)
    ob = dop.reshape(B, nq, qc, Kv, G, H).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(B, nk, kc, Kv, H).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, kc, Kv, H).transpose(1, 0, 2, 3, 4)
    mb = mp.reshape(B, Kv, G, nq, qc).transpose(3, 0, 1, 2, 4)
    lb = lp.reshape(B, Kv, G, nq, qc).transpose(3, 0, 1, 2, 4)
    db = delta.reshape(B, Kv, G, nq, qc).transpose(3, 0, 1, 2, 4)

    def block_p_ds(qi, ki, q_i, k_j, m_i, l_i, d_i, do_i, v_j):
        s = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j,
                       preferred_element_type=jnp.float32) * scale
        kpos = ki * kc + jnp.arange(kc)
        if causal:
            qpos = qi * qc + jnp.arange(qc)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
        if pad_k:  # padded kv positions contribute nothing
            s = jnp.where(kpos[None, :] < Sk, s, NEG_INF)
        p = jnp.exp(s - m_i[..., None]) / jnp.maximum(l_i, 1e-30)[..., None]
        dp = jnp.einsum("bqkgh,bskh->bkgqs", do_i, v_j,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - d_i[..., None]) * scale
        return p, ds

    # Two passes (dq per q block; dk/dv per kv block).  A fused single-pass
    # variant carrying full-size dq across the kv scan was tried and REFUTED
    # (§Perf bonus iteration: the seq-sharded dq carry is re-gathered every
    # kv step, +19% collective bytes on command-r train_4k).
    def dq_block(args):
        qi, q_i, do_i, m_i, l_i, d_i = args

        def inner(acc, inp):
            ki, k_j, v_j = inp
            p, ds = block_p_ds(qi, ki, q_i, k_j, m_i, l_i, d_i, do_i, v_j)
            return acc + jnp.einsum("bkgqs,bskh->bqkgh",
                                    ds.astype(q.dtype), k_j), None

        acc0 = jnp.zeros((B, qc, Kv, G, H), q.dtype)
        acc, _ = jax.lax.scan(inner, acc0, (jnp.arange(nk), kb, vb))
        return acc

    dqb = jax.lax.map(dq_block, (jnp.arange(nq), qb, ob, mb, lb, db))
    dq = dqb.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, Kv, G, H)[:, :Sq]

    # dk/dv: for each kv block, reduce over q blocks
    def dkv_block(args):
        ki, k_j, v_j = args

        def inner(acc, inp):
            qi, q_i, do_i, m_i, l_i, d_i = inp
            dk_a, dv_a = acc
            p, ds = block_p_ds(qi, ki, q_i, k_j, m_i, l_i, d_i, do_i, v_j)
            dv_a = dv_a + jnp.einsum("bkgqs,bqkgh->bskh", p.astype(q.dtype),
                                     do_i)
            dk_a = dk_a + jnp.einsum("bkgqs,bqkgh->bskh", ds.astype(q.dtype),
                                     q_i)
            return (dk_a, dv_a), None

        acc0 = (jnp.zeros((B, kc, Kv, H), q.dtype),
                jnp.zeros((B, kc, Kv, H), q.dtype))
        (dk_a, dv_a), _ = jax.lax.scan(
            inner, acc0, (jnp.arange(nq), qb, ob, mb, lb, db))
        return dk_a, dv_a

    dkb, dvb = jax.lax.map(dkv_block, (jnp.arange(nk), kb, vb))
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, Kv, H)[:, :Sk]
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, Kv, H)[:, :Sk]
    return dq, dk, dv


flash_attention_jax.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def _gqa_chunked(q, k, v, causal: bool, q_chunk: int, kv_chunk: int,
                 return_stats: bool = False):
    """Flash-style online softmax: scan over q blocks, inner scan over kv."""
    B, Sq0, Kv, G, H = q.shape
    Sk0 = k.shape[1]
    q_chunk = min(q_chunk, Sq0)
    kv_chunk = min(kv_chunk, Sk0)
    # pad to chunk multiples (safe under the causal mask: padded kv positions
    # are beyond every real query position)
    Sq = -(-Sq0 // q_chunk) * q_chunk
    Sk = -(-Sk0 // kv_chunk) * kv_chunk
    q = jnp.pad(q, ((0, 0), (0, Sq - Sq0)) + ((0, 0),) * 3)
    k = jnp.pad(k, ((0, 0), (0, Sk - Sk0)) + ((0, 0),) * 2)
    v = jnp.pad(v, ((0, 0), (0, Sk - Sk0)) + ((0, 0),) * 2)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = 1.0 / np.sqrt(H)

    qb = q.reshape(B, nq, q_chunk, Kv, G, H).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_chunk, Kv, H).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_chunk, Kv, H).transpose(1, 0, 2, 3, 4)

    def q_block(qi, q_i):
        # online softmax state over kv blocks
        m0 = jnp.full((B, Kv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Kv, G, q_chunk, H), jnp.float32)

        def kv_block(carry, inp):
            m, l, acc = carry
            ki, k_j, v_j = inp
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
            if Sk != Sk0:  # mask padded kv positions
                s = jnp.where(kpos[None, :] < Sk0, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(q.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (ks, kb, vb))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return (o.transpose(0, 3, 1, 2, 4).astype(q.dtype),  # [B,qc,Kv,G,H]
                m, l)

    outs, ms, ls = jax.lax.map(lambda args: q_block(*args),
                               (jnp.arange(nq), qb))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Kv, G, H)[:, :Sq0]
    if not return_stats:
        return out
    # stats [nq, B, Kv, G, qc] -> [B, Kv, G, Sq]
    m_full = ms.transpose(1, 2, 3, 0, 4).reshape(B, Kv, G, Sq)[..., :Sq0]
    l_full = ls.transpose(1, 2, 3, 0, 4).reshape(B, Kv, G, Sq)[..., :Sq0]
    return out, m_full, l_full


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray) -> jnp.ndarray:
    """Single-step decode: q [B, 1, Kv, G, H]; caches [B, S, Kv, H];
    cache_len [B] — valid prefix length (the new token's position)."""
    B, _, Kv, G, H = q.shape
    S = k_cache.shape[1]
    scale = 1.0 / np.sqrt(H)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k_cache) * scale
    valid = jnp.arange(S)[None, :] <= cache_len[:, None]          # [B, S]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(q.dtype), v_cache)
