"""Mixture-of-Experts FFN as a Scatter-Combine instance.

Token→expert dispatch IS the paper's scatter (an active message whose payload
is the token's hidden state), and the weighted top-k merge IS the combine
(⊕ = weighted sum).  The implementation follows the agent pattern:

  * routing is computed redundantly on every expert shard (router weights
    are replicated; tokens are replicated across the expert axis after the
    attention all-reduce), so dispatch needs NO token movement;
  * each expert shard computes partial outputs for the (token, expert) hits
    it owns — the local pre-combination of a combiner agent;
  * ONE `psum` over the expert axis merges partials — the single
    combiner→master message.

Sort-based capacity dispatch: hits are argsorted by local expert id and
packed into a static [E_loc, C, D] buffer (overflow tokens are dropped,
standard capacity-factor semantics).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import ACTIVATIONS, dense_init


def moe_init(key, d_model: int, d_ff: int, n_experts: int, gated: bool,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d_model)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_in": (jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * scale).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (n_experts, d_ff, d_model)) *
                  (1.0 / jnp.sqrt(d_ff))).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[3], (n_experts, d_model, d_ff)) * scale).astype(dtype)
    return p


def moe_ffn(params, x: jnp.ndarray, top_k: int, n_experts: int,
            capacity_factor: float = 1.25, activation: str = "silu",
            shard_index: Optional[jnp.ndarray] = None,
            n_shards: int = 1, axis_name=None):
    """x: [T, D] tokens.  Expert weights in `params` hold the LOCAL shard
    [E_loc, D, F] when running under shard_map (n_shards > 1); the router is
    always the full [D, E] matrix.

    Returns (out [T, D] — psum'd over `axis_name` if given, aux_loss scalar).
    """
    T, D = x.shape
    act = ACTIVATIONS[activation]
    e_loc = params["w_in"].shape[0]
    assert e_loc * n_shards == n_experts, (e_loc, n_shards, n_experts)
    my = shard_index if shard_index is not None else 0

    # ---- routing (replicated across expert shards; deterministic) ----
    logits = (x.astype(jnp.float32) @ params["router"])           # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(gates, top_k)                    # [T, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * mean(frac_tokens * frac_prob)
    counts = jnp.zeros(n_experts).at[top_i.reshape(-1)].add(1.0)
    aux = n_experts * jnp.mean((counts / (T * top_k)) * gates.mean(0))

    # ---- scatter: pack this shard's hits into [E_loc, C, D] ----
    flat_e = top_i.reshape(-1)                                    # [T*K]
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    mine = (flat_e // e_loc) == my
    le = jnp.where(mine, flat_e - my * e_loc, e_loc)              # E_loc = drop bucket
    order = jnp.argsort(le, stable=True)
    le_s, t_s, w_s = le[order], flat_t[order], flat_w[order]
    seg_counts = jnp.zeros(e_loc + 1, jnp.int32).at[le_s].add(1)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(seg_counts)[:-1]])
    pos = jnp.arange(T * top_k, dtype=jnp.int32) - offsets[le_s]
    cap = int(max(8, round(T * top_k / n_experts * capacity_factor)))
    keep = (le_s < e_loc) & (pos < cap)
    tgt_e = jnp.where(keep, le_s, e_loc)
    tgt_c = jnp.where(keep, pos, 0)
    # Invert the (token, k) -> slot mapping FIRST with integer scatters
    # (bytes ~ E_loc·cap ints), so the feature-dim gather/scatter below touch
    # only the [E_loc, cap, D] capacity buffer — ~top_k× less HBM traffic
    # than gathering x[t_s] for every (token, k) pair (§Perf iteration 1).
    w_eff = jnp.where(keep, w_s, 0.0).astype(x.dtype)
    tokmap = jnp.zeros((e_loc + 1, cap), jnp.int32
                       ).at[tgt_e, tgt_c].set(t_s.astype(jnp.int32))
    wmap = jnp.zeros((e_loc + 1, cap), x.dtype).at[tgt_e, tgt_c].set(w_eff)
    valid = jnp.zeros((e_loc + 1, cap), bool).at[tgt_e, tgt_c].set(keep)
    b = jnp.where(valid[:e_loc, :, None],
                  jnp.take(x, tokmap[:e_loc], axis=0), 0)

    # ---- expert compute on the packed buffer ----
    h = jnp.einsum("ecd,edf->ecf", b, params["w_in"])
    if "w_gate" in params:
        h = act(jnp.einsum("ecd,edf->ecf", b, params["w_gate"])) * h
    else:
        h = act(h)
    y = jnp.einsum("ecf,efd->ecd", h, params["w_out"])            # [E_loc, C, D]

    # ---- combine: weighted scatter-add back to tokens (⊕ = sum) ----
    out = jnp.zeros((T, D), x.dtype).at[tokmap[:e_loc].reshape(-1)].add(
        (wmap[:e_loc, :, None] * y).reshape(-1, D))
    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)                        # combiner flush
    return out, aux


def moe_ffn_reference(params, x: jnp.ndarray, top_k: int, n_experts: int,
                      activation: str = "silu") -> jnp.ndarray:
    """Dense oracle: run every token through its top-k experts exactly
    (no capacity dropping).  For tests."""
    act = ACTIVATIONS[activation]
    logits = x.astype(jnp.float32) @ params["router"]
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(gates, top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    h = jnp.einsum("td,edf->tef", x, params["w_in"])
    if "w_gate" in params:
        h = act(jnp.einsum("td,edf->tef", x, params["w_gate"])) * h
    else:
        h = act(h)
    y = jnp.einsum("tef,efd->ted", h, params["w_out"])            # [T, E, D]
    sel = jnp.take_along_axis(y, top_i[:, :, None], axis=1)       # [T, K, D]
    return jnp.einsum("tk,tkd->td", top_w.astype(x.dtype), sel)
