from repro.nn import layers, attention, ffn, moe, embedding
