"""E(3)-equivariant building blocks for MACE: real spherical harmonics and
numerically-projected Clebsch-Gordan coupling tensors.

Convention-free CG construction: for each (l1, l2 → l3) we find the tensor
C with  C · (D_l1(R) ⊗ D_l2(R)) = D_l3(R) · C  for all rotations R by group-
averaging a random tensor over sampled rotations (projection onto the
equivariant subspace) and orthonormalizing.  Wigner matrices D_l(R) are
obtained numerically from the polynomial definition of the real harmonics,
so everything is self-consistent by construction; the equivariance tests
validate it end to end (rotation invariance of MACE energies).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np


def real_sh_np(r: np.ndarray, l_max: int) -> Dict[int, np.ndarray]:
    """Real solid harmonics on unit vectors r [..., 3], polynomial basis."""
    x, y, z = r[..., 0], r[..., 1], r[..., 2]
    out = {0: np.ones(r.shape[:-1] + (1,), r.dtype)}
    if l_max >= 1:
        out[1] = np.stack([y, z, x], axis=-1)
    if l_max >= 2:
        s3 = np.sqrt(3.0)
        out[2] = np.stack([
            s3 * x * y, s3 * y * z,
            0.5 * (3 * z * z - 1.0),
            s3 * x * z,
            0.5 * s3 * (x * x - y * y)], axis=-1)
    return out


def real_sh(r: jnp.ndarray, l_max: int) -> Dict[int, jnp.ndarray]:
    """JAX version of `real_sh_np` (r: [..., 3] unit vectors)."""
    x, y, z = r[..., 0], r[..., 1], r[..., 2]
    out = {0: jnp.ones(r.shape[:-1] + (1,), r.dtype)}
    if l_max >= 1:
        out[1] = jnp.stack([y, z, x], axis=-1)
    if l_max >= 2:
        s3 = np.sqrt(3.0)
        out[2] = jnp.stack([
            s3 * x * y, s3 * y * z,
            0.5 * (3 * z * z - 1.0),
            s3 * x * z,
            0.5 * s3 * (x * x - y * y)], axis=-1)
    return out


def _random_rotation(rng) -> np.ndarray:
    q = rng.normal(size=4)
    q /= np.linalg.norm(q)
    w, x, y, z = q
    return np.array([
        [1 - 2 * (y * y + z * z), 2 * (x * y - z * w), 2 * (x * z + y * w)],
        [2 * (x * y + z * w), 1 - 2 * (x * x + z * z), 2 * (y * z - x * w)],
        [2 * (x * z - y * w), 2 * (y * z + x * w), 1 - 2 * (x * x + y * y)],
    ])


@lru_cache(maxsize=None)
def _sh_sample_points(l_max: int) -> Tuple[np.ndarray, Dict[int, np.ndarray]]:
    rng = np.random.default_rng(1234)
    pts = rng.normal(size=(64, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    sh = real_sh_np(pts, l_max)
    pinv = {l: np.linalg.pinv(sh[l]) for l in sh}
    return pts, pinv


def wigner_d(l: int, R: np.ndarray) -> np.ndarray:
    """Numeric Wigner matrix: Y_l(R r) = D_l(R) Y_l(r)."""
    if l == 0:
        return np.ones((1, 1))
    pts, pinv = _sh_sample_points(l)
    sh_rot = real_sh_np(pts @ R.T, l)[l]            # [N, 2l+1]
    return (pinv[l] @ sh_rot).T                     # [2l+1, 2l+1]


@lru_cache(maxsize=None)
def cg_tensor(l1: int, l2: int, l3: int, n_rotations: int = 4) -> np.ndarray:
    """Equivariant coupling tensor C [2l3+1, 2l1+1, 2l2+1] (or zeros if the
    path (l1 ⊗ l2 → l3) does not exist).  Normalized to unit Frobenius.

    Exact construction: C is equivariant iff it is a fixed point of
    T_R(C) = D3(R)^{-1} C (D1(R) ⊗ D2(R)) for all R; the common fixed space
    of a few generic rotations equals the full invariant subspace, so we take
    the null space of stacked (T_R − I) — machine-precision accurate.
    """
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    dim = d1 * d2 * d3
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((d3, d1, d2))
    rng = np.random.default_rng(42 + 100 * l1 + 10 * l2 + l3)
    rows = []
    for _ in range(n_rotations):
        R = _random_rotation(rng)
        D1, D2, D3 = wigner_d(l1, R), wigner_d(l2, R), wigner_d(l3, R)
        T = np.kron(np.linalg.inv(D3), np.kron(D1.T, D2.T))
        rows.append(T - np.eye(dim))
    M = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(M)
    null = vt[s.shape[0] - np.sum(s < 1e-8):] if s.shape[0] == dim else vt[dim - 1:]
    # count near-zero singular values (null space dimension)
    nullity = int(np.sum(s < 1e-8)) + (dim - s.shape[0])
    if nullity == 0:
        return np.zeros((d3, d1, d2))
    C = vt[-1].reshape(d3, d1, d2)  # one generator (paths here are 1-dim)
    return C / np.linalg.norm(C)


def valid_paths(l_max: int) -> List[Tuple[int, int, int]]:
    """All (l1, l2, l3) with a nonzero coupling, l ≤ l_max everywhere."""
    paths = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if abs(l1 - l2) <= l3 <= l1 + l2:
                    if np.linalg.norm(cg_tensor(l1, l2, l3)) > 1e-6:
                        paths.append((l1, l2, l3))
    return paths


def bessel_basis(d: jnp.ndarray, n: int, cutoff: float) -> jnp.ndarray:
    """Sine Bessel radial basis (DimeNet eq. 7): sqrt(2/c)·sin(nπd/c)/d."""
    dn = jnp.maximum(d, 1e-6)[..., None]
    freq = np.pi * jnp.arange(1, n + 1)
    return np.sqrt(2.0 / cutoff) * jnp.sin(freq * dn / cutoff) / dn


def cosine_cutoff(d: jnp.ndarray, cutoff: float) -> jnp.ndarray:
    u = jnp.clip(d / cutoff, 0.0, 1.0)
    return 0.5 * (jnp.cos(np.pi * u) + 1.0)
