"""Token data pipeline: deterministic synthetic streams + file-backed corpus.

Shard-aware: each data-parallel rank derives its slice from (seed, step,
rank) so a restarted/elastically-resized job reproduces the exact global
batch order without coordination (the same determinism contract the paper
uses for partition rebuild after failure)."""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    path: Optional[str] = None      # optional corpus file (uint16/uint32 bin)

    def __post_init__(self):
        self._corpus = None
        if self.path and Path(self.path).exists():
            self._corpus = np.fromfile(self.path, dtype=np.uint16)

    def batch_at(self, step: int, rank: int = 0, world: int = 1
                 ) -> Dict[str, np.ndarray]:
        """Global batch `step`, slice for `rank` of `world`."""
        assert self.batch % world == 0
        b_loc = self.batch // world
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, rank]))
        if self._corpus is not None:
            starts = rng.integers(0, len(self._corpus) - self.seq_len - 1,
                                  size=b_loc)
            toks = np.stack([self._corpus[s:s + self.seq_len + 1]
                             for s in starts]).astype(np.int32)
        else:
            # markov-ish synthetic stream: next token depends on previous
            toks = np.zeros((b_loc, self.seq_len + 1), np.int32)
            toks[:, 0] = rng.integers(0, self.vocab, b_loc)
            noise = rng.integers(0, self.vocab, (b_loc, self.seq_len))
            mix = rng.random((b_loc, self.seq_len)) < 0.7
            for t in range(self.seq_len):
                follow = (toks[:, t] * 31 + 7) % self.vocab
                toks[:, t + 1] = np.where(mix[:, t], follow, noise[:, t])
        toks = np.clip(toks, 0, self.vocab - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
