"""Graph fingerprints: the plan cache's lookup key.

A tuned `SuperstepPlan` is only as reusable as the scenario it was
measured on, so cache entries are keyed by the facets that actually move
the frontier/kernel/exchange decisions (the survey result the ROADMAP
cites: no single configuration wins across graphs AND algorithms):

  * **size class** — `num_slots` and `num_edges`, log2-quantized: the
    density crossover and the worth of compaction scale with both, but a
    graph 3% larger must hit the same entry;
  * **degree skew** — max local out-degree over mean, log2-quantized:
    the facet that decides flat vs bucketed tiles (power-law hubs
    poison a flat tile's `max_deg`; `partition_quality.degree_skew` is
    the same statistic measured at partition time);
  * **remote-destination fraction** — share of edges terminating at a
    combiner agent (0.05-quantized; 0 on a single shard): the facet that
    decides whether the pipelined exchange has anything to overlap
    (`partition_quality.remote_dst_edge_fraction`);
  * **frontier density estimate** — the largest live frontier observed
    by the probe harness (`GREEngine.calibrate_frontier_cap` /
    `probe_frontier_hist`) as a fraction of slots, decade-quantized:
    the facet that decides dense vs compacted scanning.  Omitted when no
    histogram is available (iterative dense-frontier programs).

The full cache key (`plan_cache_key`) appends the program's payload
shape, monoid, and halting mode plus the MESH SIZE — the same graph
tuned for an 8-shard agent exchange must not serve its plan to a
single-shard engine.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence


def _q_log2(x: float) -> int:
    """log2 quantization: values within a factor ~1.4 share a bin."""
    return int(round(math.log2(max(float(x), 1.0))))


def graph_fingerprint(num_slots: int, num_edges: int,
                      max_out_degree: int = 0,
                      remote_dst_fraction: float = 0.0,
                      frontier_hist: Optional[Sequence[int]] = None,
                      partitioner: str = "") -> str:
    """Quantized scenario key for one graph/partition layout.

    `partitioner` names the edge-placement heuristic that built the
    layout (`AgentGraph.partitioner`; "" for raw placements and
    single-shard partitions).  Different partitioners reshape the very
    facets the probes measure — remote fraction, skew, exchange load —
    so a plan tuned on a greedy placement must not answer for an HDRF
    one even when both quantize into the same size/skew bins."""
    mean_deg = num_edges / max(num_slots, 1)
    skew = max_out_degree / max(mean_deg, 1e-9) if max_out_degree else 0.0
    parts = [f"v{_q_log2(num_slots)}",
             f"e{_q_log2(num_edges)}",
             f"skew{_q_log2(skew) if skew >= 1.0 else 0}",
             f"rdf{round(remote_dst_fraction / 0.05) * 5}"]
    if partitioner:
        parts.append(f"p:{partitioner}")
    if frontier_hist:
        density = max(frontier_hist) / max(num_slots, 1)
        # decade quantization: 1e-3 and 8e-3 frontiers tune alike,
        # 1e-3 and 0.2 do not
        parts.append(f"fd{int(round(math.log10(max(density, 1e-9))))}")
    return "-".join(parts)


def partition_fingerprint(part, frontier_hist=None,
                          partitioner: str = "") -> str:
    """Fingerprint of a single-shard `DevicePartition` (uses the LIVE edge
    count — `edge_mask.sum()` — and the CSR max degree as the skew
    numerator).

    Counting live edges rather than the padded column length matters for
    mutated partitions: `apply_edge_delta` retires edges into masked
    tombstones and appends into slack WITHOUT changing the padded length,
    so a padded-length key would keep serving a plan tuned for the
    pre-mutation graph forever.  With the live count, log2 quantization
    absorbs small deltas (same bin → same key, the adopted plan stands)
    while large deltas shift a bin and re-key
    (`GREEngine.refresh_plan`).
    """
    if part.src is None:
        num_edges = 0
    elif part.edge_mask is not None:
        import numpy as np
        num_edges = int(np.sum(np.asarray(part.edge_mask)))
    else:
        num_edges = int(part.src.shape[0])
    return graph_fingerprint(part.num_slots, num_edges,
                             max_out_degree=part.csr_max_deg,
                             frontier_hist=frontier_hist,
                             partitioner=partitioner)


def agent_graph_fingerprint(ag, frontier_hist=None) -> str:
    """Fingerprint of an `AgentGraph` layout: per-shard slot space, total
    real edges, worst-shard CSR degree, and the measured combiner-bound
    (remote-destination) edge fraction."""
    import numpy as np
    num_edges = int(np.sum(ag.num_edges))
    comb_base = ag.cap + ag.s_pad
    if num_edges:
        remote = int(np.sum((ag.dst >= comb_base) & ag.edge_mask))
        rdf = remote / num_edges
    else:
        rdf = 0.0
    return graph_fingerprint(ag.num_slots, num_edges,
                             max_out_degree=ag.csr_max_deg,
                             remote_dst_fraction=rdf,
                             frontier_hist=frontier_hist,
                             partitioner=getattr(ag, "partitioner", ""))


def program_fingerprint(program) -> str:
    """The algorithm facets a plan depends on: payload shape (multi-source
    lanes change tile widths and combine cost), monoid (⊕ identity and
    bitwise-vs-tolerance semantics), halting mode (dense-frontier
    iterative programs never compact)."""
    shape = "x".join(str(d) for d in program.payload_shape) or "scalar"
    return f"{shape}-{program.monoid.name}-{'halt' if program.halts else 'iter'}"


def plan_cache_key(part=None, agent_graph=None, program=None,
                   mesh_size: int = 1, frontier_hist=None) -> str:
    """The persistent plan cache's full key:
    `graph fingerprint | program fingerprint | mesh size`."""
    assert (part is None) != (agent_graph is None), \
        "pass exactly one of part/agent_graph"
    if part is not None:
        gfp = partition_fingerprint(part, frontier_hist=frontier_hist)
    else:
        gfp = agent_graph_fingerprint(agent_graph,
                                      frontier_hist=frontier_hist)
    pfp = program_fingerprint(program)
    return f"{gfp}|{pfp}|mesh{mesh_size}"
