"""Persistent plan cache: measured `SuperstepPlan` winners, keyed by
scenario fingerprint.

One JSON file (human-diffable, committed or per-machine) mapping
`plan_cache_key` strings — graph fingerprint + program fingerprint +
mesh size (repro.tuning.fingerprint) — to serialized plans
(`SuperstepPlan.to_json`) plus the probe measurements that crowned them.
Engines constructed with `plan="auto-tuned"` consult it at state init:
a HIT adopts the stored plan and runs ZERO probe supersteps (the search
is skipped entirely — the cache is the point); a MISS silently keeps
the engine's hand-picked defaults.  `tune()` (repro.tuning.search)
writes entries after a search.

File format (`version` guards schema drift; unknown plan fields are
additionally rejected by `SuperstepPlan.from_json`):

    {"version": 1,
     "entries": {"<key>": {"plan": {...}, "probe_us": 123.4,
                           "default_us": 150.2, "space_size": 24}}}

The default location is `$GRE_PLAN_CACHE` or `.gre_plan_cache.json`
under the current directory; tests and benchmarks always pass explicit
paths.
"""
from __future__ import annotations

import json
import os
import warnings
from pathlib import Path
from typing import Dict, Optional

from repro.core.plan import SuperstepPlan

CACHE_VERSION = 1


def default_cache_path() -> Path:
    return Path(os.environ.get("GRE_PLAN_CACHE", ".gre_plan_cache.json"))


class PlanCache:
    """JSON-file-backed plan store.  Reads are lazy and cached; `store`
    re-reads, merges, and atomically rewrites, so concurrent tuners on
    disjoint keys lose at most a race's worth of entries, never the
    file's integrity."""

    def __init__(self, path=None):
        self.path = Path(path) if path is not None else default_cache_path()
        self._data: Optional[Dict] = None

    # ------------------------------------------------------------------ io
    def _load(self) -> Dict:
        if self._data is None:
            if self.path.exists():
                with open(self.path) as f:
                    data = json.load(f)
                if data.get("version") != CACHE_VERSION:
                    # A foreign-version file (e.g. a CI cache restored
                    # across a schema bump) degrades to an EMPTY cache:
                    # every lookup misses, the engine keeps its defaults /
                    # runs a fresh search, and the next `store` rewrites
                    # the file at the current version.  Serving stacks
                    # must not crash on a stale artifact.
                    warnings.warn(
                        f"plan cache {self.path}: version "
                        f"{data.get('version')!r} != {CACHE_VERSION}; "
                        "ignoring stale entries (fresh search fallback)",
                        stacklevel=3)
                    data = {"version": CACHE_VERSION, "entries": {}}
                self._data = data
            else:
                self._data = {"version": CACHE_VERSION, "entries": {}}
        return self._data

    def _write(self) -> None:
        tmp = self.path.with_suffix(self.path.suffix + ".tmp")
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(self._data, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)

    # ----------------------------------------------------------------- api
    def lookup(self, key: str) -> Optional[SuperstepPlan]:
        """The stored winner for `key`, or None (miss).  Raises on a
        schema-drifted entry rather than executing a half-read plan."""
        entry = self._load()["entries"].get(key)
        if entry is None:
            return None
        return SuperstepPlan.from_json(entry["plan"])

    def entry(self, key: str) -> Optional[Dict]:
        """The raw entry dict (plan + measurement metadata), or None."""
        return self._load()["entries"].get(key)

    def store(self, key: str, plan: SuperstepPlan, **meta) -> None:
        """Persist `plan` under `key` with measurement metadata
        (probe_us, default_us, space_size, ...)."""
        self._load()  # ensure version check before mutating
        # merge with any entries written since our read
        if self.path.exists():
            self._data = None
            self._load()
        self._data["entries"][key] = {"plan": plan.to_json(), **meta}
        self._write()

    def keys(self):
        return list(self._load()["entries"])

    def __len__(self) -> int:
        return len(self._load()["entries"])
