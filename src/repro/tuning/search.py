"""Plan search: successive halving over the candidate space, cache-first.

`tune()` is the offline autotuner's front door.  The protocol:

  1. Fingerprint the scenario (graph facets + probe frontier histogram +
     program facets + mesh size — repro.tuning.fingerprint) and consult
     the persistent `PlanCache`.  A HIT returns the stored winner with
     ZERO probe supersteps executed (`Evaluator.num_probes` stays 0 —
     the determinism tests pin this).
  2. On a miss, enumerate the validity-pruned candidate plans
     (`PlanSearchSpace.candidates`, capacity axis anchored on the
     measured histogram via `frontier.default_cap`) and run
     SUCCESSIVE HALVING: every candidate gets a cheap rung (2 probe
     supersteps, 1 timed iter — enough to kill the order-of-magnitude
     losers like a dense scan of a sparse frontier), the top third
     graduates to the full rung (run toward quiescence, median of 3).
     The engine's hand-picked DEFAULT plan is always seeded into the
     final rung, so the stored winner is never slower than the default
     AT PROBE TIME on this machine — the bench suite re-verifies the
     claim end-to-end (`benchmarks/bench_tuning.py`).
  3. Persist the winner keyed by the fingerprint, with the probe
     measurements as provenance (`probe_us`, `default_us`,
     `space_size`).

Determinism: probe times are noisy, but ties and near-ties resolve by
`(us, candidate_index)` — for a FIXED evaluator (the tests drive a fake
deterministic one) the winner is a pure function of the space order.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

from repro.core.engine import GREEngine
from repro.core.frontier import default_cap
from repro.core.plan import SuperstepPlan

from .cache import PlanCache
from .evaluator import Evaluator, ProbeEvaluator
from .fingerprint import plan_cache_key
from .space import PlanSearchSpace

# (probe_steps, timed iters) per rung: cheap cull, then full measurement.
DEFAULT_RUNGS = ((2, 1), (16, 3))


def successive_halving(candidates: Sequence[SuperstepPlan],
                       evaluator: Evaluator,
                       rungs: Tuple[Tuple[int, int], ...] = DEFAULT_RUNGS,
                       survive: float = 1 / 3,
                       min_finalists: int = 2,
                       must_keep: Sequence[int] = (),
                       ) -> Tuple[int, Dict[int, float]]:
    """Rung-by-rung cull; returns (winner index, final-rung times in us).

    `must_keep` indices (the default plan) are re-seeded into the FINAL
    rung even if an early cheap rung culled them, so the winner's final
    measurement is always comparable against the default's.  Ties break
    on candidate index — first enumerated wins.
    """
    assert candidates, "empty candidate space"
    alive = list(range(len(candidates)))
    scores: Dict[int, float] = {}
    for r, (steps, iters) in enumerate(rungs):
        final = r == len(rungs) - 1
        if final:
            for i in must_keep:
                if i not in alive:
                    alive.append(i)
            alive.sort()
        ranked = sorted((evaluator.evaluate(candidates[i], steps, iters), i)
                        for i in alive)
        scores = {i: us for us, i in ranked}
        if final:
            break
        keep = max(min_finalists, math.ceil(len(alive) * survive))
        alive = sorted(i for _, i in ranked[:keep])
    best_us, best_i = min((us, i) for i, us in scores.items())
    return best_i, scores


class TuneResult(NamedTuple):
    plan: SuperstepPlan
    probe_us: float        # winner's final-rung median
    default_us: float      # default plan's final-rung median
    key: str               # plan-cache key the winner is stored under
    from_cache: bool       # True = hit, no probes executed
    num_probes: int        # measured probe evaluations this call


def tune(program, graph, *, source=0, cache=None,
         space: Optional[PlanSearchSpace] = None, force: bool = False,
         rungs: Tuple[Tuple[int, int], ...] = DEFAULT_RUNGS,
         evaluator: Optional[Evaluator] = None,
         warmup: int = 1) -> TuneResult:
    """Tune one (program, graph) scenario; cache-first, halving on miss.

    `cache` is a `PlanCache`, a path, or None (default location);
    `force=True` re-searches and overwrites a hit.  Passing `evaluator`
    substitutes the measurement half (tests inject deterministic
    fakes); it must expose `partition()/frontier_hist()/evaluate()`.
    """
    space = space or PlanSearchSpace()
    if not isinstance(cache, PlanCache):
        cache = PlanCache(cache)
    ev = evaluator or ProbeEvaluator(program, graph, source=source,
                                     warmup=warmup)
    part = ev.partition()
    hist = ev.frontier_hist()
    key = plan_cache_key(part=part, program=program, mesh_size=1,
                         frontier_hist=hist)
    if not force:
        hit = cache.lookup(key)
        if hit is not None:
            meta = cache.entry(key)
            return TuneResult(hit, meta.get("probe_us", 0.0),
                              meta.get("default_us", 0.0), key,
                              from_cache=True, num_probes=0)

    default_plan = GREEngine(program).make_plan()
    dense = default_plan.dense_frontier
    cands = list(space.candidates(part.num_slots,
                                  default_cap(part.num_slots, hist),
                                  dense_frontier=dense,
                                  monotone=program.monotone))
    if default_plan in cands:
        default_i = cands.index(default_plan)
    else:
        cands.append(default_plan)
        default_i = len(cands) - 1

    best_i, scores = successive_halving(cands, ev, rungs=rungs,
                                        must_keep=(default_i,))
    winner = cands[best_i]
    probe_us = scores[best_i]
    default_us = scores[default_i]
    cache.store(key, winner, probe_us=round(probe_us, 1),
                default_us=round(default_us, 1), space_size=len(cands))
    return TuneResult(winner, probe_us, default_us, key,
                      from_cache=False, num_probes=ev.num_probes)
