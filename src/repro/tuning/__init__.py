"""Offline plan autotuner: measured search over the `SuperstepPlan`
space with a persistent plan cache (docs/tuning.md).

Optimizer/evaluator split: `PlanSearchSpace` enumerates the valid plan
candidates, `ProbeEvaluator` times short probe supersteps against a real
partition, `successive_halving`/`tune` drive the search cheap-rung-first,
and `PlanCache` persists winners keyed by `plan_cache_key` (graph +
program + mesh fingerprints) so engines built with `plan="auto-tuned"`
adopt a measured plan without re-searching.
"""
from .cache import CACHE_VERSION, PlanCache, default_cache_path
from .evaluator import Evaluator, Measurement, ProbeEvaluator, measure
from .fingerprint import (agent_graph_fingerprint, graph_fingerprint,
                          partition_fingerprint, plan_cache_key,
                          program_fingerprint)
from .search import (DEFAULT_RUNGS, TuneResult, successive_halving, tune)
from .space import DEFAULT_BOUND_CHOICES, SMOKE_SPACE, PlanSearchSpace

__all__ = [
    "CACHE_VERSION", "PlanCache", "default_cache_path",
    "Evaluator", "Measurement", "ProbeEvaluator", "measure",
    "agent_graph_fingerprint", "graph_fingerprint",
    "partition_fingerprint", "plan_cache_key", "program_fingerprint",
    "DEFAULT_RUNGS", "TuneResult", "successive_halving", "tune",
    "DEFAULT_BOUND_CHOICES", "SMOKE_SPACE", "PlanSearchSpace",
]
