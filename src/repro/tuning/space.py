"""`PlanSearchSpace`: the enumerable, validity-pruned candidate set.

The optimizer half of the optimizer/evaluator split (the deephyper-style
architecture the ROADMAP names): the space knows which `SuperstepPlan`
combinations are WELL-FORMED for a given scenario, the evaluator
(repro.tuning.evaluator) knows how fast each one actually is.  The axes
are exactly the plan's fields:

  frontier strategy x capacity multiplier x degree-bucket bounds
  x exchange phase shape (sync | pipelined | async x staleness) x kernel
  stage (XLA | Pallas +- dynamic table)

Validity pruning keeps the enumeration honest instead of large:

  * `dense` ignores caps and bucket bounds — ONE candidate per
    (phase, kernel), not |caps| x |bounds| duplicates that would waste
    probe budget re-measuring the same compiled program;
  * `flat` ignores bucket bounds (a single tile has no buckets);
  * capacities are clamped to `num_slots` (a cap can't exceed the slot
    space — the bucketed caps derived from it then respect `num_slots`
    per bucket via `frontier.bucket_caps`) and deduplicated after
    clamping;
  * `pipelined`/`async` phases require split edge tiles (the distributed
    backends' static ingress split) — pruned entirely for single-shard
    scenarios; `async` additionally requires a MONOTONE program
    (`VertexProgram.monotone` — ⊕=min/max halting traversals), so sync
    stays the only measured phase for sum-monoid programs and a tuned
    plan can never hand them bounded staleness;
  * `KernelPlan(use_pallas=False, dynamic_table=False)` is pruned: the
    dynamic-table bit only exists on the Pallas route.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.plan import KernelPlan, SuperstepPlan

# Candidate degree-bucket ladders: the default 2-octave ladder plus one
# finer and one coarser alternative (None = whatever the partition was
# built with, i.e. graph.structures.DEFAULT_BUCKET_BOUNDS).
DEFAULT_BOUND_CHOICES = (None, (4, 16, 64, 256), (16, 64, 256, 1024))


def _round8(x: float) -> int:
    return max(8, -(-int(x) // 8) * 8)


@dataclasses.dataclass(frozen=True)
class PlanSearchSpace:
    """Declarative axes; `candidates()` does the pruned enumeration."""

    strategies: Tuple[str, ...] = ("dense", "flat", "compact")
    cap_multipliers: Tuple[float, ...] = (0.5, 1.0, 2.0, 4.0)
    bucket_bounds: Tuple[Optional[tuple], ...] = DEFAULT_BOUND_CHOICES
    phases: Tuple[str, ...] = ("sync",)
    # Async ring depths measured when the "async" phase shape survives
    # pruning (split tiles present AND the program is monotone).
    staleness_choices: Tuple[int, ...] = (2, 4)
    kernels: Tuple[KernelPlan, ...] = (KernelPlan(use_pallas=False),)

    def candidates(self, num_slots: int, base_cap: int,
                   dense_frontier: bool = False,
                   has_split_tiles: bool = False,
                   monotone: bool = False
                   ) -> Tuple[SuperstepPlan, ...]:
        """Enumerate valid `SuperstepPlan`s for one scenario.

        `base_cap` anchors the capacity axis (typically
        `frontier.default_cap` over the probe histogram); `num_slots`
        clamps it.  `dense_frontier` marks iterative programs — their
        engines never compact, so only the dense strategy survives.
        `has_split_tiles` gates the pipelined/async phase shapes (both
        require the distributed ingress edge split); `monotone`
        additionally gates async (bounded staleness preserves only
        min/max fixed points — see `VertexProgram.monotone`)."""
        caps = []
        for m in self.cap_multipliers:
            c = min(num_slots, _round8(m * base_cap))
            if c not in caps:
                caps.append(c)
        kernels = [k for k in self.kernels
                   if k.use_pallas or k.dynamic_table]  # prune no-op combo
        phases = []           # (phase, staleness) pairs after pruning
        for p in self.phases:
            if p == "sync":
                phases.append((p, 0))
            elif p == "pipelined" and has_split_tiles:
                phases.append((p, 0))
            elif p == "async" and has_split_tiles and monotone:
                phases.extend((p, st) for st in self.staleness_choices)
        strategies = (("dense",) if dense_frontier else self.strategies)
        out, seen = [], set()
        for phase, staleness in phases:
            for kernel in kernels:
                for strategy in strategies:
                    if strategy == "dense":
                        combos = [(None, None)]
                        # the dynamic-table bit is a tile-combine knob;
                        # the dense scan's Pallas route ignores it
                        if kernel.use_pallas and not kernel.dynamic_table:
                            continue
                    elif strategy == "flat":
                        combos = [(c, None) for c in caps]
                    else:  # bucketed compaction ("compact" / "auto")
                        combos = [(c, b) for c in caps
                                  for b in self.bucket_bounds]
                    for cap, bounds in combos:
                        plan = SuperstepPlan(
                            strategy=strategy, frontier_cap=cap,
                            dense_frontier=dense_frontier, phases=phase,
                            staleness=staleness,
                            kernel=kernel, bucket_bounds=bounds)
                        if plan not in seen:
                            seen.add(plan)
                            out.append(plan)
        return tuple(out)


# Tiny space for CI smoke runs and tests: 1 cap anchor x 2 multipliers,
# default bounds only, XLA kernel, sync phase.
SMOKE_SPACE = PlanSearchSpace(
    strategies=("dense", "flat", "compact"),
    cap_multipliers=(1.0, 2.0),
    bucket_bounds=(None,),
)


def describe(space: PlanSearchSpace, candidates: Sequence[SuperstepPlan]
             ) -> str:
    return (f"{len(candidates)} candidates from "
            f"{len(space.strategies)} strategies x "
            f"{len(space.cap_multipliers)} caps x "
            f"{len(space.bucket_bounds)} bucket ladders x "
            f"{len(space.phases)} phases x {len(space.kernels)} kernels")
