"""Evaluators: measured probe supersteps for plan candidates.

The measurement half of the optimizer/evaluator split.  `measure` is THE
shared warmup/median timing harness — `benchmarks.common.time_fn` is the
same function (the benchmark suite re-exports it), so a tuned plan's
probe numbers and its bench-gate numbers come from one clock discipline:
warmup calls absorb compilation, the median of the timed calls defeats
one-off scheduler spikes, and the recorded dispersion (max/median over
the timed calls) feeds the per-entry noise margins of the CI perf gate
(`benchmarks/compare.py`).

`ProbeEvaluator` generalizes `GREEngine.calibrate_frontier_cap`'s
one-knob eager probe into the full plan space: each candidate plan gets
a real engine over a real partition (REBUILT per candidate bucket ladder
— `bucket_bounds` is ingress metadata, so probing it means re-binning;
partitions are memoized per ladder so a 20-candidate search builds each
ladder once), runs `probe_steps` supersteps of the actual program from
the actual source, and reports the median wall time.  `num_probes`
counts evaluate() calls — the tuner-determinism tests assert a cache hit
leaves it at zero.
"""
from __future__ import annotations

import time
from typing import Callable, NamedTuple, Optional

import jax

from repro.core.engine import DevicePartition, GREEngine
from repro.core.plan import SuperstepPlan


class Measurement(NamedTuple):
    us: float      # median wall time per call, microseconds
    noise: float   # max/median dispersion over the timed calls (>= 1.0)


def measure(fn: Callable, *args, warmup: int = 2,
            iters: int = 5) -> Measurement:
    """Median wall time per call plus dispersion (blocking on outputs).

    `noise` is the max/median ratio across the timed iterations: ~1.0 on
    a quiet machine, ~2x under the scheduler bimodality that plagues
    2-core CI hosts — exactly the margin the perf gate needs per entry.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    # lower-median: for even counts this reports the faster middle sample
    # (2-iter smoke runs would otherwise report the max as the median and
    # a constant 1.0 dispersion — hiding exactly the noise we record)
    med = times[(len(times) - 1) // 2]
    return Measurement(med * 1e6, times[-1] / max(med, 1e-12))


class Evaluator:
    """Protocol: `evaluate(plan, probe_steps, iters) -> median us`.
    Subclasses own the scenario; `num_probes` counts measured probes so
    tests can assert cache hits never measure."""

    def __init__(self):
        self.num_probes = 0

    def evaluate(self, plan: SuperstepPlan, probe_steps: int = 2,
                 iters: int = 1) -> float:
        raise NotImplementedError


class ProbeEvaluator(Evaluator):
    """Measured probe supersteps against a real single-shard partition.

    `probe_steps` bounds the jitted BSP loop (`GREEngine.run`'s
    `max_steps`), so a cheap rung times 2 supersteps and a graduation
    rung times the run to quiescence — the successive-halving driver
    (repro.tuning.search) picks the rungs.
    """

    def __init__(self, program, graph, source=0, warmup: int = 1,
                 default_bounds: Optional[tuple] = None):
        super().__init__()
        self.program = program
        self.graph = graph
        self.source = source
        self.warmup = warmup
        self.default_bounds = default_bounds
        self._parts = {}

    def partition(self, bounds: Optional[tuple] = None) -> DevicePartition:
        """The probe partition for one bucket ladder (memoized)."""
        key = tuple(bounds) if bounds else None
        if key not in self._parts:
            self._parts[key] = DevicePartition.from_graph(
                self.graph, bucket_bounds=bounds or self.default_bounds)
        return self._parts[key]

    def frontier_hist(self, probe_steps: int = 2) -> list:
        """The probe harness's frontier histogram on the DEFAULT-ladder
        partition (the fingerprint's density facet; also what
        `calibrate_frontier_cap` measures)."""
        part = self.partition()
        eng = GREEngine(self.program)
        state = eng.init_state(part, source=self.source)
        return eng.probe_frontier_hist(part, state, probe_steps)

    def evaluate(self, plan: SuperstepPlan, probe_steps: int = 2,
                 iters: int = 1) -> float:
        self.num_probes += 1
        part = self.partition(plan.bucket_bounds)
        eng = GREEngine(self.program, plan=plan)
        state = eng.init_state(part, source=self.source)
        # jit the probe exactly the way production runs execute (warmup
        # absorbs the trace): eager dispatch overhead would otherwise
        # dominate — and re-rank — millisecond-scale candidates
        run_fn = jax.jit(lambda s: eng.run(part, s, probe_steps))
        m = measure(run_fn, state, warmup=self.warmup, iters=iters)
        return m.us
