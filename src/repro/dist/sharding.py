"""Mesh / NamedSharding helpers shared by the launchers and dry-run cells.

Three groups:

  * `shard_map` — version shim: jax >= 0.5 exposes `jax.shard_map`
    (`check_vma`); 0.4.x keeps it in `jax.experimental.shard_map`
    (`check_rep`).  Every shard_map in this repo goes through here.
  * spec trees — `lm_param_specs` / `opt_specs` / ... return PartitionSpec
    pytrees that mirror the corresponding parameter pytrees (dense parts
    tensor-parallel over `tp`, embeddings row-sharded, MoE expert-sharded).
  * materialization — `to_shardings` / `abstract_with_sharding` turn spec
    trees into NamedSharding / ShapeDtypeStruct trees for jit in/out specs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig, RecSysConfig


# ------------------------------------------------------------- version shim
def shard_map(f, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """Portable shard_map: prefers `jax.shard_map` (jax >= 0.5), falls back
    to `jax.experimental.shard_map.shard_map` (0.4.x)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


# ----------------------------------------------------------------- utilities
def dp_entry(dp: Tuple[str, ...]):
    """A PartitionSpec entry for the (possibly multi-axis) data dimension."""
    if not dp:
        return None
    return dp[0] if len(dp) == 1 else tuple(dp)


def to_shardings(mesh: Mesh, specs):
    """PartitionSpec tree -> NamedSharding tree (for jit out_shardings)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_with_sharding(tree, mesh: Mesh, specs):
    """Attach NamedShardings to a ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs)


# ------------------------------------------------------------------ LM specs
def lm_param_specs(cfg: LMConfig, dp: Tuple[str, ...],
                   tp: Optional[str]) -> Dict[str, Any]:
    """PartitionSpec tree mirroring `transformer.init_lm` params.

    Megatron-style: qkv/ffn-in column-parallel over `tp`, wo/ffn-out
    row-parallel, embedding row-sharded (vocab), MoE expert-sharded.
    """
    layer = {
        "ln_attn": P(None, None),
        "wq": P(None, None, tp),
        "wk": P(None, None, tp),
        "wv": P(None, None, tp),
        "wo": P(None, tp, None),
        "ln_ffn": P(None, None),
    }
    if cfg.moe:
        layer["moe"] = {
            "router": P(None, None, None),
            "w_in": P(None, tp, None, None),
            "w_out": P(None, tp, None, None),
        }
        if cfg.gated:
            layer["moe"]["w_gate"] = P(None, tp, None, None)
    else:
        layer["ffn"] = {"w_in": P(None, None, tp), "w_out": P(None, tp, None)}
        if cfg.gated:
            layer["ffn"]["w_gate"] = P(None, None, tp)
    specs = {"embed": P(tp, None), "layers": layer, "ln_out": P(None)}
    if not cfg.tie_embeddings:
        specs["head"] = P(None, tp)
    return specs


def lm_batch_specs(dp: Tuple[str, ...]) -> Dict[str, P]:
    d = dp_entry(dp)
    return {"tokens": P(d, None), "labels": P(d, None)}


def lm_cache_specs(cfg: LMConfig, batch: int, dp: Tuple[str, ...],
                   tp: Optional[str], dp_size: int) -> Dict[str, P]:
    """KV-cache specs [L, B, S, n_kv, d_head]: batch over dp when it divides,
    kv heads over tp when they divide (else replicated)."""
    d = dp_entry(dp) if batch >= max(dp_size, 1) else None
    return {"k": P(None, d, None, None, None),
            "v": P(None, d, None, None, None),
            "len": P(d)}


# -------------------------------------------------------------- recsys specs
def recsys_param_specs(cfg: RecSysConfig, dp: Tuple[str, ...],
                       tp: Optional[str]) -> Dict[str, Any]:
    """AutoInt params: embedding table row-sharded over `tp` (the lookup
    shard_maps over it), attention projections replicated."""
    layer = {"wq": P(None, None), "wk": P(None, None),
             "wv": P(None, None), "wr": P(None, None)}
    return {"table": P(tp, None),
            "layers": [layer for _ in range(cfg.n_attn_layers)],
            "final": P(None, None), "final_b": P(None)}


# ------------------------------------------------------------ optimizer state
def opt_specs(param_specs):
    """AdamW state (step, m, v): moments shard like their parameters."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(),
                      m=jax.tree.map(lambda s: s, param_specs,
                                     is_leaf=lambda x: isinstance(x, P)),
                      v=jax.tree.map(lambda s: s, param_specs,
                                     is_leaf=lambda x: isinstance(x, P)))
