"""Distribution helpers: mesh/NamedSharding utilities and parameter specs."""
