"""Fanout neighbor sampling for minibatch GNN training (GraphSAGE-style).

The `minibatch_lg` shape (232,965 nodes / 114.6M edges, 1024 seeds, fanout
15-10) trains on sampled subgraphs; this sampler produces them with static
padded shapes so the jitted train step never recompiles:

  * per hop h, every frontier node draws ≤ fanout[h] in-neighbors uniformly
    without replacement (CSR row slices);
  * the union of sampled nodes is compacted to local ids; edges are emitted
    dst-sorted (the combine key), padded to the static budget
    seeds·(f1 + f1·f2), with node budget seeds·(1 + f1 + f1·f2);
  * deterministic from (seed, step, rank) — the same coordination-free
    restart contract as the token pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.graph.structures import CSR, Graph, coo_to_csr


@dataclasses.dataclass
class SampledSubgraph:
    """Padded, locally-renumbered subgraph (numpy, ready for device)."""
    node_ids: np.ndarray    # [n_pad] global ids (-1 padding)
    src: np.ndarray         # [e_pad] local ids
    dst: np.ndarray         # [e_pad] local ids
    edge_mask: np.ndarray   # [e_pad]
    seed_mask: np.ndarray   # [n_pad] True on the seed nodes (loss targets)
    num_nodes: int
    num_edges: int


class NeighborSampler:
    def __init__(self, graph: Graph, fanout: Sequence[int], seed: int = 0):
        self.graph = graph
        self.fanout = tuple(fanout)
        self.seed = seed
        # in-adjacency: sample the neighbors that MESSAGE INTO a node
        self.csr: CSR = coo_to_csr(graph.src, graph.dst, graph.num_vertices,
                                   by="dst")

    def budget(self, n_seeds: int) -> Tuple[int, int]:
        n, e, layer = 1, 0, 1
        for f in self.fanout:
            layer *= f
            n += layer
            e += layer
        return n_seeds * n, n_seeds * e

    def sample(self, n_seeds: int, step: int, rank: int = 0
               ) -> SampledSubgraph:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, rank]))
        n_pad, e_pad = self.budget(n_seeds)
        seeds = rng.choice(self.graph.num_vertices, size=n_seeds,
                           replace=False)
        frontier = seeds
        edges_s, edges_d = [], []
        all_nodes = [seeds]
        for f in self.fanout:
            starts = self.csr.indptr[frontier]
            degs = self.csr.indptr[frontier + 1] - starts
            # uniform without replacement via per-node random offsets
            take = np.minimum(degs, f)
            next_nodes = []
            for v, st, dg, tk in zip(frontier, starts, degs, take):
                if tk == 0:
                    continue
                picks = (rng.permutation(dg)[:tk] if dg > f
                         else np.arange(dg))
                nbrs = self.csr.indices[st + picks]
                edges_s.append(nbrs)
                edges_d.append(np.full(len(nbrs), v))
                next_nodes.append(nbrs)
            frontier = (np.unique(np.concatenate(next_nodes))
                        if next_nodes else np.empty(0, np.int64))
            all_nodes.append(frontier)

        nodes = np.unique(np.concatenate(all_nodes))
        src_g = (np.concatenate(edges_s) if edges_s
                 else np.empty(0, np.int64))
        dst_g = (np.concatenate(edges_d) if edges_d
                 else np.empty(0, np.int64))
        # compact to local ids, dst-sorted edges
        lut = {g: i for i, g in enumerate(nodes)}
        src_l = np.fromiter((lut[g] for g in src_g), np.int32,
                            count=len(src_g))
        dst_l = np.fromiter((lut[g] for g in dst_g), np.int32,
                            count=len(dst_g))
        order = np.argsort(dst_l, kind="stable")
        src_l, dst_l = src_l[order], dst_l[order]

        n, e = len(nodes), len(src_l)
        assert n <= n_pad and e <= e_pad, (n, n_pad, e, e_pad)
        out_nodes = np.full(n_pad, -1, np.int64)
        out_nodes[:n] = nodes
        out_src = np.full(e_pad, n_pad - 1, np.int32)
        out_dst = np.full(e_pad, n_pad - 1, np.int32)
        out_src[:e], out_dst[:e] = src_l, dst_l
        mask = np.zeros(e_pad, bool)
        mask[:e] = True
        seed_mask = np.zeros(n_pad, bool)
        seed_set = set(seeds.tolist())
        for i, g in enumerate(nodes):
            if int(g) in seed_set:
                seed_mask[i] = True
        return SampledSubgraph(out_nodes, out_src, out_dst, mask, seed_mask,
                               n, e)

    def batch(self, n_seeds: int, step: int, world: int
              ) -> Dict[str, np.ndarray]:
        """One stacked data-parallel batch: `world` independent subgraphs."""
        subs = [self.sample(n_seeds, step, rank) for rank in range(world)]
        return {
            "node_ids": np.stack([s.node_ids for s in subs]),
            "src": np.stack([s.src for s in subs]),
            "dst": np.stack([s.dst for s in subs]),
            "edge_mask": np.stack([s.edge_mask for s in subs]),
            "seed_mask": np.stack([s.seed_mask for s in subs]),
        }
