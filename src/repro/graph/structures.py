"""Graph topology containers.

GRE (paper §6.1.1) stores each partition's topology in CSR with local 32-bit
vertex ids; property data is column-oriented (flat arrays indexed by local
id).  We keep the same layout: a `Graph` is COO edge arrays (src, dst) plus
optional per-edge/per-vertex property columns; `CSR` is the
retrieval-optimized form.  All arrays are numpy on the host (graph ingress is
a host-side pass, as in the paper) and are converted to device arrays when a
partition is handed to the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class Graph:
    """Directed property graph in COO form (host-side)."""

    num_vertices: int
    src: np.ndarray  # [E] int32/int64 source vertex ids
    dst: np.ndarray  # [E] destination vertex ids
    edge_props: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    vertex_props: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int64)
        self.dst = np.asarray(self.dst, dtype=np.int64)
        assert self.src.shape == self.dst.shape
        for k, v in self.edge_props.items():
            assert len(v) == self.num_edges, f"edge prop {k} length mismatch"

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def out_degree(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.num_vertices).astype(np.int64)

    def in_degree(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.num_vertices).astype(np.int64)

    def reversed(self) -> "Graph":
        """Transposed graph (paper §4.2: backward traversal for BC/SCC)."""
        return Graph(self.num_vertices, self.dst.copy(), self.src.copy(),
                     {k: v.copy() for k, v in self.edge_props.items()},
                     {k: v.copy() for k, v in self.vertex_props.items()})

    def as_undirected(self) -> "Graph":
        """Each undirected edge becomes two directed edges (paper §2.1)."""
        src = np.concatenate([self.src, self.dst])
        dst = np.concatenate([self.dst, self.src])
        props = {k: np.concatenate([v, v]) for k, v in self.edge_props.items()}
        return Graph(self.num_vertices, src, dst, props, dict(self.vertex_props))

    def apply_edge_delta(self, delta: "EdgeDelta") -> "Graph":
        """The COO-level mutation (host-side reference semantics): retire
        every live instance of each removed pair, then append the added
        edges.  Partition-level deltas (`DevicePartition.apply_edge_delta`,
        `agent_graph.apply_edge_delta`) must agree with rebuilding from
        this graph — the mutation conformance suite checks exactly that.
        """
        validate_edge_delta(
            delta, self.num_vertices,
            live_keys=(self.src.astype(np.int64) *
                       np.int64(self.num_vertices) +
                       self.dst.astype(np.int64)))
        rem = removal_selector(self.src, self.dst, delta.rem_src,
                               delta.rem_dst, self.num_vertices)
        keep = ~rem
        for k in self.edge_props:
            if k not in delta.add_props and delta.num_adds:
                raise KeyError(f"delta adds missing edge prop {k!r}")
        src = np.concatenate([self.src[keep], delta.add_src])
        dst = np.concatenate([self.dst[keep], delta.add_dst])
        props = {k: np.concatenate([v[keep],
                                    np.asarray(delta.add_props[k], v.dtype)
                                    if delta.num_adds else v[:0]])
                 for k, v in self.edge_props.items()}
        return Graph(self.num_vertices, src, dst, props,
                     dict(self.vertex_props))

    def iter_edge_chunks(self, chunk_size: int):
        """Yield the edge stream as `EdgeChunk` slices of at most
        `chunk_size` rows, in stream order (the chunk-source protocol's
        reference producer — see `EdgeChunkSource`)."""
        for lo in range(0, self.num_edges, chunk_size):
            hi = min(lo + chunk_size, self.num_edges)
            yield EdgeChunk(
                src=self.src[lo:hi], dst=self.dst[lo:hi],
                props={k: v[lo:hi] for k, v in self.edge_props.items()},
                offset=lo)

    def chunk_source(self, chunk_size: int) -> "EdgeChunkSource":
        """Wrap this in-memory graph as an `EdgeChunkSource` (views, no
        copies), so the chunked ingress paths exercise the exact protocol
        an out-of-core producer would implement."""
        return EdgeChunkSource(
            num_vertices=self.num_vertices, num_edges=self.num_edges,
            prop_dtypes={k: v.dtype for k, v in self.edge_props.items()},
            chunks=lambda: self.iter_edge_chunks(chunk_size))

    def dedup(self) -> "Graph":
        """Drop duplicate (src, dst) pairs and self loops."""
        keep = self.src != self.dst
        key = self.src[keep] * np.int64(self.num_vertices) + self.dst[keep]
        _, idx = np.unique(key, return_index=True)
        sel = np.flatnonzero(keep)[idx]
        props = {k: v[sel] for k, v in self.edge_props.items()}
        return Graph(self.num_vertices, self.src[sel], self.dst[sel], props,
                     dict(self.vertex_props))


@dataclasses.dataclass
class EdgeChunk:
    """One contiguous slice of an edge stream, in stream order.

    The unit of the chunked ingress pipeline (docs/partitioning.md): the
    streaming partitioners (`repro.core.partition_stream`), the chunked
    `build_agent_graph`, and `DevicePartition.from_graph(chunk_size=...)`
    all consume a sequence of these instead of whole-stream arrays, so the
    host never needs a second full copy of the edge list in flight —
    peak ingress state is the OUTPUT tiles plus one chunk.
    """

    src: np.ndarray                 # [b] source vertex ids
    dst: np.ndarray                 # [b] destination vertex ids
    props: Dict[str, np.ndarray]    # per-edge property slices, each [b]
    offset: int                     # stream position of row 0

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])


@dataclasses.dataclass
class EdgeChunkSource:
    """The chunk-source protocol: restartable edge-stream metadata.

    `chunks()` returns a FRESH iterator over the stream (multi-pass
    ingress — `build_agent_graph` streams once to size the per-shard
    tiles and once to fill them); `num_vertices` / `num_edges` /
    `prop_dtypes` are the only whole-graph facts a consumer may rely on.
    `Graph.chunk_source` is the in-memory reference implementation; an
    out-of-core producer re-reads its file chunks instead (the tests'
    `synthetic` sources generate chunks on the fly and never materialize
    the stream at all).
    """

    num_vertices: int
    num_edges: int
    prop_dtypes: Dict[str, np.dtype]
    chunks: "object"                # callable -> iterator of EdgeChunk


def as_chunk_source(graph_or_source, chunk_size: int = 1 << 18):
    """Accept either a `Graph` or an `EdgeChunkSource`-shaped object."""
    if hasattr(graph_or_source, "chunks"):
        return graph_or_source
    return graph_or_source.chunk_source(chunk_size)


@dataclasses.dataclass
class EdgeDelta:
    """A batch of edge mutations in ORIGINAL vertex ids (docs/incremental.md).

    `removes` retire every live instance of each (src, dst) pair — a pair
    matching NO live edge is rejected up front (`validate_edge_delta`), as
    are out-of-range ids and within-batch duplicate add rows; `adds` append
    otherwise unconditionally (multi-edges across batches stay legal,
    matching `Graph`'s COO semantics).  `add_props` must supply a column
    for every edge property the target graph carries — zero-filling a
    weight would silently create zero-cost edges.
    """

    add_src: np.ndarray = None
    add_dst: np.ndarray = None
    add_props: Dict[str, np.ndarray] = None
    rem_src: np.ndarray = None
    rem_dst: np.ndarray = None

    def __post_init__(self):
        def ids(a):
            return (np.zeros(0, np.int64) if a is None
                    else np.asarray(a, dtype=np.int64).reshape(-1))
        self.add_src, self.add_dst = ids(self.add_src), ids(self.add_dst)
        self.rem_src, self.rem_dst = ids(self.rem_src), ids(self.rem_dst)
        assert self.add_src.shape == self.add_dst.shape
        assert self.rem_src.shape == self.rem_dst.shape
        self.add_props = {k: np.asarray(v)
                          for k, v in (self.add_props or {}).items()}
        for k, v in self.add_props.items():
            assert v.shape[0] == self.num_adds, f"add prop {k} length"

    @property
    def num_adds(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def num_removes(self) -> int:
        return int(self.rem_src.shape[0])


@dataclasses.dataclass
class DeltaReport:
    """What an `apply_edge_delta` actually did, in ORIGINAL vertex ids.

    The warm-start seeding rules (docs/incremental.md) consume this:
    `added_src` endpoints are re-activated so new edges deliver, and
    `removed_dst` endpoints seed the min-monoid invalidation pass.
    `removed_*` list every retired live edge instance (a pair matching two
    parallel edges appears twice); `compacted` flags that spare capacity
    ran out and the static edge/agent shapes were rebuilt (the one case
    where downstream jitted functions retrace).
    """

    added_src: np.ndarray
    added_dst: np.ndarray
    removed_src: np.ndarray
    removed_dst: np.ndarray
    compacted: bool = False

    @property
    def num_adds(self) -> int:
        return int(self.added_src.shape[0])

    @property
    def num_removed(self) -> int:
        return int(self.removed_src.shape[0])


def _offending(rows: np.ndarray, limit: int = 8) -> str:
    shown = ", ".join(str(int(r)) for r in rows[:limit])
    more = f", ... ({rows.shape[0]} total)" if rows.shape[0] > limit else ""
    return shown + more


def validate_edge_delta(delta: "EdgeDelta", num_vertices: int,
                        live_keys: Optional[np.ndarray] = None) -> None:
    """Up-front `EdgeDelta` validation shared by every delta-ingress path
    (`Graph.apply_edge_delta`, `DevicePartition.apply_edge_delta`,
    `agent_graph.apply_edge_delta`), so malformed batches fail loudly with
    the offending ROW INDICES instead of surfacing as numpy fancy-index
    errors (out-of-range ids), silent multi-edges (a duplicated add row is
    near-always a batch-construction bug; legitimate parallel edges arrive
    in separate batches), or silent no-op masks (a removal matching no live
    edge — already tombstoned, or never existed).

    `live_keys` is the caller's pre-delta live edge set as `src * V + dst`
    int64 keys in ORIGINAL vertex ids (None skips the liveness check).
    All three paths validate identically, so a delta that raises on the
    single-shard partition raises the same way on the mesh.
    """
    V = np.int64(num_vertices)
    for label, ids in (("add_src", delta.add_src),
                       ("add_dst", delta.add_dst),
                       ("rem_src", delta.rem_src),
                       ("rem_dst", delta.rem_dst)):
        bad = np.flatnonzero((ids < 0) | (ids >= V))
        if bad.size:
            raise ValueError(
                f"EdgeDelta.{label} has out-of-range vertex ids at rows "
                f"[{_offending(bad)}]: values "
                f"[{_offending(ids[bad])}] outside [0, {num_vertices})")
    if delta.num_adds:
        keys = delta.add_src * V + delta.add_dst
        _, first, counts = np.unique(keys, return_index=True,
                                     return_counts=True)
        if np.any(counts > 1):
            dup_mask = np.ones(keys.shape[0], dtype=bool)
            dup_mask[first] = False
            dup = np.flatnonzero(dup_mask)
            raise ValueError(
                f"EdgeDelta add batch repeats (src, dst) pairs at rows "
                f"[{_offending(dup)}] — duplicate rows in one batch are "
                f"almost always a construction bug; submit parallel edges "
                f"in separate deltas")
    if delta.num_removes and live_keys is not None:
        rem_keys = delta.rem_src * V + delta.rem_dst
        dead = np.flatnonzero(~np.isin(rem_keys, live_keys))
        if dead.size:
            pairs = [f"({int(delta.rem_src[r])}, {int(delta.rem_dst[r])})"
                     for r in dead[:8]]
            raise ValueError(
                f"EdgeDelta removal rows [{_offending(dead)}] match no "
                f"live edge (already tombstoned or never present): "
                f"{', '.join(pairs)}")


def removal_selector(src: np.ndarray, dst: np.ndarray, rem_src: np.ndarray,
                     rem_dst: np.ndarray, id_space: int) -> np.ndarray:
    """Boolean selector over (src, dst) rows matching any removed pair.

    `id_space` must exceed every id in play (keys are `src * id_space +
    dst`); callers pass original |V| or the local slot count.
    """
    if rem_src.shape[0] == 0 or src.shape[0] == 0:
        return np.zeros(src.shape[0], dtype=bool)
    n = np.int64(id_space)
    keys = src.astype(np.int64) * n + dst.astype(np.int64)
    rem_keys = rem_src.astype(np.int64) * n + rem_dst.astype(np.int64)
    return np.isin(keys, rem_keys)


@dataclasses.dataclass
class CSR:
    """Compressed sparse row adjacency: dst-sorted or src-sorted edge list."""

    num_vertices: int
    indptr: np.ndarray   # [V+1]
    indices: np.ndarray  # [E] neighbor ids
    edge_ids: np.ndarray  # [E] position of each CSR slot in the original COO

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]


def coo_to_csr(src: np.ndarray, dst: np.ndarray, num_vertices: int,
               by: str = "src") -> CSR:
    """Build CSR sorted by `src` (out-adjacency) or `dst` (in-adjacency)."""
    key, other = (src, dst) if by == "src" else (dst, src)
    order = np.argsort(key, kind="stable")
    counts = np.bincount(key, minlength=num_vertices)
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(num_vertices, indptr, other[order].astype(np.int64), order.astype(np.int64))


def pad_edges(src: np.ndarray, dst: np.ndarray, target: int,
              pad_vertex: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad COO edge arrays to a static length for XLA.

    Padded slots point `pad_vertex -> pad_vertex` and are masked out via the
    returned validity mask.  `pad_vertex` is typically a dedicated sink slot
    (== num_local_slots) so that combines on padding never touch real state.
    """
    e = src.shape[0]
    assert target >= e, (target, e)
    mask = np.zeros(target, dtype=bool)
    mask[:e] = True
    ps = np.full(target, pad_vertex, dtype=np.int32)
    pd = np.full(target, pad_vertex, dtype=np.int32)
    ps[:e] = src
    pd[:e] = dst
    return ps, pd, mask


def csr_layout(src: np.ndarray, edge_mask: np.ndarray, num_slots: int
               ) -> tuple[np.ndarray, np.ndarray, int]:
    """Src-sorted secondary index over padded (typically dst-sorted) edges.

    The frontier-compacted scatter (`repro.core.frontier`) gathers only the
    active vertices' out-edge ranges; that needs CSR `indptr` keyed by source
    slot.  Rather than duplicating the edge columns in src-sorted order, we
    return a POSITION index: `eidx[p]` is where the p-th src-sorted real edge
    lives in the original padded arrays, so `dst[eidx]`/`props[eidx]` read
    the canonical columns (and stay consistent when callers rewrite `dst` —
    the overlap exchange's in-superstep remote/local split — or hand in
    per-destination-class tiles with their own layouts, as the pipelined
    exchange's `agent_graph.split_edge_tiles` does).

    Returns `(indptr [num_slots+1], eidx [E_pad], max_deg)`.  Padded edges
    (mask False) are excluded — every slot's range covers real edges only,
    so `max_deg` is the true maximum out-degree over local slots.
    """
    real = np.flatnonzero(edge_mask)
    order = real[np.argsort(src[real], kind="stable")]
    counts = np.bincount(src[real], minlength=num_slots).astype(np.int64)
    indptr = np.zeros(num_slots + 1, dtype=np.int32)
    indptr[1:] = np.cumsum(counts)
    eidx = np.zeros(src.shape[0], dtype=np.int32)
    eidx[:order.shape[0]] = order
    return indptr, eidx, int(counts.max()) if counts.size else 0


# Degree-bucket upper bounds (inclusive): bucket b holds slots whose local
# out-degree d satisfies bounds[b-1] < d <= bounds[b]; one extra unbounded
# bucket catches the hubs.  Roughly ⌈log2 d⌉ collapsed to a small fixed set
# so every bucket's [cap_b, max_deg_b] tile shape stays static for XLA:
# finer ladders tighten the worst-case tile bound but pay one extra
# frontier scan + partial ⊕ per bucket — 2-octave steps won the measured
# trade on the power-law scatter benchmark (benchmarks/bench_frontier.py).
DEFAULT_BUCKET_BOUNDS = (8, 32, 128, 512)


def degree_buckets(indptr: np.ndarray, num_slots: int,
                   bounds: tuple = DEFAULT_BUCKET_BOUNDS
                   ) -> tuple[np.ndarray, tuple, tuple]:
    """Bin slots by local out-degree into `len(bounds) + 1` buckets.

    The substrate of the degree-bucketed frontier tiles
    (`repro.core.frontier.bucketed_scatter_combine`): a single padded
    `[cap, max_deg]` tile lets one hub poison `max_deg` for every frontier
    slot; binning by degree gives each bucket its own tile whose `max_deg_b`
    is bounded by the bucket's upper bound — the hub bucket degrades to a
    per-hub edge-range scan while the low-degree masses stay tightly packed.

    Returns `(bucket_id [num_slots] int32, sizes, max_degs)`.  `bucket_id`
    is -1 for slots with no out-edges (they can never contribute a message,
    so they are excluded from every bucket's capacity); `sizes[b]` and
    `max_degs[b]` are the member count and true max degree per bucket
    (0 for empty buckets).
    """
    deg = np.diff(indptr[:num_slots + 1]).astype(np.int64)
    nb = len(bounds) + 1
    bucket = np.searchsorted(np.asarray(bounds, dtype=np.int64), deg,
                             side="left").astype(np.int32)
    bucket_id = np.where(deg > 0, bucket, -1).astype(np.int32)
    sizes, max_degs = [], []
    for b in range(nb):
        members = deg[bucket_id == b]
        sizes.append(int(members.shape[0]))
        max_degs.append(int(members.max()) if members.size else 0)
    return bucket_id, tuple(sizes), tuple(max_degs)


def sort_edges_by_dst(src: np.ndarray, dst: np.ndarray,
                      edge_props: Optional[Dict[str, np.ndarray]] = None):
    """Sort COO edges by destination (the combine key).

    The Scatter-Combine hot loop segment-reduces messages by destination;
    dst-sorted order makes the reduction a contiguous segmented scan, which is
    what both the XLA path (`segment_sum` with `indices_are_sorted=True`) and
    the Pallas kernel (block-local one-hot matmul) exploit.
    """
    order = np.argsort(dst, kind="stable")
    props = {k: v[order] for k, v in (edge_props or {}).items()}
    return src[order], dst[order], props, order
