"""Synthetic graph generators.

The paper evaluates on R-MAT graphs "generated using Graph500 benchmark with
parameters a=0.57, b=c=0.19, d=0.05 ... fixed out-degree 16" (§7).  We
implement the same Kronecker/R-MAT recursive generator plus a few structured
graphs used by tests.
"""
from __future__ import annotations

import numpy as np

from repro.graph.structures import Graph

GRAPH500_A, GRAPH500_B, GRAPH500_C = 0.57, 0.19, 0.19


def rmat_edges(scale: int, edge_factor: int = 16, a: float = GRAPH500_A,
               b: float = GRAPH500_B, c: float = GRAPH500_C,
               seed: int = 0, weights: bool = False,
               permute: bool = True) -> Graph:
    """Graph500-style R-MAT generator: 2**scale vertices, edge_factor*V edges.

    Edge weights (when requested) are integers sampled from [1, 65535],
    matching the paper's SSSP setup (§7.1.1).
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        src_bit = (r >= ab).astype(np.int64)
        # within chosen half, pick column quadrant
        r2 = rng.random(m)
        thr = np.where(src_bit == 0, a / ab, c / (1.0 - ab))
        dst_bit = (r2 >= thr).astype(np.int64)
        src |= src_bit << bit
        dst |= dst_bit << bit
    if permute:
        perm = rng.permutation(n)
        src, dst = perm[src], perm[dst]
    props = {}
    if weights:
        props["weight"] = rng.integers(1, 65536, size=m).astype(np.float32)
    return Graph(n, src, dst, props)


def circulant_graph(n: int, degree: int = 16, weights: bool = False,
                    seed: int = 0) -> Graph:
    """Each vertex connects to its next `degree` neighbors mod n.

    Uniform out-degree and diameter ≈ n/degree make this the sparse-frontier
    stress case for traversal: a BFS frontier never exceeds `degree` vertices
    (< 1% of V for n ≥ 128·degree), so dense every-edge scans waste ≥ 99% of
    their gather bandwidth — the workload frontier compaction targets.
    """
    src = np.repeat(np.arange(n, dtype=np.int64), degree)
    dst = (src + np.tile(np.arange(1, degree + 1, dtype=np.int64), n)) % n
    props = {}
    if weights:
        rng = np.random.default_rng(seed)
        props["weight"] = rng.integers(1, 16, size=n * degree).astype(np.float32)
    return Graph(n, src, dst, props)


def barabasi_albert_graph(n: int, m: int = 8, seed: int = 0,
                          weights: bool = False) -> Graph:
    """Preferential-attachment power-law graph (Barabási–Albert).

    Each new vertex attaches `m` edges to existing vertices sampled with
    probability proportional to their degree (the repeated-endpoints trick:
    uniform sampling from the flat endpoint list IS degree-proportional).
    Every edge is emitted in BOTH directions, so OUT-degrees follow the
    p(d) ~ d^-3 power law with hubs of degree O(m·√n) — the skew regime
    where a single padded `[cap, max_deg]` frontier tile used to collapse
    to the static dense fallback (`cap * max_deg >= E`) while degree
    buckets stay tight (`repro.core.frontier`).
    """
    rng = np.random.default_rng(seed)
    rep = np.empty(2 * n * m, dtype=np.int64)   # flat endpoint list
    ptr = 0
    srcs, dsts = [], []
    for v in range(m, n):
        if ptr == 0:
            tgts = np.arange(min(v, m), dtype=np.int64)
        else:
            tgts = np.unique(rep[rng.integers(0, ptr, size=m)])
        k = tgts.shape[0]
        srcs.append(np.full(k, v, dtype=np.int64))
        dsts.append(tgts)
        rep[ptr:ptr + k] = tgts
        rep[ptr + k:ptr + 2 * k] = v
        ptr += 2 * k
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    e = 2 * src.shape[0]
    props = {}
    if weights:
        props["weight"] = rng.integers(1, 16, size=e).astype(np.float32)
    return Graph(n, np.concatenate([src, dst]), np.concatenate([dst, src]),
                 props)


def ring_graph(n: int, weights: bool = False) -> Graph:
    src = np.arange(n, dtype=np.int64)
    dst = (src + 1) % n
    props = {"weight": np.ones(n, dtype=np.float32)} if weights else {}
    return Graph(n, src, dst, props)


def grid_graph(rows: int, cols: int) -> Graph:
    """4-neighbor grid, directed both ways."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    s, d = [], []
    s.append(idx[:, :-1].ravel())
    d.append(idx[:, 1:].ravel())
    s.append(idx[:-1, :].ravel())
    d.append(idx[1:, :].ravel())
    src = np.concatenate(s + d)
    dst = np.concatenate(d + s)
    return Graph(rows * cols, src, dst)


def erdos_renyi_edges(n: int, m: int, seed: int = 0,
                      weights: bool = False) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    props = {}
    if weights:
        props["weight"] = rng.integers(1, 65536, size=m).astype(np.float32)
    return Graph(n, src, dst, props)


def random_geometric_molecule(n_atoms: int, n_edges: int, seed: int = 0):
    """Small 3D point cloud + kNN-ish edges, for DimeNet/MACE smoke inputs."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(n_atoms, 3)).astype(np.float32) * 1.5
    d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    k = max(1, int(np.ceil(n_edges / n_atoms)))
    nbr = np.argsort(d2, axis=1)[:, :k]
    src = np.repeat(np.arange(n_atoms), k)
    dst = nbr.ravel()
    order = np.argsort(dst, kind="stable")
    return pos, src[order][:n_edges].astype(np.int32), dst[order][:n_edges].astype(np.int32)
