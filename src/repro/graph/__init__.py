from repro.graph.structures import Graph, CSR, coo_to_csr, pad_edges
from repro.graph.generators import rmat_edges, ring_graph, grid_graph, erdos_renyi_edges
