"""GCN and GIN on the GRE scatter-combine primitive.

The layer aggregation IS the paper's active-message pattern:
`gather(src) → message → segment-combine(dst)`; full-graph distributed
training runs each layer's propagation through the Agent-Graph exchange
(`propagate_sharded`), i.e. local partial sums on combiner slots + ONE
all_to_all per layer — the same machinery as `repro.core.dist_engine`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.core.exchange import (ShardTopology, flush_combiners,
                                 refresh_scatter_agents)
from repro.core.vertex_program import MONOIDS, segment_combine
from repro.nn.layers import dense_init, mlp_apply, mlp_init


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphBatch:
    """Padded COO graph (single shard / replicated)."""
    node_feats: jnp.ndarray       # [V, F]
    src: jnp.ndarray              # [E]
    dst: jnp.ndarray              # [E]
    edge_mask: jnp.ndarray        # [E]
    labels: jnp.ndarray           # [V] int or [G] for graph tasks
    train_mask: jnp.ndarray       # [V]
    edge_norm: Optional[jnp.ndarray] = None   # [E] sym-norm coefficients
    graph_ids: Optional[jnp.ndarray] = None   # [V] for batched molecule graphs
    num_graphs: int = dataclasses.field(default=1, metadata=dict(static=True))


def propagate(h: jnp.ndarray, src, dst, edge_mask, num_nodes: int,
              edge_weight=None, use_pallas: bool = False) -> jnp.ndarray:
    """Scatter-combine a feature matrix along edges (⊕ = sum).

    Routes through the engine's unified `segment_combine` hot path —
    vector-payload messages through the same XLA fused scatter-reduce or
    Pallas MXU kernel every VertexProgram uses.
    """
    msg = jnp.take(h, src, axis=0)
    if edge_weight is not None:
        msg = msg * edge_weight[:, None]
    msg = jnp.where(edge_mask[:, None], msg, 0)
    return segment_combine(msg, dst, num_nodes, MONOIDS["sum"],
                           use_pallas=use_pallas)


def engine_propagate(batch: "GraphBatch", use_pallas: bool = False):
    """Full-batch aggregation through the GRE engine itself.

    Builds a DevicePartition over the batch's COO arrays plus a
    `gnn_aggregate_program` with payload_shape = (D,), and returns
    `prop_fn(h, edge_weight)` whose single canonical superstep performs the
    layer propagation — byte-identical to `propagate` but running on the
    unified engine stack (and its Pallas combine when `use_pallas`).
    """
    from repro.core.algorithms import gnn_aggregate_program
    from repro.core.engine import DevicePartition, EngineState, GREEngine
    V = int(batch.node_feats.shape[0])
    sink = V  # padded edges already point in [0, V); add one sink slot
    part = DevicePartition(
        src=batch.src, dst=jnp.where(batch.edge_mask, batch.dst, sink),
        edge_mask=batch.edge_mask, num_masters=V, num_slots=V + 1,
        edges_sorted_by_dst=False,
        edge_props={}, aux={})

    def prop_fn(h, edge_weight):
        d = h.shape[-1]
        eng = GREEngine(gnn_aggregate_program(
            d, edge_weighted=edge_weight is not None), use_pallas=use_pallas)
        props = ({"edge_norm": jnp.where(batch.edge_mask, edge_weight, 0.0)}
                 if edge_weight is not None else {})
        p = dataclasses.replace(part, edge_props=props)
        sd = jnp.zeros((V + 1, d), h.dtype).at[:V].set(h)
        state = EngineState(
            vertex_data=jnp.zeros((V, d), h.dtype), scatter_data=sd,
            active_scatter=jnp.ones(V + 1, dtype=bool).at[sink].set(False),
            step=jnp.zeros((), jnp.int32))
        return eng.superstep(p, state).vertex_data

    return prop_fn


def propagate_sharded(h_slots: jnp.ndarray, topo: ShardTopology, axes,
                      edge_weight=None) -> jnp.ndarray:
    """Distributed propagation over one Agent-Graph shard (inside shard_map).

    h_slots: [num_slots, F] — master features in [0, cap); agent slots are
    refreshed here.  Returns combined [num_slots, F] (masters valid).
    """
    part = topo.part
    active = jnp.ones((h_slots.shape[0],), dtype=bool)
    h_slots, _ = refresh_scatter_agents(topo, h_slots, active, axes)
    combined = propagate(h_slots, part.src, part.dst, part.edge_mask,
                         part.num_slots, edge_weight)
    flushed = flush_combiners(topo, combined, axes, MONOIDS["sum"])
    local = jnp.where(
        (jnp.arange(part.num_slots) < part.num_masters)[:, None], combined, 0)
    return local + flushed


# ----------------------------------------------------------------- GCN / GIN
def init_gnn(key, cfg: GNNConfig, d_in: int, n_out: int):
    ks = jax.random.split(key, cfg.n_layers + 2)
    dims = [d_in] + [cfg.d_hidden] * cfg.n_layers
    layers = []
    for i in range(cfg.n_layers):
        if cfg.family == "gcn":
            layers.append({"w": dense_init(ks[i], dims[i], dims[i + 1]),
                           "b": jnp.zeros((dims[i + 1],))})
        else:  # gin: MLP per layer + learnable eps
            layers.append({
                "mlp": mlp_init(ks[i], [dims[i], dims[i + 1], dims[i + 1]]),
                "eps": jnp.zeros(()) if cfg.eps_learnable else None,
            })
    return {"layers": layers, "out": dense_init(ks[-1], cfg.d_hidden, n_out),
            "out_b": jnp.zeros((n_out,))}


def gnn_forward(params, batch: GraphBatch, cfg: GNNConfig,
                prop_fn=None) -> jnp.ndarray:
    """Returns per-node logits [V, n_out] (or per-graph after pooling).

    `prop_fn(h, edge_weight) -> aggregated` abstracts local vs agent-sharded
    propagation; defaults to the local/GSPMD path.
    """
    V = batch.node_feats.shape[0]
    if prop_fn is None:
        def prop_fn(h, ew):
            return propagate(h, batch.src, batch.dst, batch.edge_mask, V, ew)

    h = batch.node_feats
    for lp in params["layers"]:
        if cfg.family == "gcn":
            agg = prop_fn(h, batch.edge_norm)
            h = jax.nn.relu(agg @ lp["w"] + lp["b"])
        else:  # GIN: h = MLP((1 + eps) h + sum_neighbors)
            agg = prop_fn(h, None)
            eps = lp["eps"] if lp["eps"] is not None else 0.0
            h = mlp_apply(lp["mlp"], (1.0 + eps) * h + agg, act=jax.nn.relu,
                          final_act=True)
    if batch.graph_ids is not None:  # graph classification: mean-pool
        pooled = jax.ops.segment_sum(h, batch.graph_ids, batch.num_graphs)
        cnt = jax.ops.segment_sum(jnp.ones((V, 1)), batch.graph_ids,
                                  batch.num_graphs)
        h = pooled / jnp.maximum(cnt, 1.0)
    return h @ params["out"] + params["out_b"]


def gnn_loss(params, batch: GraphBatch, cfg: GNNConfig, prop_fn=None):
    logits = gnn_forward(params, batch, cfg, prop_fn)
    if batch.graph_ids is not None:
        labels, mask = batch.labels, jnp.ones_like(batch.labels, jnp.float32)
    else:
        labels, mask = batch.labels, batch.train_mask.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ------------------------------------------------- additional GNN families
def gat_layer_init(key, d_in: int, d_out: int, n_heads: int = 1):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w": dense_init(k1, d_in, d_out * n_heads),
            "a_src": dense_init(k2, d_out, n_heads, scale=0.1)[:, :],
            "a_dst": dense_init(k3, d_out, n_heads, scale=0.1)[:, :]}


def gat_layer(params, h, src, dst, edge_mask, num_nodes, n_heads: int = 1,
              leaky_slope: float = 0.2):
    """Graph attention (GAT, arXiv:1710.10903) on scatter-combine:
    SDDMM edge scores → segment-SOFTMAX (max-combine + sum-combine — the
    engine's other two monoids) → weighted sum-combine."""
    V = num_nodes
    d_out = params["a_src"].shape[0]
    z = (h @ params["w"]).reshape(V, n_heads, d_out)           # [V, H, F]
    e_src = jnp.einsum("vhf,fh->vh", z, params["a_src"])
    e_dst = jnp.einsum("vhf,fh->vh", z, params["a_dst"])
    logits = jnp.take(e_src, src, axis=0) + jnp.take(e_dst, dst, axis=0)
    logits = jax.nn.leaky_relu(logits, leaky_slope)
    logits = jnp.where(edge_mask[:, None], logits, -1e30)
    # numerically-stable segment softmax: ⊕=max then ⊕=sum
    mx = jax.ops.segment_max(logits, dst, V)
    p = jnp.exp(logits - jnp.take(jnp.where(jnp.isfinite(mx), mx, 0.0),
                                  dst, axis=0))
    p = jnp.where(edge_mask[:, None], p, 0.0)
    denom = jax.ops.segment_sum(p, dst, V)
    alpha = p / jnp.maximum(jnp.take(denom, dst, axis=0), 1e-9)
    msgs = jnp.take(z, src, axis=0) * alpha[:, :, None]
    out = jax.ops.segment_sum(msgs, dst, V)                    # [V, H, F]
    return jax.nn.elu(out.reshape(V, n_heads * d_out))


def sage_layer_init(key, d_in: int, d_out: int):
    k1, k2 = jax.random.split(key)
    return {"w_self": dense_init(k1, d_in, d_out),
            "w_nbr": dense_init(k2, d_in, d_out)}


def sage_layer(params, h, src, dst, edge_mask, num_nodes,
               aggregator: str = "mean"):
    """GraphSAGE (arXiv:1706.02216): mean or max neighbor aggregation."""
    V = num_nodes
    msgs = jnp.where(edge_mask[:, None], jnp.take(h, src, axis=0), 0.0)
    if aggregator == "mean":
        s = jax.ops.segment_sum(msgs, dst, V)
        cnt = jax.ops.segment_sum(edge_mask.astype(h.dtype), dst, V)
        agg = s / jnp.maximum(cnt, 1.0)[:, None]
    else:  # max
        neg = jnp.where(edge_mask[:, None], jnp.take(h, src, axis=0), -1e30)
        agg = jax.ops.segment_max(neg, dst, V)
        agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
    return jax.nn.relu(h @ params["w_self"] + agg @ params["w_nbr"])


def compute_gcn_edge_norm(src, dst, edge_mask, num_nodes):
    """Symmetric normalization 1/sqrt(deg_out(u) deg_in(v)) (host or jnp)."""
    ones = edge_mask.astype(jnp.float32)
    dout = jax.ops.segment_sum(ones, src, num_nodes)
    din = jax.ops.segment_sum(ones, dst, num_nodes)
    return (1.0 / jnp.sqrt(jnp.maximum(dout[src], 1.0)) *
            1.0 / jnp.sqrt(jnp.maximum(din[dst], 1.0)))
