# Model families are imported lazily by the config registry; importing the
# package does not pull heavy modules.
