"""MACE-style higher-order equivariant message passing (arXiv:2206.07697).

Structure per layer (2 layers, l_max=2, correlation order 3):

  1. edge attrs: real spherical harmonics Y_l(r̂) and Bessel radial basis;
  2. A-features: for every coupling path (l_in ⊗ l_edge → l_out), messages
     m = CG(h[src], Y) · R(d) are scatter-combined (⊕ = sum) to nodes — the
     GRE active-message primitive with irrep-vector payloads;
  3. higher-order B-features: iterated CG products A⊗A → B, B⊗A → C
     (correlation order 3), linearly mixed per path;
  4. update: linear mix per l, residual; readout from l=0 channels.

CG tensors come from `repro.nn.equivariant` (numerically projected,
convention-free); rotation invariance is asserted by tests.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.nn.equivariant import bessel_basis, cg_tensor, cosine_cutoff, real_sh, valid_paths
from repro.nn.layers import dense_init, mlp_apply, mlp_init

CUTOFF = 5.0


def _irrep_dims(l_max: int) -> List[int]:
    return [2 * l + 1 for l in range(l_max + 1)]


def init_mace(key, cfg: GNNConfig, n_species: int = 16, d_out: int = 1):
    lm = cfg.l_max
    ch = cfg.d_hidden
    paths = valid_paths(lm)
    ks = iter(jax.random.split(key, 64))
    params: Dict = {
        "embed": (jax.random.normal(next(ks), (n_species, ch)) * 0.5),
        "layers": [],
        "readout": mlp_init(next(ks), [ch, ch, d_out]),
    }
    for _ in range(cfg.n_layers):
        lp = {
            # radial MLP: bessel -> weights per path per channel
            "radial": mlp_init(next(ks), [cfg.n_rbf, 32, len(paths) * ch]),
            # linear mixes per output l, applied after aggregation
            "mix_A": {l: dense_init(next(ks), ch, ch) for l in range(lm + 1)},
            "mix_B": {l: dense_init(next(ks), ch, ch) for l in range(lm + 1)},
            "mix_C": {l: dense_init(next(ks), ch, ch) for l in range(lm + 1)},
            "self": {l: dense_init(next(ks), ch, ch) for l in range(lm + 1)},
        }
        params["layers"].append(lp)
    return params


def _cg_apply(u: jnp.ndarray, v: jnp.ndarray, l1: int, l2: int, l3: int
              ) -> jnp.ndarray:
    """u: [N, ch, 2l1+1], v: [N, (ch,) 2l2+1] → [N, ch, 2l3+1]."""
    C = jnp.asarray(cg_tensor(l1, l2, l3), u.dtype)
    if v.ndim == u.ndim:          # channel-wise product
        return jnp.einsum("kij,nci,ncj->nck", C, u, v)
    return jnp.einsum("kij,nci,nj->nck", C, u, v)


def mace_forward(params, pos: jnp.ndarray, species: jnp.ndarray,
                 src: jnp.ndarray, dst: jnp.ndarray, edge_mask: jnp.ndarray,
                 cfg: GNNConfig, prop_fn=None) -> jnp.ndarray:
    """pos [V,3], species [V] int, COO edges.  Returns per-node scalar
    outputs [V, d_out] (sum for a graph energy).

    `prop_fn(msgs [E, ch, m], dst) -> [V, ch, m]` abstracts local vs
    agent-sharded aggregation.
    """
    V = pos.shape[0]
    lm = cfg.l_max
    ch = cfg.d_hidden
    paths = valid_paths(lm)

    if prop_fn is None:
        def prop_fn(msgs, dst_):
            return jax.ops.segment_sum(msgs, dst_, V)

    vec = pos[dst] - pos[src]                      # [E, 3]
    d = jnp.linalg.norm(vec, axis=-1)
    rhat = vec / jnp.maximum(d, 1e-6)[:, None]
    Y = real_sh(rhat, lm)                          # l -> [E, 2l+1]
    rbf = bessel_basis(d, cfg.n_rbf, CUTOFF) * cosine_cutoff(d, CUTOFF)[:, None]
    emask = edge_mask.astype(pos.dtype)

    # node features: l -> [V, ch, 2l+1]; start with scalar species embedding
    h = {l: jnp.zeros((V, ch, 2 * l + 1), pos.dtype) for l in range(lm + 1)}
    h[0] = jnp.take(params["embed"], species, axis=0)[:, :, None]

    @jax.checkpoint
    def one_layer(h, lp):
            Rw = mlp_apply(lp["radial"], rbf).reshape(-1, len(paths), ch)  # [E,P,ch]
            # --- A features: first-order scatter-combine over edges ---
            A = {l: jnp.zeros((V, ch, 2 * l + 1), pos.dtype) for l in range(lm + 1)}

            def path_msg(pi, l1, l2, l3):
                # checkpointed per path: backward recomputes the edge
                # messages, keeping only one path's [E, ch, m] live at a time
                def f(h_l1, rw):
                    m = _cg_apply(jnp.take(h_l1, src, axis=0), Y[l2],
                                  l1, l2, l3)
                    m = m * (rw * emask[:, None])[:, :, None]
                    return prop_fn(m, dst)
                return jax.checkpoint(f)(h[l1], Rw[:, pi])

            for pi, (l1, l2, l3) in enumerate(paths):
                A[l3] = A[l3] + path_msg(pi, l1, l2, l3)
            A = {l: jnp.einsum("ncm,cd->ndm", A[l], lp["mix_A"][l]) for l in A}
            # --- higher-order products (correlation order 3) ---
            B = {l: jnp.zeros_like(A[l]) for l in A}
            for (l1, l2, l3) in paths:
                B[l3] = B[l3] + _cg_apply(A[l1], A[l2], l1, l2, l3)
            B = {l: jnp.einsum("ncm,cd->ndm", B[l], lp["mix_B"][l]) for l in B}
            Cf = {l: jnp.zeros_like(A[l]) for l in A}
            for (l1, l2, l3) in paths:
                Cf[l3] = Cf[l3] + _cg_apply(B[l1], A[l2], l1, l2, l3)
            Cf = {l: jnp.einsum("ncm,cd->ndm", Cf[l], lp["mix_C"][l]) for l in Cf}
            # --- update: self-mix + message orders, residual ---
            return {l: h[l] + jnp.einsum("ncm,cd->ndm", h[l], lp["self"][l])
                   + A[l] + B[l] + Cf[l]
                for l in h}

    for lp in params["layers"]:
        h = one_layer(h, lp)

    scalars = h[0][:, :, 0]                        # invariant channels
    return mlp_apply(params["readout"], scalars, act=jax.nn.silu)


def mace_energy(params, pos, species, src, dst, edge_mask, cfg: GNNConfig):
    node_e = mace_forward(params, pos, species, src, dst, edge_mask, cfg)
    return node_e.sum()
