"""AutoInt (arXiv:1810.11921): self-attention feature interaction for CTR.

Hot path: the sparse embedding lookup over 39 fields with a multi-million-row
concatenated table — an EmbeddingBag (gather + segment-sum), i.e. the GRE
scatter-combine primitive.  Distributed serving row-shards the table and uses
the combiner-agent pattern (local masked partial lookups + ONE psum), see
`repro.nn.embedding.sharded_embedding_lookup`.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecSysConfig
from repro.nn.embedding import embedding_init, sharded_embedding_lookup
from repro.nn.layers import dense_init, mlp_apply, mlp_init


def field_offsets(cfg: RecSysConfig) -> np.ndarray:
    """Start row of each field in the concatenated embedding table."""
    return np.concatenate([[0], np.cumsum(cfg.vocab_sizes)[:-1]]).astype(np.int64)


def init_autoint(key, cfg: RecSysConfig):
    ks = iter(jax.random.split(key, 8 + 4 * cfg.n_attn_layers))
    d, da, nh = cfg.embed_dim, cfg.d_attn, cfg.n_heads
    params = {
        "table": embedding_init(next(ks), cfg.total_rows(), d),
        "layers": [],
        "final": dense_init(next(ks), cfg.n_sparse * da, 1),
        "final_b": jnp.zeros((1,)),
    }
    d_in = d
    for _ in range(cfg.n_attn_layers):
        params["layers"].append({
            "wq": dense_init(next(ks), d_in, da),
            "wk": dense_init(next(ks), d_in, da),
            "wv": dense_init(next(ks), d_in, da),
            "wr": dense_init(next(ks), d_in, da),   # residual projection
        })
        d_in = da
    return params


def interact(params, emb: jnp.ndarray, cfg: RecSysConfig) -> jnp.ndarray:
    """emb [B, F, d] -> AutoInt representation [B, F*d_attn]."""
    B, F, _ = emb.shape
    nh = cfg.n_heads
    h = emb
    for lp in params["layers"]:
        dh = cfg.d_attn // nh
        q = (h @ lp["wq"]).reshape(B, F, nh, dh)
        k = (h @ lp["wk"]).reshape(B, F, nh, dh)
        v = (h @ lp["wv"]).reshape(B, F, nh, dh)
        s = jnp.einsum("bfnh,bgnh->bnfg", q, k) / np.sqrt(dh)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bnfg,bgnh->bfnh", a, v).reshape(B, F, nh * dh)
        h = jax.nn.relu(o + h @ lp["wr"])
    return h.reshape(B, F * cfg.d_attn)


def autoint_logits(params, ids: jnp.ndarray, cfg: RecSysConfig,
                   lookup_fn=None) -> jnp.ndarray:
    """ids [B, F]: GLOBAL row ids (field offsets already added)."""
    if lookup_fn is None:
        emb = jnp.take(params["table"], ids, axis=0)          # [B, F, d]
    else:
        emb = lookup_fn(params["table"], ids)
    rep = interact(params, emb, cfg)
    return (rep @ params["final"] + params["final_b"])[:, 0]


def autoint_loss(params, batch: Dict[str, jnp.ndarray], cfg: RecSysConfig,
                 lookup_fn=None) -> jnp.ndarray:
    logits = autoint_logits(params, batch["ids"], cfg, lookup_fn)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_scores(params, ids: jnp.ndarray, cand_table: jnp.ndarray,
                     proj: jnp.ndarray, cfg: RecSysConfig) -> jnp.ndarray:
    """Retrieval scoring: one query's AutoInt representation against N
    candidates via a single batched dot product (no loop).

    ids [1, F]; cand_table [N, d_attn]; proj [F*d_attn, d_attn]."""
    rep = interact(params, jnp.take(params["table"], ids, axis=0), cfg)
    qvec = rep @ proj                                          # [1, d_attn]
    return (cand_table @ qvec[0]).reshape(-1)                  # [N]


def synth_batch(key, cfg: RecSysConfig, batch: int) -> Dict[str, jnp.ndarray]:
    """Synthetic criteo-like batch with power-law id distribution."""
    kid, klab = jax.random.split(key)
    offs = jnp.asarray(field_offsets(cfg))
    sizes = jnp.asarray(cfg.vocab_sizes)
    u = jax.random.uniform(kid, (batch, cfg.n_sparse))
    ids = (u ** 3.0 * (sizes - 1)).astype(jnp.int32) + offs[None, :]
    labels = jax.random.bernoulli(klab, 0.25, (batch,)).astype(jnp.int32)
    return {"ids": ids, "labels": labels}
