"""Decoder-only LM (dense GQA or MoE), scan-over-layers, three step kinds.

Covers the 5 assigned LM architectures (command-r-plus-104b, smollm-135m,
nemotron-4-15b, qwen3-moe-30b-a3b, granite-moe-1b-a400m) from `LMConfig`.

Distribution context (`DistCtx`) carries mesh + logical axes; dense parts are
GSPMD-sharded via in/out shardings at jit time (see repro/dist/sharding.py);
the MoE block runs its scatter-combine dispatch under an explicit shard_map
(expert axis = 'model', token axis = dp) as described in repro/nn/moe.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import LMConfig
from repro.nn.attention import apply_rope, decode_attention, gqa_attention
from repro.nn.ffn import ffn_apply, ffn_init
from repro.nn.layers import dense_init, rmsnorm, rmsnorm_init
from repro.nn.moe import moe_ffn, moe_init


@dataclasses.dataclass(frozen=True)
class DistCtx:
    """Mesh + logical axis names for distributed execution (None = local)."""
    mesh: Optional[Mesh] = None
    dp: Tuple[str, ...] = ()      # data axes, e.g. ("pod", "data")
    tp: Optional[str] = None      # tensor/expert axis, e.g. "model"

    @property
    def n_ep(self) -> int:
        if self.mesh is None or self.tp is None:
            return 1
        return self.mesh.shape[self.tp]


LOCAL_CTX = DistCtx()


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def embed_lookup(embed, tokens, spec, mesh, vocab=None, dtype_str=None):
    """Embedding gather whose backward lands PRE-SHARDED.

    The naive `take` backward scatters a full [V, D] partial on every device
    before the cross-device reduce (12.5 GiB f32 for a 256k×12288 vocab);
    constraining the cotangent inside a custom VJP lets SPMD produce the
    reduce-scattered layout directly.
    """
    return jnp.take(embed, tokens, axis=0)


def _embed_fwd(embed, tokens, spec, mesh, vocab, dtype_str):
    return jnp.take(embed, tokens, axis=0), tokens


def _embed_bwd(spec, mesh, vocab, dtype_str, res, dx):
    tokens = res
    edtype = jnp.dtype(dtype_str)
    flat = dx.reshape(-1, dx.shape[-1])
    demb = jax.ops.segment_sum(flat, tokens.reshape(-1), vocab)
    if mesh is not None:
        demb = jax.lax.with_sharding_constraint(
            demb, jax.sharding.NamedSharding(mesh, spec))
    dtok = np.zeros(tokens.shape, dtype=jax.dtypes.float0)
    return demb.astype(edtype), dtok


embed_lookup.defvjp(_embed_fwd, _embed_bwd)


# --------------------------------------------------------------------- init
def init_layer(key, cfg: LMConfig):
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    p = {
        "ln_attn": rmsnorm_init(d, dt),
        "wq": dense_init(ks[0], d, nh * hd, dt),
        "wk": dense_init(ks[1], d, nkv * hd, dt),
        "wv": dense_init(ks[2], d, nkv * hd, dt),
        "wo": dense_init(ks[3], nh * hd, d, dt),
        "ln_ffn": rmsnorm_init(d, dt),
    }
    if cfg.moe:
        p["moe"] = moe_init(ks[4], d, cfg.moe.d_ff_expert, cfg.moe.n_experts,
                            cfg.gated, dt)
    else:
        p["ffn"] = ffn_init(ks[4], d, cfg.d_ff, cfg.gated, dt)
    return p


def init_lm(key, cfg: LMConfig):
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[init_layer(ks[i], cfg) for i in range(cfg.n_layers)])
    dt = cfg.param_dtype
    params = {
        "embed": (jax.random.normal(ks[-3], (cfg.padded_vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "layers": layers,
        "ln_out": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[-2], cfg.d_model, cfg.padded_vocab, dt)
    return params


def abstract_params(cfg: LMConfig):
    """Parameter ShapeDtypeStructs without allocating (for the dry-run)."""
    return jax.eval_shape(lambda k: init_lm(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ------------------------------------------------------------------ forward
def _attention_block(p, x, cfg: LMConfig, positions):
    B, S, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv, cfg.d_head
    h = rmsnorm(x, p["ln_attn"])
    q = (h @ p["wq"]).reshape(B, S, nkv, nh // nkv, hd)
    k = (h @ p["wk"]).reshape(B, S, nkv, hd)
    v = (h @ p["wv"]).reshape(B, S, nkv, hd)
    q = apply_rope(q.reshape(B, S, nkv * (nh // nkv), hd).transpose(0, 2, 1, 3),
                   positions[:, None, :], cfg.rope_theta).transpose(0, 2, 1, 3)
    q = q.reshape(B, S, nkv, nh // nkv, hd)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions[:, None, :],
                   cfg.rope_theta).transpose(0, 2, 1, 3)
    o = gqa_attention(q, k, v, causal=True, impl=cfg.attention_impl,
                      q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    return x + o.reshape(B, S, nh * hd) @ p["wo"], (k, v)


def _ffn_block(p, x, cfg: LMConfig, ctx: DistCtx):
    B, S, d = x.shape
    h = rmsnorm(x, p["ln_ffn"])
    if cfg.moe is None:
        return x + ffn_apply(p["ffn"], h, cfg.activation), 0.0
    m = cfg.moe
    if ctx.mesh is None or ctx.n_ep == 1:
        out, aux = moe_ffn(p["moe"], h.reshape(B * S, d), m.top_k,
                           m.n_experts, m.capacity_factor, cfg.activation)
        return x + out.reshape(B, S, d), aux

    wdp = (ctx.dp if len(ctx.dp) != 1 else ctx.dp[0]) if ctx.dp else None
    dp_entry = wdp
    if ctx.mesh is not None and ctx.dp:
        dp_size = int(np.prod([ctx.mesh.shape[a] for a in ctx.dp]))
        if (B * S) % dp_size != 0:
            dp_entry = None  # tiny decode batches: replicate tokens over dp
    tok_spec = P(dp_entry, None)

    def moe_shard(h_loc, pl):
        idx = jax.lax.axis_index(ctx.tp)
        # FSDP all-gather of this shard's expert weights over dp axes
        if len(ctx.dp) > 0:
            gather = lambda w, ax: jax.lax.all_gather(w, ctx.dp, axis=ax,
                                                      tiled=True)
            pl = dict(pl, w_in=gather(pl["w_in"], 1),
                      w_out=gather(pl["w_out"], 2),
                      **({"w_gate": gather(pl["w_gate"], 1)}
                         if "w_gate" in pl else {}))
        out, aux = moe_ffn(pl, h_loc, m.top_k, m.n_experts,
                           m.capacity_factor, cfg.activation,
                           shard_index=idx, n_shards=ctx.n_ep,
                           axis_name=ctx.tp)
        return out, jax.lax.pmean(aux, (ctx.tp,) + tuple(ctx.dp))

    mp = p["moe"]
    pspec = {"router": P(), "w_in": P(ctx.tp, wdp, None),
             "w_out": P(ctx.tp, None, wdp)}
    if "w_gate" in mp:
        pspec["w_gate"] = P(ctx.tp, wdp, None)
    from repro.dist.sharding import shard_map
    out, aux = shard_map(
        moe_shard, mesh=ctx.mesh, in_specs=(tok_spec, pspec),
        out_specs=(tok_spec, P()))(h.reshape(B * S, d), mp)
    return x + out.reshape(B, S, d), aux


def _activation_constraint(x, cfg: LMConfig, ctx: DistCtx):
    """Between-layer activation sharding: batch over dp, sequence over tp
    (Megatron-SP style — attention/ffn gather what they need internally).
    Cuts stored remat boundaries by the tp degree."""
    if ctx.mesh is None or not cfg.seq_shard_activations:
        return x
    dp_entry = (ctx.dp if len(ctx.dp) != 1 else ctx.dp[0]) if ctx.dp else None
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, P(dp_entry, ctx.tp, None)))


def _scan_layers(layer, carry, stacked, cfg: LMConfig, collect_ys=False):
    """Two-level remat scan: outer scan over L/remat_block blocks stores the
    only boundaries; the inner scan over remat_block layers is recomputed in
    the backward pass (activation-checkpoint policy)."""
    L = cfg.n_layers
    B = cfg.remat_block if cfg.remat else 1
    if cfg.remat and L % B == 0 and B > 1 and not collect_ys:
        blocked = jax.tree.map(
            lambda a: a.reshape((L // B, B) + a.shape[1:]), stacked)

        @jax.checkpoint
        def block(carry, bp):
            out, _ = jax.lax.scan(layer, carry, bp)
            return out, None

        return jax.lax.scan(block, carry, blocked)
    layer_fn = jax.checkpoint(layer) if cfg.remat else layer
    return jax.lax.scan(layer_fn, carry, stacked)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def grad_cast(x, dtype_str: str):
    """Identity whose BACKWARD casts the cotangent to `dtype_str`.

    The CE loss keeps f32 logits for stable log-softmax; without a barrier
    that f32 cotangent propagates through every layer's backward (2× HBM
    bytes and 2× collective traffic on all seq-shard gathers — observed on
    granite train_4k §Perf iteration 3).  Placing grad_cast before the head
    keeps the layer-stack backward in bf16.
    """
    return x


def _grad_cast_fwd(x, dtype_str):
    return x, None


def _grad_cast_bwd(dtype_str, _res, dx):
    return (dx.astype(jnp.dtype(dtype_str)),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def _mask_pad_logits(logits, cfg: LMConfig):
    """-inf on Megatron-style vocab-padding columns (no-op when unpadded)."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(mask, logits, jnp.finfo(jnp.float32).min)


def _embed_spec(ctx: DistCtx):
    dp_entry = (ctx.dp if len(ctx.dp) != 1 else ctx.dp[0]) if ctx.dp else None
    return P(ctx.tp, dp_entry)


def lm_forward(params, tokens: jnp.ndarray, cfg: LMConfig,
               ctx: DistCtx = LOCAL_CTX):
    """tokens [B, S] -> logits [B, S, V]; also returns aux (moe loss)."""
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens, _embed_spec(ctx), ctx.mesh,
                     cfg.padded_vocab, str(params["embed"].dtype))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def layer(carry, lp):
        x, aux = carry
        x = _activation_constraint(x, cfg, ctx)
        x, _ = _attention_block(lp, x, cfg, positions)
        x, a = _ffn_block(lp, x, cfg, ctx)
        x = _activation_constraint(x, cfg, ctx)
        return (x, aux + a), None

    (x, aux), _ = _scan_layers(layer, (x, jnp.zeros((), jnp.float32)),
                               params["layers"], cfg)
    x = grad_cast(x, cfg.dtype)  # layer-stack backward stays in param dtype
    x = rmsnorm(x, params["ln_out"])
    if ctx.mesh is not None:
        # unshard the sequence before the vocab projection so logits land
        # [B/dp, S, V/tp] (otherwise SPMD all-gathers the full f32 head)
        dp_entry = (ctx.dp if len(ctx.dp) != 1 else ctx.dp[0]) if ctx.dp else None
        x = jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(ctx.mesh, P(dp_entry, None, None)))
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = _mask_pad_logits(x @ head, cfg)
    return logits, aux / cfg.n_layers


def lm_loss(params, batch: Dict[str, jnp.ndarray], cfg: LMConfig,
            ctx: DistCtx = LOCAL_CTX, aux_weight: float = 0.01):
    logits, aux = lm_forward(params, batch["tokens"], cfg, ctx)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(ll))
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux, {"ce": loss, "moe_aux": aux}


# ------------------------------------------------------------------ serving
def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.param_dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.d_head)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "len": jnp.zeros((batch,), jnp.int32)}


def prefill(params, tokens: jnp.ndarray, cfg: LMConfig,
            ctx: DistCtx = LOCAL_CTX, max_len: Optional[int] = None):
    """Run the full prompt; returns (last-token logits, populated cache)."""
    B, S = tokens.shape
    max_len = max_len or S
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def layer(carry, lp):
        x, aux = carry
        x, (k, v) = _attention_block(lp, x, cfg, positions)
        x, a = _ffn_block(lp, x, cfg, ctx)
        return (x, aux + a), (k, v)

    layer_fn = jax.checkpoint(layer) if cfg.remat else layer
    (x, _), (ks, vs) = jax.lax.scan(layer_fn, (x, jnp.zeros((), jnp.float32)),
                                    params["layers"])
    x = rmsnorm(x[:, -1:], params["ln_out"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = _mask_pad_logits((x @ head)[:, 0], cfg)
    pad = max_len - S
    cache = {
        "k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "len": jnp.full((B,), S, jnp.int32),
    }
    return logits, cache


def decode_step(params, cache, token: jnp.ndarray, cfg: LMConfig,
                ctx: DistCtx = LOCAL_CTX):
    """One decode step.  token [B] int32; cache from init_cache/prefill.
    Returns (logits [B, V], updated cache)."""
    B = token.shape[0]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv, cfg.d_head
    x = jnp.take(params["embed"], token[:, None], axis=0)     # [B, 1, D]
    pos = cache["len"]                                        # [B]

    def layer(carry, xs):
        x, aux = carry
        lp, k_c, v_c = xs
        h = rmsnorm(x, lp["ln_attn"])
        q = (h @ lp["wq"]).reshape(B, 1, nkv, nh // nkv, hd)
        k = (h @ lp["wk"]).reshape(B, 1, nkv, hd)
        v = (h @ lp["wv"]).reshape(B, 1, nkv, hd)
        q = apply_rope(q.reshape(B, 1, nh, hd).transpose(0, 2, 1, 3),
                       pos[:, None, None], cfg.rope_theta
                       ).transpose(0, 2, 1, 3).reshape(B, 1, nkv, nh // nkv, hd)
        k = apply_rope(k.transpose(0, 2, 1, 3), pos[:, None, None],
                       cfg.rope_theta).transpose(0, 2, 1, 3)
        # insert new kv at position `pos` (per batch row)
        upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
            c, n, (i, 0, 0)))(k_c, k[:, 0:1], pos)
        vpd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
            c, n, (i, 0, 0)))(v_c, v[:, 0:1], pos)
        o = decode_attention(q, upd, vpd, pos)
        x = x + o.reshape(B, 1, nh * hd) @ lp["wo"]
        x, a = _ffn_block(lp, x, cfg, ctx)
        return (x, aux + a), (upd, vpd)

    (x, _), (ks, vs) = jax.lax.scan(layer, (x, jnp.zeros((), jnp.float32)),
                                    (params["layers"], cache["k"], cache["v"]))
    x = rmsnorm(x, params["ln_out"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = _mask_pad_logits((x @ head)[:, 0], cfg)
    return logits, {"k": ks, "v": vs, "len": cache["len"] + 1}
