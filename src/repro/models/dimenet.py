"""DimeNet: directional message passing (arXiv:2003.03123).

Kernel regime: TRIPLET GATHER — messages live on edges; each interaction
block aggregates over triplets (k→j→i): the incoming message m_kj is
modulated by the angular basis of angle ∠(k,j,i) through a bilinear layer,
then scatter-combined (⊕ = sum) back onto edge (j→i).  Two nested levels of
the GRE primitive: edge→triplet gather, triplet→edge combine, plus the final
edge→node combine in the output blocks.

Triplet lists are precomputed host-side (`build_triplets`) like the paper's
offline graph ingress; shapes are padded static for XLA.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.nn.equivariant import bessel_basis, cosine_cutoff
from repro.nn.layers import dense_init, mlp_apply, mlp_init

CUTOFF = 5.0


def build_triplets(src: np.ndarray, dst: np.ndarray, num_nodes: int,
                   pad_to: int = 0) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side: for each edge pair (k→j, j→i) with k != i emit a triplet.

    Returns (edge_kj [T], edge_ji [T], mask [T]) padded to `pad_to`.
    """
    E = src.shape[0]
    by_dst: Dict[int, list] = {}
    for e in range(E):
        by_dst.setdefault(int(dst[e]), []).append(e)
    kj, ji = [], []
    for e_ji in range(E):
        j = int(src[e_ji])
        for e_kj in by_dst.get(j, ()):
            if int(src[e_kj]) != int(dst[e_ji]):
                kj.append(e_kj)
                ji.append(e_ji)
    t = len(kj)
    pad_to = max(pad_to, t, 1)
    out_kj = np.zeros(pad_to, np.int32)
    out_ji = np.zeros(pad_to, np.int32)
    mask = np.zeros(pad_to, bool)
    out_kj[:t] = kj
    out_ji[:t] = ji
    mask[:t] = True
    return out_kj, out_ji, mask


def angular_basis(cos_angle: jnp.ndarray, n_spherical: int) -> jnp.ndarray:
    """Chebyshev angular expansion T_n(cos θ) (stand-in for the spherical
    Bessel × Legendre basis; same tensor shape and smoothness class)."""
    terms = [jnp.ones_like(cos_angle), cos_angle]
    for _ in range(2, n_spherical):
        terms.append(2 * cos_angle * terms[-1] - terms[-2])
    return jnp.stack(terms[:n_spherical], axis=-1)


def init_dimenet(key, cfg: GNNConfig, n_species: int = 16, d_out: int = 1):
    ch, nb = cfg.d_hidden, cfg.n_bilinear
    nr, ns = cfg.n_radial, cfg.n_spherical
    ks = iter(jax.random.split(key, 16 + 8 * cfg.n_layers))
    params = {
        "embed": jax.random.normal(next(ks), (n_species, ch)) * 0.5,
        "rbf_proj": dense_init(next(ks), nr, ch),
        "msg_init": mlp_init(next(ks), [3 * ch, ch]),
        "blocks": [],
        "out_rbf": dense_init(next(ks), nr, ch),
        "readout": mlp_init(next(ks), [ch, ch, d_out]),
    }
    for _ in range(cfg.n_layers):
        params["blocks"].append({
            "w_src": dense_init(next(ks), ch, ch),
            "w_msg": dense_init(next(ks), ch, ch),
            "sbf_proj": dense_init(next(ks), ns * nr, nb),
            "bilinear": jax.random.normal(next(ks), (ch, nb, ch)) * (1.0 / np.sqrt(ch)),
            "update": mlp_init(next(ks), [ch, ch, ch]),
        })
    return params


def dimenet_forward(params, pos: jnp.ndarray, species: jnp.ndarray,
                    src: jnp.ndarray, dst: jnp.ndarray, edge_mask: jnp.ndarray,
                    tri_kj: jnp.ndarray, tri_ji: jnp.ndarray,
                    tri_mask: jnp.ndarray, cfg: GNNConfig,
                    wsc=None) -> jnp.ndarray:
    """Returns per-node outputs [V, d_out].

    `wsc(x)` (optional) re-applies the leading-axis sharding constraint on
    the big edge/triplet intermediates (full-graph SPMD cells)."""
    if wsc is None:
        wsc = lambda x: x
    V, E = pos.shape[0], src.shape[0]
    vec = pos[dst] - pos[src]
    d = jnp.linalg.norm(vec, axis=-1)
    rbf = bessel_basis(d, cfg.n_radial, CUTOFF) * cosine_cutoff(d, CUTOFF)[:, None]

    # angle at j between (k→j) and (j→i): cos θ = -v_kj·v_ji /(|..||..|)
    v_kj = jnp.take(vec, tri_kj, axis=0)
    v_ji = jnp.take(vec, tri_ji, axis=0)
    cosang = (v_kj * v_ji).sum(-1) / jnp.maximum(
        jnp.linalg.norm(v_kj, axis=-1) * jnp.linalg.norm(v_ji, axis=-1), 1e-6)
    d_kj = jnp.take(d, tri_kj, axis=0)
    sbf = (angular_basis(cosang, cfg.n_spherical)[:, :, None] *
           bessel_basis(d_kj, cfg.n_radial, CUTOFF)[:, None, :]
           ).reshape(-1, cfg.n_spherical * cfg.n_radial)    # [T, ns*nr]
    sbf = wsc(sbf * tri_mask[:, None])

    # initial edge messages from endpoint embeddings + rbf
    hz = jnp.take(params["embed"], species, axis=0)
    m = mlp_apply(params["msg_init"], jnp.concatenate(
        [hz[src], hz[dst], rbf @ params["rbf_proj"]], axis=-1))  # [E, ch]
    m = wsc(m * edge_mask[:, None])

    node_out = jnp.zeros((V, params["embed"].shape[1]), pos.dtype)
    def block_fn(m, blk):
        # triplet interaction: m_kj (gather) ⊙ bilinear(sbf) → combine on (j,i)
        m_kj = wsc(jnp.take(m, tri_kj, axis=0))              # [T, ch]
        sb = wsc(sbf @ blk["sbf_proj"])                      # [T, nb]
        inter = wsc(jnp.einsum("tc,cbd,tb->td", m_kj, blk["bilinear"], sb))
        agg = wsc(jax.ops.segment_sum(inter * tri_mask[:, None], tri_ji, E))
        m = m + jax.nn.silu(m @ blk["w_msg"] + agg @ blk["w_src"])
        m = wsc(m * edge_mask[:, None])
        m = m + mlp_apply(blk["update"], m, act=jax.nn.silu)
        return wsc(m)

    for blk in params["blocks"]:
        m = jax.checkpoint(block_fn)(m, blk)
        # per-block output: edge → node scatter-combine
        node_out = node_out + jax.ops.segment_sum(
            m * (rbf @ params["out_rbf"]), dst, V)

    return mlp_apply(params["readout"], node_out, act=jax.nn.silu)


def dimenet_forward_sharded(params, shard, topo_tri, topo_node, cfg: GNNConfig,
                            axes) -> jnp.ndarray:
    """Agent-Graph DimeNet (inside shard_map; §Perf hillclimb on
    ogb_products).

    The GSPMD path all-gathers the [E, ch] message tensor to every device
    for the triplet gather and all-reduces E-sized partials back (29.5 GiB
    per collective at ogb_products scale — both infeasible and collective-
    bound).  Here BOTH nested combines run through the paper's combiner
    agents:

      triplets are ingress-sorted by their kj edge, so `m[tri_kj]` is a
      LOCAL gather; the triplet→edge(ji) combine goes into local combiner
      slots and ONE all_to_all per block (`flush_combiners`); the final
      edge→node combine uses a second agent topology the same way.

    `shard` per-device arrays: species_src/dst [E_loc], rbf_d [E_loc],
    tri_kj_loc [T_loc], tri_tgt_slot [T_loc] (local ji edge or combiner
    slot), tri_mask [T_loc], sbf [T_loc, ns·nr], dst_slot [E_loc]
    (local node or node-combiner slot), target [V_loc].
    """
    from repro.core.dist_engine import flush_combiners
    from repro.core.vertex_program import MONOIDS

    ch = cfg.d_hidden
    e_slots = topo_tri.part.num_slots          # E_loc + tri combiners + sink
    v_slots = topo_node.part.num_slots         # V_loc + node combiners + sink
    e_loc = topo_tri.part.num_masters
    v_loc = topo_node.part.num_masters
    sum_m = MONOIDS["sum"]

    rbf = bessel_basis(shard["d"], cfg.n_radial, CUTOFF) \
        * cosine_cutoff(shard["d"], CUTOFF)[:, None]
    hz_s = jnp.take(params["embed"], shard["species_src"], axis=0)
    hz_d = jnp.take(params["embed"], shard["species_dst"], axis=0)
    m = mlp_apply(params["msg_init"], jnp.concatenate(
        [hz_s, hz_d, rbf @ params["rbf_proj"]], axis=-1))       # [E_loc, ch]
    m = m * shard["edge_mask"][:, None]
    sbf = shard["sbf"] * shard["tri_mask"][:, None]

    def block_fn(m, blk):
        m_kj = jnp.take(m, shard["tri_kj_loc"], axis=0)          # LOCAL
        sb = sbf @ blk["sbf_proj"]
        inter = jnp.einsum("tc,cbd,tb->td", m_kj, blk["bilinear"], sb)
        inter = inter * shard["tri_mask"][:, None]
        comb = jax.ops.segment_sum(inter, shard["tri_tgt_slot"], e_slots)
        flushed = flush_combiners(topo_tri, comb, axes, sum_m)
        agg = comb[:e_loc] + flushed[:e_loc]                     # ji edges
        m = m + jax.nn.silu(m @ blk["w_msg"] + agg @ blk["w_src"])
        m = m * shard["edge_mask"][:, None]
        return m + mlp_apply(blk["update"], m, act=jax.nn.silu)

    node_out = jnp.zeros((v_loc, ch), m.dtype)
    for blk in params["blocks"]:
        m = jax.checkpoint(block_fn)(m, blk)
        contrib = m * (rbf @ params["out_rbf"])
        comb = jax.ops.segment_sum(contrib, shard["dst_slot"], v_slots)
        flushed = flush_combiners(topo_node, comb, axes, sum_m)
        node_out = node_out + comb[:v_loc] + flushed[:v_loc]

    return mlp_apply(params["readout"], node_out, act=jax.nn.silu)
