"""Docs link/reference checker (CI `docs` job).

  python tools/check_docs.py [--docs docs] [--root .]

Scans every `docs/*.md` for three kinds of references and exits non-zero
if any is dead, so stale docs fail the build instead of rotting:

  * markdown links `[text](target)` — http(s)/mailto targets are skipped;
    everything else (with any `#anchor` stripped) must exist relative to
    the doc's directory or the repo root;
  * wiki-style refs `[[name]]` — must name another doc (`docs/<name>.md`);
  * repo paths in prose/backticks — any token shaped like
    `dir/sub/file.ext` with a known extension must exist relative to the
    repo root (tokens containing glob/placeholder characters are skipped).

No dependencies beyond the standard library.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
WIKI_REF = re.compile(r"\[\[([^\]]+)\]\]")
# dir/file.ext tokens in prose or backticks; extensions kept deliberately
# narrow to avoid false positives on things like version numbers
REPO_PATH = re.compile(
    r"(?<![\w/.])((?:[\w-]+/)+[\w.-]+\."
    r"(?:py|md|json|yml|yaml|toml|ini|txt|sh))(?![\w/])")
PLACEHOLDER = re.compile(r"[*<>{}$]")


def check_file(doc: Path, docs_dir: Path, root: Path) -> list:
    text = doc.read_text()
    errors = []

    def exists(rel: str, base: Path) -> bool:
        return (base / rel).exists() or (root / rel).exists()

    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:  # pure #anchor link
            continue
        if not exists(path, doc.parent):
            errors.append(f"{doc}: dead link ({target})")

    for m in WIKI_REF.finditer(text):
        name = m.group(1).split("|", 1)[0].split("#", 1)[0].strip()
        if not (docs_dir / f"{name}.md").exists():
            errors.append(f"{doc}: unresolved wiki ref [[{name}]]")

    for m in REPO_PATH.finditer(text):
        token = m.group(1)
        if PLACEHOLDER.search(token):
            continue
        if not exists(token, doc.parent):
            errors.append(f"{doc}: missing repo path ({token})")

    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--docs", default="docs", help="docs directory to scan")
    ap.add_argument("--root", default=".", help="repo root for path refs")
    args = ap.parse_args(argv)
    root = Path(args.root).resolve()
    docs_dir = Path(args.docs)
    if not docs_dir.is_absolute():
        docs_dir = root / docs_dir
    files = sorted(docs_dir.glob("*.md"))
    if not files:
        print(f"check_docs: no markdown files under {docs_dir}",
              file=sys.stderr)
        return 1
    errors = []
    for doc in files:
        errors.extend(check_file(doc, docs_dir, root))
    for e in errors:
        print(e)
    print(f"check_docs: {len(files)} file(s), {len(errors)} dead reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
