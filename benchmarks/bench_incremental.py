"""Incremental re-convergence payoff: warm start vs cold restart after a
small edge delta (docs/incremental.md).

The claim under test is the whole point of delta ingress + warm start: a
1% churn batch invalidates a small region of the previous fixed point, so
re-converging from it should scan a small fraction of the edges a cold
restart scans — and never take MORE supersteps, since the warm state
starts at (or past) the cold run's late-stage wavefront.

Two scenarios, both SSSP (weighted, path invalidation):

* **power-law (Barabási–Albert)** — the headline case: short diameter,
  so a cold restart floods nearly every edge within a few supersteps
  while the warm run touches only the delta's influence cones.
  ACCEPTANCE (asserted here, not just gated): the cold restart scans
  >= 3x the warm run's edges, and the warm run takes no more supersteps.
* **circulant** — the long-diameter trend row: a removed ring edge can
  taint a long downstream stretch and an added chord can re-converge
  half the ring, so the scan ratio is reported for trend reading only.

Edge scans are counted exactly — sum over supersteps of the active
masters' out-degrees, read off the host between single jitted supersteps
(the canonical superstep makes the active trajectory, and therefore the
count, identical across frontier strategies).  Wall-clock entries time
the jitted end-to-end runs (`GREEngine.run`) for the CI artifact; the
scan counts ride in `derived`.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import barabasi_albert_graph, circulant_graph
from repro.graph.structures import EdgeDelta


def _churn(g, frac, seed):
    """A `frac` churn batch: retire that fraction of the live edges and
    add the same count of fresh random ones (integer weights: exact in
    f32, so warm == cold stays bitwise)."""
    rng = np.random.default_rng(seed)
    n = g.num_vertices
    m = max(1, int(g.num_edges * frac))
    pick = rng.choice(g.num_edges, size=m, replace=False)
    add_s = rng.integers(0, n, size=m)
    add_d = rng.integers(0, n, size=m)
    # in-batch duplicate (src, dst) rows are rejected by delta ingress
    _, first = np.unique(add_s.astype(np.int64) * n + add_d,
                         return_index=True)
    keep = np.sort(first)
    add_s, add_d = add_s[keep], add_d[keep]
    return EdgeDelta(
        add_src=add_s, add_dst=add_d,
        add_props={"weight": rng.integers(1, 100, size=keep.size)
                   .astype(np.float32)},
        rem_src=np.asarray(g.src)[pick], rem_dst=np.asarray(g.dst)[pick])


def _run_counted(eng, part, state, max_steps=600):
    """Run to quiescence one jitted superstep at a time, counting the
    exact edge scans: sum of active masters' out-degrees per superstep."""
    step = jax.jit(lambda s: eng.superstep(part, s))
    out_deg = np.asarray(part.aux["out_degree"])
    n = part.num_masters
    scans = steps = 0
    while steps < max_steps:
        act = np.asarray(state.active_scatter)[:n]
        if not act.any():
            break
        scans += int(out_deg[act].sum())
        state = step(state)
        steps += 1
    return state, scans, steps


def _scenario(name, g, churn, seed, iters, assert_ratio=None):
    prog = algorithms.sssp_program()
    eng = GREEngine(prog)
    part = DevicePartition.from_graph(g)
    prev = eng.run(part, eng.init_state(part, source=0), 600)
    delta = _churn(g, churn, seed)
    new_part, report = part.apply_edge_delta(delta)
    warm0 = eng.warm_start_state(new_part, prev, report, source=0)
    cold0 = eng.init_state(new_part, source=0)
    warm_out, warm_scans, warm_steps = _run_counted(eng, new_part, warm0)
    cold_out, cold_scans, cold_steps = _run_counted(eng, new_part, cold0)
    np.testing.assert_array_equal(np.asarray(warm_out.vertex_data),
                                  np.asarray(cold_out.vertex_data))
    ratio = cold_scans / max(warm_scans, 1)
    if assert_ratio is not None:
        assert ratio >= assert_ratio, (
            f"{name}: warm start scanned {warm_scans} edges vs cold "
            f"{cold_scans} — below the {assert_ratio}x payoff floor")
        assert warm_steps <= cold_steps, (name, warm_steps, cold_steps)
    run_fn = jax.jit(lambda s: eng.run(new_part, s, 600))
    t_warm = time_fn(lambda: run_fn(warm0), iters=iters)
    t_cold = time_fn(lambda: run_fn(cold0), iters=iters)
    edges = int(np.asarray(new_part.edge_mask).sum())
    emit(f"incremental_{name}_warm", t_warm, edges=edges,
         derived=f"scans={warm_scans} steps={warm_steps} "
                 f"scan_ratio={ratio:.1f}x")
    emit(f"incremental_{name}_cold", t_cold, edges=edges,
         derived=f"scans={cold_scans} steps={cold_steps}")


def run(scale=11, churn=0.01, iters=3):
    """The headline row: 1% churn on a BA power-law graph.  The >= 3x
    edge-scan payoff floor is ASSERTED — a regression that erodes the
    warm start's selectivity fails the bench outright, before the
    wall-clock gate ever sees it."""
    g = barabasi_albert_graph(1 << scale, m=8, seed=7, weights=True)
    _scenario(f"ba{scale}", g, churn, seed=11, iters=iters, assert_ratio=3.0)


def run_circulant(scale=11, churn=0.01, iters=3):
    """Trend row: long-diameter ring where a single added chord can
    legitimately re-converge half the graph — reported, not asserted."""
    g = circulant_graph(1 << scale, degree=8, weights=True, seed=3)
    _scenario(f"circulant{scale}", g, churn, seed=13, iters=iters)


def main():
    run()
    run_circulant()


if __name__ == "__main__":
    main()
