"""Paper §2.2 motivation: two-sided GAS (intermediate edge-state storage,
extra load/store per edge) vs one-sided Scatter-Combine, same semantics.

Reports per-superstep runtime of both paths and the extra memory traffic
GAS pays (the [E] edge-state round trip Scatter-Combine eliminates)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import rmat_edges


def main():
    g = rmat_edges(scale=14, edge_factor=16, seed=0).dedup()
    part = DevicePartition.from_graph(g)
    eng = GREEngine(algorithms.pagerank_program())
    state = eng.init_state(part)

    sc_step = jax.jit(lambda s: eng.superstep(part, s))
    us_sc = time_fn(sc_step, state, iters=5)

    # faithful GAS: the two phases are SEPARATE program launches with the
    # [E] edge state persisting between them (Pregel's super-step boundary);
    # a single fused jit would let XLA hide the round trip
    from repro.core.vertex_program import segment_combine as _sc

    p = eng.program

    @jax.jit
    def gas_scatter_phase(s):
        gathered = jnp.take(s.scatter_data, part.src, axis=0)
        msgs = p.scatter_msg(gathered, None)
        live = jnp.take(s.active_scatter, part.src, axis=0) & part.edge_mask
        return jnp.where(live, msgs, p.monoid.identity)

    @jax.jit
    def gas_gather_phase(s, edge_state):
        combined = _sc(edge_state, part.dst, part.num_slots, p.monoid,
                       indices_are_sorted=True)
        return eng.apply(part, s, combined)

    def gas_step(s, e):
        e2 = gas_scatter_phase(s)
        return gas_gather_phase(s, e2), e2

    edge_state = jnp.zeros(part.src.shape[0], jnp.float32)
    us_gas = time_fn(gas_step, state, edge_state, iters=5)

    # TPU-modeled memory traffic from the compiled HLO (the CPU wall clock
    # hides the HBM round trip; the roofline term does not)
    from repro.launch import roofline as rl
    mem_sc = rl.analyze(jax.jit(sc_step).lower(state).compile().as_text()
                        )["hbm_bytes_per_device"]
    mem_gas = (rl.analyze(gas_scatter_phase.lower(state).compile().as_text()
                          )["hbm_bytes_per_device"]
               + rl.analyze(gas_gather_phase.lower(state, edge_state)
                            .compile().as_text())["hbm_bytes_per_device"])

    # Finding (recorded in EXPERIMENTS.md): the XLA path materializes the
    # [E] message vector either way, so XLA-level HBM bytes match; the
    # paper's fusion win is realized by the Pallas segment_combine kernel,
    # which generates messages in VMEM.  Modeled TPU HBM words per superstep:
    E, V = g.num_edges, g.num_vertices
    sc_pallas_bytes = (3 * E + V) * 4          # ids + gathered src + out
    gas_bytes = (5 * E + V) * 4                # + edge-state store + reload
    emit("gas_vs_sc_scatter_combine", us_sc,
         f"E={E};hbm_bytes_xla={mem_sc:.0f};"
         f"modeled_tpu_bytes={sc_pallas_bytes}")
    emit("gas_vs_sc_gas_emulation", us_gas,
         f"ratio={us_gas / us_sc:.2f}x;hbm_bytes_xla={mem_gas:.0f};"
         f"modeled_tpu_bytes={gas_bytes};"
         f"modeled_saving={gas_bytes / sc_pallas_bytes:.2f}x")


if __name__ == "__main__":
    main()
