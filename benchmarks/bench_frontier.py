"""Dense-mask vs frontier-compacted traversal (ROADMAP item 1 payoff).

Two scenarios:

* **circulant** — the uniform-degree sparse-frontier case: a BFS frontier
  never exceeds `degree` vertices (≈0.2-0.8% of V), so the dense
  every-edge scan wastes ≥99% of its gather bandwidth every superstep;
  end-to-end dense vs compacted runtimes (~6-8× observed on CPU XLA).
* **power-law (Barabási–Albert)** — the skew case degree BUCKETING
  exists for: hubs inflate the single flat tile's `max_deg` until the
  padded gather out-scans the dense path (the old `cap * max_deg >= E`
  static fallback), while per-bucket tiles stay tight.  Times ONE
  scatter-combine at a fixed ~1% frontier density for each strategy
  (dense / flat single-tile / bucketed) and asserts `frontier="auto"`
  statically selects the bucketed path; expected ≥2× bucketed vs dense
  ns/edge.
* **power-law Pallas tile combine** (`run_powerlaw_pallas`) — the
  dynamic block table's payoff: the same bucketed scatter with
  `use_pallas=True`, on-device `dynamic_block_table` pruning vs the
  degenerate full-table fallback (interpret mode on CPU, so runtimes are
  visit-count-driven and the scenario stays small).  Emits the measured
  `block_table_occupancy` — the visited fraction of (dst block, edge
  block) pairs — which the acceptance contract bounds at ≤ 0.25 for ~1%
  frontier density, and asserts the dynamic path is no slower than the
  full table.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import algorithms
from repro.core.engine import DevicePartition, EngineState, GREEngine
from repro.graph.generators import barabasi_albert_graph, circulant_graph


def _frontier_stats(eng, part, state, max_steps):
    """Mean/max frontier fraction over the run (host loop, not timed)."""
    sizes = []
    for _ in range(max_steps):
        if not bool(jnp.any(state.active_scatter)):
            break
        sizes.append(int(jnp.sum(state.active_scatter)))
        state = eng.superstep(part, state)
    frac = np.asarray(sizes, np.float64) / part.num_slots
    return float(frac.mean()), float(frac.max())


def run(scale: int = 13, degree: int = 16, iters: int = 3):
    n = 1 << scale
    g = circulant_graph(n, degree=degree, weights=True)
    rng = np.random.default_rng(0)
    g.edge_props["weight"][:] = rng.integers(1, 3, size=g.num_edges
                                             ).astype(np.float32)
    part = DevicePartition.from_graph(g)
    max_steps = 2 * n // degree + 32

    for pname, prog in (("bfs", algorithms.bfs_program()),
                        ("sssp", algorithms.sssp_program())):
        us = {}
        for strategy in ("dense", "compact"):
            eng = GREEngine(prog, frontier=strategy)
            run_fn = jax.jit(lambda s, e=eng: e.run(part, s, max_steps))
            st = eng.init_state(part, source=0)
            us[strategy] = time_fn(run_fn, st, warmup=1, iters=iters)
        steps = int(run_fn(st).step)
        mean_f, max_f = _frontier_stats(
            GREEngine(prog, frontier="dense"), part,
            GREEngine(prog).init_state(part, source=0), max_steps)
        speedup = us["dense"] / us["compact"]
        common = (f"V={n};E={g.num_edges};supersteps={steps};"
                  f"frontier_mean={mean_f:.4f};frontier_max={max_f:.4f}")
        edge_work = g.num_edges * steps  # edges scanned by the dense path
        emit(f"{pname}_dense_circulant{scale}", us["dense"], common,
             edges=edge_work)
        emit(f"{pname}_compact_circulant{scale}", us["compact"],
             f"{common};speedup_vs_dense={speedup:.2f}", edges=edge_work)
    return us


def _powerlaw_setup(scale: int, m: int, density: float):
    """Shared BA-graph scenario: partition + frozen ~`density` frontier."""
    n = 1 << scale
    g = barabasi_albert_graph(n, m=m, seed=0).dedup()
    part = DevicePartition.from_graph(g)
    prog = algorithms.bfs_program()
    # auto must statically pick the bucketed plan (the old cap*max_deg >= E
    # hub gate used to force power-law graphs dense)
    auto_plan = GREEngine(prog, frontier="auto")._frontier_plan(part)
    assert auto_plan is not None and auto_plan[0] == "bucketed", auto_plan
    rng = np.random.default_rng(1)
    live = rng.choice(n, size=max(8, int(n * density)), replace=False)
    active = np.zeros(part.num_slots, dtype=bool)
    active[live] = True
    return g, part, prog, active, live, rng


def run_powerlaw(scale: int = 13, m: int = 8, iters: int = 5,
                 density: float = 0.01, repeats: int = 64):
    """Dense vs flat-compact vs bucketed scatter-combine on a power-law
    graph at a fixed ~`density` frontier.

    A full BFS on a Barabási–Albert graph floods within a few supersteps,
    so instead of end-to-end runs this times `repeats` chained
    scatter-combines over a frozen random frontier of `density * V` slots
    — the controlled-density regime the acceptance contract names.  The
    output of each combine feeds the next call's scatter data, so XLA
    cannot elide the repeats.
    """
    n = 1 << scale
    g, part, prog, active, live, rng = _powerlaw_setup(scale, m, density)
    e_scan = g.num_edges * repeats

    def make_fn(strategy):
        eng = GREEngine(prog, frontier=strategy)
        st0 = eng.init_state(part)

        def many(sd):
            def body(_, s):
                out = eng.scatter_combine(
                    part, EngineState(st0.vertex_data, s,
                                      jnp.asarray(active), st0.step))
                return jnp.where(jnp.isfinite(out), out, s)
            return jax.lax.fori_loop(0, repeats, body, sd)

        sd = st0.scatter_data.at[:n].set(
            jnp.asarray(rng.uniform(1.0, 100.0, n), jnp.float32))
        return jax.jit(many), sd

    us = {}
    for strategy in ("dense", "flat", "compact"):
        fn, sd = make_fn(strategy)
        us[strategy] = time_fn(fn, sd, warmup=1, iters=iters)
    frac = live.shape[0] / n
    common = (f"V={n};E={g.num_edges};repeats={repeats};"
              f"frontier={frac:.4f};max_deg={part.csr_max_deg};"
              f"buckets={'/'.join(map(str, part.bucket_sizes))}")
    emit(f"powerlaw_scatter_dense_ba{scale}", us["dense"], common,
         edges=e_scan)
    emit(f"powerlaw_scatter_flat_ba{scale}", us["flat"],
         f"{common};speedup_vs_dense={us['dense'] / us['flat']:.2f}",
         edges=e_scan)
    emit(f"powerlaw_scatter_bucketed_ba{scale}", us["compact"],
         f"{common};speedup_vs_dense={us['dense'] / us['compact']:.2f};"
         f"auto_plan=bucketed", edges=e_scan)
    return us


def run_powerlaw_pallas(scale: int = 11, m: int = 8, iters: int = 3,
                        density: float = 0.01):
    """Pallas bucketed tile combine: on-device dynamic block table vs the
    degenerate full-table fallback, on the Barabási–Albert scenario.

    Kernels run in interpret mode (CPU), where cost tracks the number of
    (dst block, edge block) visits — exactly what the dynamic table
    prunes — so the scenario stays at the smoke scale regardless of the
    suite mode.  Emits the measured `block_table_occupancy`; the
    acceptance contract bounds it at ≤ 0.25 for ~1% frontier density and
    requires the dynamic path to be no slower than the full table.
    """
    from repro.core.frontier import bucketed_tile_occupancy

    n = 1 << scale
    g, part, prog, active, live, rng = _powerlaw_setup(scale, m, density)

    def make_fn(dynamic):
        eng = GREEngine(prog, frontier="compact", use_pallas=True,
                        dynamic_table=dynamic)
        st0 = eng.init_state(part)

        def one(sd):
            return eng.scatter_combine(
                part, EngineState(st0.vertex_data, sd,
                                  jnp.asarray(active), st0.step))

        sd = st0.scatter_data.at[:n].set(
            jnp.asarray(rng.uniform(1.0, 100.0, n), jnp.float32))
        return jax.jit(one), sd

    us = {}
    for mode, dynamic in (("dynamic", True), ("full", False)):
        fn, sd = make_fn(dynamic)
        us[mode] = time_fn(fn, sd, warmup=1, iters=iters)

    caps = GREEngine(prog, frontier="compact")._frontier_plan(part)[1]
    visited, total = bucketed_tile_occupancy(part, jnp.asarray(active), caps)
    occ = visited / max(total, 1)
    assert occ <= 0.25, \
        f"dynamic table visits {occ:.1%} of the full table (want <= 25%)"
    assert us["dynamic"] <= us["full"] * 1.1, \
        f"dynamic {us['dynamic']:.0f}us slower than full {us['full']:.0f}us"
    frac = live.shape[0] / n
    emit(f"powerlaw_scatter_pallas_dynamic_ba{scale}", us["dynamic"],
         f"V={n};E={g.num_edges};frontier={frac:.4f};"
         f"block_table_occupancy={occ:.4f};visited={visited};total={total};"
         f"speedup_vs_full_table={us['full'] / us['dynamic']:.2f}",
         edges=g.num_edges)
    return us


def main():
    run(13)
    run_powerlaw(13)
    run_powerlaw_pallas(11)


if __name__ == "__main__":
    main()
