"""Dense-mask vs frontier-compacted traversal (ROADMAP item 1 payoff).

The workload frontier compaction targets: a uniform-degree circulant graph
whose BFS frontier never exceeds `degree` vertices (≈0.2-0.8% of V), so the
dense every-edge scan wastes ≥99% of its gather bandwidth every superstep.
SSSP runs with weights in {1, 2} — enough label correcting to be
non-degenerate while the frontier stays a few percent of V.

Emits end-to-end runtimes for both strategies plus the speedup; the
compacted path is expected ≥2× faster (observed ~6-8× on CPU XLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import circulant_graph


def _frontier_stats(eng, part, state, max_steps):
    """Mean/max frontier fraction over the run (host loop, not timed)."""
    sizes = []
    for _ in range(max_steps):
        if not bool(jnp.any(state.active_scatter)):
            break
        sizes.append(int(jnp.sum(state.active_scatter)))
        state = eng.superstep(part, state)
    frac = np.asarray(sizes, np.float64) / part.num_slots
    return float(frac.mean()), float(frac.max())


def run(scale: int = 13, degree: int = 16, iters: int = 3):
    n = 1 << scale
    g = circulant_graph(n, degree=degree, weights=True)
    rng = np.random.default_rng(0)
    g.edge_props["weight"][:] = rng.integers(1, 3, size=g.num_edges
                                             ).astype(np.float32)
    part = DevicePartition.from_graph(g)
    max_steps = 2 * n // degree + 32

    for pname, prog in (("bfs", algorithms.bfs_program()),
                        ("sssp", algorithms.sssp_program())):
        us = {}
        for strategy in ("dense", "compact"):
            eng = GREEngine(prog, frontier=strategy)
            run_fn = jax.jit(lambda s, e=eng: e.run(part, s, max_steps))
            st = eng.init_state(part, source=0)
            us[strategy] = time_fn(run_fn, st, warmup=1, iters=iters)
        steps = int(run_fn(st).step)
        mean_f, max_f = _frontier_stats(
            GREEngine(prog, frontier="dense"), part,
            GREEngine(prog).init_state(part, source=0), max_steps)
        speedup = us["dense"] / us["compact"]
        common = (f"V={n};E={g.num_edges};supersteps={steps};"
                  f"frontier_mean={mean_f:.4f};frontier_max={max_f:.4f}")
        edge_work = g.num_edges * steps  # edges scanned by the dense path
        emit(f"{pname}_dense_circulant{scale}", us["dense"], common,
             edges=edge_work)
        emit(f"{pname}_compact_circulant{scale}", us["compact"],
             f"{common};speedup_vs_dense={speedup:.2f}", edges=edge_work)
    return us


def main():
    run(13)


if __name__ == "__main__":
    main()
