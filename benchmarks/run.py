"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run            # all
  python -m benchmarks.run pagerank   # one

Output: ``name,us_per_call,derived`` CSV on stdout.
"""
import sys

from benchmarks import (bench_gas_vs_sc, bench_memory, bench_pagerank,
                        bench_partition, bench_traversal, bench_weak)

SUITES = {
    "pagerank": bench_pagerank.main,     # Table 5 / Fig. 8a-b
    "traversal": bench_traversal.main,   # Fig. 8c-d
    "weak": bench_weak.main,             # Fig. 10
    "partition": bench_partition.main,   # Fig. 11/12/13 + §5.1
    "memory": bench_memory.main,         # §7.1.2 memory claim
    "gas_vs_sc": bench_gas_vs_sc.main,   # §2.2 motivation
}


def main() -> None:
    wanted = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    for name in wanted:
        SUITES[name]()


if __name__ == "__main__":
    main()
