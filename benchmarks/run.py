"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run            # all
  python -m benchmarks.run pagerank   # one
  python -m benchmarks.run --smoke    # CI: one tiny config per suite

Output: ``name,us_per_call,derived`` CSV on stdout.
"""
import sys

from benchmarks import (bench_gas_vs_sc, bench_memory, bench_pagerank,
                        bench_partition, bench_traversal, bench_vector_combine,
                        bench_weak)

SUITES = {
    "pagerank": bench_pagerank.main,     # Table 5 / Fig. 8a-b
    "traversal": bench_traversal.main,   # Fig. 8c-d
    "weak": bench_weak.main,             # Fig. 10
    "partition": bench_partition.main,   # Fig. 11/12/13 + §5.1
    "memory": bench_memory.main,         # §7.1.2 memory claim
    "gas_vs_sc": bench_gas_vs_sc.main,   # §2.2 motivation
    "vector": bench_vector_combine.main, # D=64 feature-vector payloads
}

# Reduced-scale configs for the CI smoke run (seconds, not minutes); suites
# without an entry fall back to their full run.
SMOKE = {
    "pagerank": lambda: bench_pagerank.run(scale=8, iters=2),
    "vector": lambda: bench_vector_combine.run(scale=8, d_feat=64, iters=2),
}


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    if smoke:
        args.remove("--smoke")
    wanted = args or list(SMOKE if smoke else SUITES)
    unknown = [n for n in wanted if n not in SUITES]
    if unknown:
        sys.exit(f"unknown suite(s) {unknown}; choose from {list(SUITES)}")
    if smoke:
        print("name,us_per_call,derived")
        for name in wanted:
            SMOKE.get(name, SUITES[name])()
        return
    print("name,us_per_call,derived")
    for name in wanted:
        SUITES[name]()


if __name__ == "__main__":
    main()
