"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run                         # all
  python -m benchmarks.run pagerank                # one
  python -m benchmarks.run --smoke                 # CI: tiny config per suite
  python -m benchmarks.run --smoke --json OUT.json # CI: + perf artifact

``--json`` writes the machine-readable results (per-benchmark
us_per_call and, where meaningful, ns/edge) for the CI regression gate
(`benchmarks/compare.py` against the committed BENCH_baseline.json).
Human-readable ``name,us_per_call,derived`` CSV always goes to stdout.
"""
import json
import platform
import sys

from benchmarks import (bench_async, bench_exchange_overlap, bench_frontier,
                        bench_gas_vs_sc, bench_incremental, bench_memory,
                        bench_pagerank, bench_partition, bench_serving,
                        bench_traversal, bench_tuning, bench_vector_combine,
                        bench_weak, common)

SUITES = {
    "pagerank": bench_pagerank.main,     # Table 5 / Fig. 8a-b
    "traversal": bench_traversal.main,   # Fig. 8c-d
    "frontier": bench_frontier.main,     # dense vs compacted frontier
    "exchange_overlap": bench_exchange_overlap.main,  # §6.2 pipelined flush
    "async": bench_async.main,           # bounded-staleness ring vs sync
    "weak": bench_weak.main,             # Fig. 10
    "partition": bench_partition.main,   # Fig. 11/12/13 + §5.1
    "memory": bench_memory.main,         # §7.1.2 memory claim
    "gas_vs_sc": bench_gas_vs_sc.main,   # §2.2 motivation
    "vector": bench_vector_combine.main, # D=64 feature-vector payloads
    "tuning": bench_tuning.main,         # plan autotuner vs defaults
    # serving is ALSO a standalone CI job (`python -m benchmarks.bench_serving
    # --smoke --json ...` gated with `compare.py --only serving_`); the full
    # suite runs it at full scale here
    "serving": bench_serving.main,       # continuous batching vs re-init
    "incremental": bench_incremental.main,  # warm start vs cold restart
}

# Reduced-scale configs for the CI smoke run (seconds, not minutes); suites
# without an entry fall back to their full run.
SMOKE = {
    "pagerank": lambda: bench_pagerank.run(scale=8, iters=2),
    # powerlaw iters=7: the bucketed entry's many small per-bucket ops are
    # scheduler-sensitive on 2-core hosts; a wider median keeps the gated
    # value out of the bimodal tails
    "frontier": lambda: (bench_frontier.run(scale=12, iters=2),
                         bench_frontier.run_powerlaw(scale=11, iters=7),
                         bench_frontier.run_powerlaw_pallas(scale=11,
                                                            iters=3)),
    "exchange_overlap": lambda: bench_exchange_overlap.run(scale=10, k=2,
                                                           steps=24, iters=9),
    # the >= 1.3x flush-amortization floor is asserted inside the bench
    "async": lambda: bench_async.run(n=512, iters=3, n_ba=256),
    "vector": lambda: bench_vector_combine.run(scale=8, d_feat=64, iters=2),
    # powerlaw iters=7: the tuned-vs-default comparison is interleaved,
    # but the ~3ms BA runs still need a wide median on 2-core hosts
    "tuning": lambda: (bench_tuning.run(scale=11, iters=3),
                       bench_tuning.run_powerlaw(scale=10, iters=7)),
    # the >= 3x edge-scan payoff floor is asserted inside the bench
    "incremental": lambda: (bench_incremental.run(scale=10, iters=3),
                            bench_incremental.run_circulant(scale=10,
                                                            iters=3)),
    # the >= 15% HDRF-vs-greedy remote-dst floor is asserted inside run();
    # run_dist's exchange-volume reduction is asserted inside run_dist()
    "partition": lambda: (bench_partition.run(scale=11, ks=(4, 16)),
                          bench_partition.run_dist(scale=9, k=4, iters=3)),
    # byte models + chunked==monolithic ingress assert inside run()
    "memory": lambda: bench_memory.run(scale=11, k=16, chunk_size=1 << 13),
}


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    if smoke:
        args.remove("--smoke")
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        try:
            json_path = args[i + 1]
        except IndexError:
            sys.exit("--json needs an output path")
        del args[i:i + 2]
    wanted = args or list(SMOKE if smoke else SUITES)
    unknown = [n for n in wanted if n not in SUITES]
    if unknown:
        sys.exit(f"unknown suite(s) {unknown}; choose from {list(SUITES)}")
    print("name,us_per_call,derived")
    for name in wanted:
        if smoke and name in SMOKE:
            SMOKE[name]()
        else:
            SUITES[name]()
    if json_path:
        payload = {
            "mode": "smoke" if smoke else "full",
            "python": platform.python_version(),
            "machine": platform.machine(),
            "results": common.RESULTS,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {len(common.RESULTS)} results to {json_path}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
