"""Paper §7.1.2 memory claim: "PowerGraph requires at least 2 times more
memory space as it needs to store redundant in-edges and lots of
intermediate data".

Measured here as actual bytes of the runtime representation:
  GRE        — agent-graph topology (CSR columns) + one runtime-state value
               per slot; NO edge-state storage (one-sided combine);
  PowerGraph — same edges + redundant in-edge storage (×2 edges), mirror
               replicas of vertex state (replication factor R/V), and
               per-edge intermediate data (the gather phase's messages).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.agent_graph import build_agent_graph
from repro.core.partition import greedy_partition, partition_quality
from repro.graph.generators import rmat_edges


def main():
    g = rmat_edges(scale=13, edge_factor=16, seed=0).dedup()
    k = 16
    part = greedy_partition(g, k, batch_size=256)
    ag = build_agent_graph(g, part, k)
    q = partition_quality(g, part)

    # GRE bytes: stacked topology + exchange tables + 3 state columns/slot
    topo = (ag.src.nbytes + ag.dst.nbytes + ag.edge_mask.nbytes
            + ag.comb_send_slot.nbytes + ag.comb_recv_master.nbytes
            + ag.scat_send_master.nbytes + ag.scat_recv_slot.nbytes)
    slots = ag.k * ag.num_slots
    gre_state = 3 * slots * 4 + slots // 8
    gre_total = topo + gre_state

    # PowerGraph model: out-edges + redundant in-edges (2E), vertex replicas
    # R × full state (3 values), per-edge intermediate gather data (E × 4B)
    E, V = g.num_edges, g.num_vertices
    R = q.vertexcut_replicas
    pg_total = (2 * E * 8) + (R * 3 * 4) + (E * 4)

    emit("memory_gre_bytes", 0.0,
         f"bytes={gre_total};topology={topo};state={gre_state}")
    emit("memory_powergraph_model_bytes", 0.0,
         f"bytes={pg_total};replicas={R};ratio={pg_total / gre_total:.2f}x")


if __name__ == "__main__":
    main()
