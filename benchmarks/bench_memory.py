"""Paper §7.1.2 memory claim + the chunked-ingress byte budget.

The paper's headline is memory-bound scale — 1B vertices / 17B edges on
768GB, i.e. ~45 bytes of host memory per edge for the whole runtime
representation ("PowerGraph requires at least 2 times more memory space
as it needs to store redundant in-edges and lots of intermediate data").
Measured here as actual bytes:

  GRE        — agent-graph topology (CSR columns) + one runtime-state
               value per slot; NO edge-state storage (one-sided combine);
               derived `bytes_per_edge` is compared against the paper's
               768GB/17B budget line;
  PowerGraph — same edges + redundant in-edge storage (×2 edges), mirror
               replicas of vertex state (replication factor R/V), and
               per-edge intermediate data (the gather phase's messages);
  partitioner state — the loader-heuristic working set: packed greedy
               presence bitsets and HDRF's degree-aware state
               (`repro.core.partition_stream.*_state_bytes`), asserted
               against the measured arrays and the documented O(V·k/8)
               bound, vs the legacy O(2·k·V) bool layout;
  ingress    — the chunked two-pass `build_agent_graph` vs the
               whole-edge-list build: identical output (asserted bitwise
               on the edge columns), with peak transient state bounded
               by one chunk + the touch bitsets instead of full relabeled
               endpoint copies.

Peak host RSS (`resource.getrusage`, monotone over process life) is
reported next to every modeled count so the model can be sanity-checked
against what the allocator actually did.
"""
from __future__ import annotations

import resource
import time

import numpy as np

from benchmarks.common import emit
from repro.core.agent_graph import build_agent_graph
from repro.core.partition import greedy_partition, partition_quality
from repro.core.partition_stream import (greedy_state_bytes,
                                         hdrf_partition, hdrf_state_bytes)
from repro.graph.generators import rmat_edges

# the paper's budget line: 17B edges in 768GB of aggregate host memory
BUDGET_BYTES_PER_EDGE = 768e9 / 17e9


def _peak_rss_mb() -> float:
    """Peak resident set of this process so far, MB (ru_maxrss is KB on
    Linux)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run(scale: int = 13, k: int = 16, chunk_size: int = 1 << 14):
    g = rmat_edges(scale=scale, edge_factor=16, seed=0).dedup()
    E, V = g.num_edges, g.num_vertices

    part = greedy_partition(g, k, batch_size=256)
    ag = build_agent_graph(g, part, k)
    q = partition_quality(g, part,
                          partitioner_state_bytes=greedy_state_bytes(V, k))

    # GRE bytes: stacked topology + exchange tables + 3 state columns/slot
    topo = (ag.src.nbytes + ag.dst.nbytes + ag.edge_mask.nbytes
            + ag.comb_send_slot.nbytes + ag.comb_recv_master.nbytes
            + ag.scat_send_master.nbytes + ag.scat_recv_slot.nbytes)
    slots = ag.k * ag.num_slots
    gre_state = 3 * slots * 4 + slots // 8
    gre_total = topo + gre_state

    # PowerGraph model: out-edges + redundant in-edges (2E), vertex replicas
    # R × full state (3 values), per-edge intermediate gather data (E × 4B)
    R = q.vertexcut_replicas
    pg_total = (2 * E * 8) + (R * 3 * 4) + (E * 4)

    emit("memory_gre_bytes", 0.0,
         f"bytes={gre_total};topology={topo};state={gre_state};"
         f"bytes_per_edge={gre_total / E:.1f};"
         f"budget_bytes_per_edge={BUDGET_BYTES_PER_EDGE:.1f};"
         f"peak_rss_mb={_peak_rss_mb():.0f}")
    emit("memory_powergraph_model_bytes", 0.0,
         f"bytes={pg_total};replicas={R};ratio={pg_total / gre_total:.2f}x;"
         f"bytes_per_edge={pg_total / E:.1f}")

    # ---- partitioner loader state: packed vs legacy, modeled vs measured
    stats = {}
    hdrf_partition(g, k, stats=stats)
    legacy_bool = 2 * k * V + 8 * k       # the pre-packing [k, V] bool pair
    assert stats["state_bytes"] == hdrf_state_bytes(V, k), \
        (stats["state_bytes"], hdrf_state_bytes(V, k))
    assert hdrf_state_bytes(V, k) <= V * (-(-k // 8)) + 4 * V + 8 * k + 8 * V, \
        "HDRF state exceeds the documented O(V*k/8 + V + k) bound"
    emit("memory_partitioner_state_bytes", 0.0,
         f"hdrf={stats['state_bytes']};"
         f"greedy_packed={greedy_state_bytes(V, k)};"
         f"greedy_bool_legacy={legacy_bool};"
         f"pack_ratio={legacy_bool / greedy_state_bytes(V, k):.1f}x;"
         f"hdrf_replication={stats['replication_factor']:.3f};"
         f"peak_rss_mb={_peak_rss_mb():.0f}")

    # ---- chunked vs monolithic ingress: same bits, bounded transients
    t0 = time.time()
    ag_c = build_agent_graph(g.chunk_source(chunk_size), part, k)
    chunked_us = (time.time() - t0) * 1e6
    t0 = time.time()
    build_agent_graph(g, part, k)
    mono_us = (time.time() - t0) * 1e6
    for name in ("src", "dst", "edge_mask", "csr_indptr", "csr_eidx"):
        assert np.array_equal(getattr(ag, name), getattr(ag_c, name)), \
            f"chunked ingress diverged on {name}"
    # transient working set beyond the output tiles: one chunk (2 × int64
    # endpoint columns) + the packed touch bitsets + owner counts
    chunk_bytes = 2 * chunk_size * 8
    bitset_bytes = 2 * k * ((V + 63) // 64) * 8
    mono_transient = 4 * E * 8            # relabeled + owner endpoint copies
    emit("memory_ingress_chunked_us", chunked_us,
         f"chunk_size={chunk_size};monolithic_us={mono_us:.0f};"
         f"transient_bytes={chunk_bytes + bitset_bytes};"
         f"chunk_bytes={chunk_bytes};touch_bitset_bytes={bitset_bytes};"
         f"monolithic_transient_bytes={mono_transient};"
         f"peak_rss_mb={_peak_rss_mb():.0f}",
         edges=E, gate=False)
    return gre_total


def main():
    run()


if __name__ == "__main__":
    main()
