"""Paper Fig. 8c-d analog: SSSP and CC end-to-end runtimes on R-MAT."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import rmat_edges


def run(scale: int = 13):
    g = rmat_edges(scale=scale, edge_factor=16, seed=0, weights=True).dedup()
    part = DevicePartition.from_graph(g)

    eng = GREEngine(algorithms.sssp_program())
    run_fn = jax.jit(lambda s: eng.run(part, s, max_steps=200))
    st = eng.init_state(part, source=0)
    us = time_fn(run_fn, st, warmup=1, iters=3)
    steps = int(run_fn(st).step)
    emit(f"sssp_rmat{scale}", us,
         f"V={g.num_vertices};E={g.num_edges};supersteps={steps}",
         edges=g.num_edges * max(steps, 1))

    gu = g.as_undirected()
    part_u = DevicePartition.from_graph(gu)
    eng = GREEngine(algorithms.cc_program())
    run_fn = jax.jit(lambda s: eng.run(part_u, s, max_steps=200))
    st = eng.init_state(part_u)
    us = time_fn(run_fn, st, warmup=1, iters=3)
    steps = int(run_fn(st).step)
    emit(f"cc_rmat{scale}", us,
         f"V={gu.num_vertices};E={gu.num_edges};supersteps={steps}",
         edges=gu.num_edges * max(steps, 1))


def main():
    run(13)


if __name__ == "__main__":
    main()
