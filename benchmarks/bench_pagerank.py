"""Paper Table 5 / Fig. 8a-b analog: PageRank per-iteration runtime.

CPU-scaled: R-MAT graphs (Graph500 parameters, as in §7) instead of Twitter;
reports per-iteration time for the GRE Scatter-Combine engine, plus the
engine throughput in edges/s (the cross-system comparison number)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import rmat_edges


def run(scale: int = 14, edge_factor: int = 16, iters: int = 5):
    g = rmat_edges(scale=scale, edge_factor=edge_factor, seed=0).dedup()
    part = DevicePartition.from_graph(g)
    eng = GREEngine(algorithms.pagerank_program())
    state = eng.init_state(part)

    step = jax.jit(lambda s: eng.superstep(part, s))
    us = time_fn(step, state, iters=iters)
    eps = g.num_edges / (us / 1e6)
    emit(f"pagerank_iter_rmat{scale}", us,
         f"V={g.num_vertices};E={g.num_edges};edges_per_s={eps:.3g}",
         edges=g.num_edges)
    return us


def main():
    for scale in (12, 14):
        run(scale)


if __name__ == "__main__":
    main()
