"""Bounded-staleness async supersteps vs the synchronous exchange.

Races the sync AgentExchange against the k-deep AsyncAgentExchange ring
(`exchange="async"`) on single-source BFS, whole-run wall clock to
quiescence:

  sync      — AgentExchange: the refresh + combiner-flush collectives
              are a barrier in EVERY superstep;
  async-k2 / async-k4 — the staleness ring: remote partials accumulate
              in k ring slots and flush in ONE collective every k
              supersteps; shards proceed on stale remote state in
              between, and the monotone (min) fixed point is unchanged.

Two regimes, deliberately opposite:

  skewed ghost-chord ring — a directed ring sliced into contiguous
      EQUAL vertex blocks (master placement is cap-balanced by
      construction — `build_agent_graph` rebalances any vertex-count
      skew away, which would turn intra-block hops into agent-mediated
      crossings), so the BFS wavefront is intra-shard except at the
      k - 1 block boundaries and supersteps stay ~equal across modes.
      The imbalance lives in the EDGE load: every vertex outside block
      0 carries backward "ghost" chords into the previous block, with
      per-shard ghost degree skewed 2x geometrically.  Ghosts never
      improve a distance (their target is always closer to the source)
      but they populate ~cap combiner agents per shard, so the sync
      backend hauls a topology-sized flush payload across the mesh on
      every superstep — and waits on the heaviest shard to produce it —
      while the ring amortizes the same payload k-fold.  The parent
      asserts the async win here (>= `floor`x at the best measured
      ring depth).

  barabasi-albert + hash partition — nearly every edge crosses shards,
      so each BFS depth needs a flush before the next depth can make
      progress: supersteps inflate ~k-fold and eat the collective
      savings.  Recorded trend-only (no floor) as the documented
      counter-regime; the plan autotuner's measured search is what
      chooses per scenario.

Both regimes pin `frontier="dense"`: the masked every-edge scan keeps
the superstep body identical across backends, so the measured delta is
the exchange protocol itself.  (Compacted frontiers run the gather
machinery once per edge TILE, which double-charges the split backends
on ~empty frontiers and measures the frontier stage, not the ring.)

Runs in a subprocess because the multi-device XLA_FLAGS must be set
before jax initializes.  Same protocol as bench_exchange_overlap:
single-threaded simulated devices, interleaved measurement rounds,
per-mode medians; entries emit `gate=False` (absolute times of simulated
devices on shared CI hosts are scheduler-bimodal) — the async-vs-sync
comparison lives in the within-run medians of the derived speedups.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit

ROOT = Path(__file__).resolve().parent.parent

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%(k)d "
                           "--xla_cpu_multi_thread_eigen=false "
                           "intra_op_parallelism_threads=1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import time
import numpy as np
import jax

from repro.graph.structures import Graph
from repro.graph.generators import barabasi_albert_graph
from repro.core.partition import hash_partition
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core import algorithms

n, k, iters = %(n)d, %(k)d, %(iters)d
n_ba = %(n_ba)d

def ghost_ring(n, k):
    # directed ring in contiguous cap-aligned blocks (block b = shard b's
    # masters, exactly) + backward ghost chords i -> i - (cap + 1): each
    # crosses one block boundary, never improves a BFS distance, and the
    # per-shard ghost degree doubles per block -- skewed combiner/edge
    # load per shard with an intra-shard critical path.
    cap = -(-(-(-n // k)) // 8) * 8
    n = k * cap
    src = np.arange(n, dtype=np.int64)
    dst = (src + 1) %% n
    gs, gd = [src], [dst]
    for b in range(1, k):
        i = np.arange(max(b * cap, cap + 1), (b + 1) * cap, dtype=np.int64)
        for _ in range(2 ** (k - 1 - b)):
            gs.append(i)
            gd.append(i - (cap + 1))
    src, dst = np.concatenate(gs), np.concatenate(gd)
    g = Graph(num_vertices=n, src=src, dst=dst)
    part = (src // cap).astype(np.int64)
    owner = (np.arange(n, dtype=np.int64) // cap).astype(np.int32)
    return g, part, owner, n

def modes_for(g, part, max_steps, owner=None, source=0):
    ag = build_agent_graph(g, part, k, owner=owner)
    mesh = jax.make_mesh((k,), ("graph",))
    out = {}
    for mode, exchange, stal in (("sync", "agent", 0),
                                 ("async-k2", "async", 2),
                                 ("async-k4", "async", 4)):
        kw = {"staleness": stal} if exchange == "async" else {}
        eng = DistGREEngine(algorithms.bfs_program(), mesh, ("graph",),
                            exchange=exchange, frontier="dense", **kw)
        topo = eng.device_topology(ag)
        state = eng.init_state(ag, source=source)
        fn = eng.make_run(ag, max_steps=max_steps)
        final = jax.block_until_ready(fn(topo, state))  # compile + warm
        out[mode] = (fn, topo, state, int(np.asarray(final.step).max()))
    return out

def race(fns, iters):
    samples = {m: [] for m in fns}
    for _ in range(iters):
        for m, (fn, topo, state, _) in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(topo, state))
            samples[m].append(time.perf_counter() - t0)
    return {m: sorted(s)[len(s) // 2] * 1e6 for m, s in samples.items()}

# ---- regime 1: skewed ghost-chord ring, contiguous equal blocks
g, part, owner, n = ghost_ring(n, k)
fns = modes_for(g, part, n + 16 * k + 64, owner=owner)
us = race(fns, iters)
for m, (_, _, _, nsteps) in fns.items():
    print("RESULT " + json.dumps(
        {"scenario": "skew", "mode": m, "us_per_run": us[m],
         "supersteps": nsteps, "E": g.num_edges}), flush=True)
best = max(us["sync"] / us["async-k2"], us["sync"] / us["async-k4"])
print("RESULT " + json.dumps(
    {"scenario": "skew", "mode": "summary",
     "speedup_k2": us["sync"] / us["async-k2"],
     "speedup_k4": us["sync"] / us["async-k4"],
     "best_speedup": best}), flush=True)

# ---- regime 2 (trend-only): power-law, hash partition, crossing-heavy
gb = barabasi_albert_graph(n_ba, m=4, seed=3).dedup()
fns = modes_for(gb, hash_partition(gb, k), 64 * k)
us = race(fns, iters)
for m, (_, _, _, nsteps) in fns.items():
    print("RESULT " + json.dumps(
        {"scenario": "ba", "mode": m, "us_per_run": us[m],
         "supersteps": nsteps, "E": gb.num_edges}), flush=True)
print("RESULT " + json.dumps(
    {"scenario": "ba", "mode": "summary",
     "speedup_k2": us["sync"] / us["async-k2"],
     "speedup_k4": us["sync"] / us["async-k4"]}), flush=True)
"""


def run(n: int = 2048, k: int = 4, iters: int = 5,
        n_ba: int = 1024, floor: float = 1.3):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT), str(ROOT / "src"), env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-c",
         CHILD % dict(n=n, k=k, iters=iters, n_ba=n_ba)],
        capture_output=True, text=True, timeout=1800, env=env, cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"bench child failed:\n{proc.stderr[-4000:]}")
    rows = [json.loads(line.split(" ", 1)[1])
            for line in proc.stdout.splitlines() if line.startswith("RESULT ")]
    summaries = {r["scenario"]: r for r in rows if r["mode"] == "summary"}
    for r in rows:
        if r["mode"] == "summary":
            continue
        s = summaries[r["scenario"]]
        tag = {"skew": f"skew{n}", "ba": f"ba{n_ba}"}[r["scenario"]]
        derived = f"k={k};supersteps={r['supersteps']}"
        if r["mode"] == "sync":
            derived += (f";speedup_k2={s['speedup_k2']:.2f}"
                        f";speedup_k4={s['speedup_k4']:.2f}")
        emit(f"async_{r['mode']}_{tag}_k{k}", r["us_per_run"], derived,
             edges=r["E"] * r["supersteps"], gate=False)
    best = summaries["skew"]["best_speedup"]
    # the tentpole's payoff floor: on the skew-imbalanced low-crossing
    # scenario the flush amortization must show up as wall clock
    assert best >= floor, (
        f"async best speedup {best:.2f}x < {floor}x on the skewed "
        f"ghost-chord ring scenario")
    return summaries


def main():
    run()


if __name__ == "__main__":
    main()
