"""CI perf regression gate: compare a fresh BENCH_ci.json against the
committed BENCH_baseline.json.

  python benchmarks/compare.py BENCH_baseline.json BENCH_ci.json \
      [--threshold 1.5] [--margin 1.25] [--floor 1.25] [--cap 2.5] \
      [--min-us 5000] [--only PREFIX ...] [--skip PREFIX ...]

``--only``/``--skip`` (repeatable name PREFIXES) subset BOTH files before
any comparison — shared set, missing-entry check, gating, and the
machine-speed normalization all see only the selected entries.  CI jobs
that produce disjoint slices of the artifact gate their own slice without
tripping the missing-entry check for the rest: the main bench job runs
``--skip serving_`` and the serving job runs ``--only serving_`` against
the same committed baseline.

Fails (exit 1) when any benchmark present in BOTH files regressed past
its PER-ENTRY margin in MACHINE-NORMALIZED us_per_call: every ratio is
divided by the median ratio across shared benchmarks before gating.
Shared CI runners vary in absolute speed — and differ from whatever
machine produced the committed baseline — so a uniform 1.4× slowdown is
machine drift, not a regression; a single benchmark regressing relative
to the rest of the suite (the compact path silently falling back to dense
scans, an accidentally quadratic exchange) still sticks out.  Raw ratios
are printed for trend reading.

The per-entry margin comes from the baseline's own measured dispersion
instead of one hand-picked headroom: ``benchmarks.common.time_fn``
records each entry's max/median ratio across its timed iterations as
``"noise"`` in BENCH_baseline.json, and an entry's threshold is
``clamp(noise x --margin, --floor, --cap)`` — a rock-steady kernel
microbenchmark (noise ~1.02) gates at the 1.25x floor, a
scheduler-bimodal end-to-end run (noise ~1.8) gets the headroom its own
history proves it needs, and ``--cap`` stops a pathologically noisy
baseline from disabling its gate entirely.  Entries with no recorded
noise fall back to the uniform ``--threshold``.

Entries whose baseline is under ``--min-us`` are reported but never gate
(sub-millisecond timings are runner noise), as are entries whose baseline
record carries ``"gate": false`` (benchmarks whose absolute time is
scheduler-dominated opt out at emit time — see ``benchmarks.common.emit``
— but stay in the artifact for trend reading).  Benchmarks only in the
current run are listed as added, never fatal; benchmarks present in the
baseline but MISSING from the current run FAIL regardless of gating — a
dropped benchmark would otherwise hide exactly the property it was
recording.  Intentional removals ship with a baseline refresh: commit a
trusted main-branch BENCH_ci.json artifact as BENCH_baseline.json.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys


def load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: r for r in data.get("results", [])}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fallback margin for entries with no recorded "
                         "noise: fail when the machine-normalized "
                         "current/baseline ratio exceeds this")
    ap.add_argument("--margin", type=float, default=1.25,
                    help="per-entry margin = recorded noise x this")
    ap.add_argument("--floor", type=float, default=1.25,
                    help="minimum per-entry margin (quiet entries still "
                         "get this much headroom)")
    ap.add_argument("--cap", type=float, default=2.5,
                    help="maximum per-entry margin (a noisy baseline "
                         "cannot disable its own gate)")
    ap.add_argument("--min-us", type=float, default=5000.0,
                    help="baselines under this never gate (noise floor)")
    ap.add_argument("--only", action="append", default=[], metavar="PREFIX",
                    help="compare only entries whose name starts with this "
                         "prefix (repeatable; applied to both files)")
    ap.add_argument("--skip", action="append", default=[], metavar="PREFIX",
                    help="drop entries whose name starts with this prefix "
                         "from both files before comparing (repeatable)")
    args = ap.parse_args(argv)

    def selected(name: str) -> bool:
        if args.only and not any(name.startswith(p) for p in args.only):
            return False
        return not any(name.startswith(p) for p in args.skip)

    base, cur = load(args.baseline), load(args.current)
    base = {n: r for n, r in base.items() if selected(n)}
    cur = {n: r for n, r in cur.items() if selected(n)}
    shared = sorted(set(base) & set(cur))
    ratios = {n: cur[n]["us_per_call"] / max(base[n]["us_per_call"], 1e-9)
              for n in shared}
    # machine-speed factor: median ratio over the gated (above-noise-floor)
    # benchmarks only — sub-floor micro-benchmark jitter must not shift the
    # normalization that gates everything else; needs a few samples to be
    # meaningful, otherwise gate on raw ratios
    def gates(rec):
        return rec["us_per_call"] >= args.min_us and rec.get("gate", True)

    def entry_threshold(rec):
        noise = rec.get("noise")
        if noise is None:
            return args.threshold
        return max(args.floor, min(args.cap, noise * args.margin))

    solid = [r for n, r in ratios.items() if gates(base[n])]
    speed = statistics.median(solid) if len(solid) >= 3 else 1.0
    regressions, missing, rows = [], [], []
    for name in sorted(set(base) | set(cur)):
        b, c = base.get(name), cur.get(name)
        if b is None:
            rows.append(f"  + {name}: new benchmark ({c['us_per_call']:.0f} us)")
            continue
        if c is None:
            rows.append(f"  - {name}: MISSING from current run")
            missing.append(name)
            continue
        ratio = ratios[name]
        norm = ratio / speed
        gated = gates(b)
        limit = entry_threshold(b)
        flag = ""
        if norm > limit:
            flag = " REGRESSION" if gated else " (regressed, ungated)"
            if gated:
                regressions.append(name)
        rows.append(f"    {name}: {b['us_per_call']:.0f} -> "
                    f"{c['us_per_call']:.0f} us ({ratio:.2f}x raw, "
                    f"{norm:.2f}x normalized, limit {limit:.2f}x){flag}")
    print(f"perf gate: noise-margin x{args.margin} "
          f"(floor {args.floor}x, cap {args.cap}x, "
          f"fallback {args.threshold}x normalized), "
          f"noise floor {args.min_us:.0f} us, "
          f"machine-speed factor {speed:.2f}x")
    print("\n".join(rows))
    if missing:
        print(f"\nFAIL: {len(missing)} baseline benchmark(s) missing from "
              f"the current run: {missing} — a dropped benchmark can't "
              "gate; remove it from BENCH_baseline.json if intentional")
    if regressions:
        print(f"\nFAIL: {len(regressions)} regression(s) past their "
              f"per-entry margin: {regressions}")
    if missing or regressions:
        return 1
    print("\nOK: no gated regressions, no missing benchmarks")
    return 0


if __name__ == "__main__":
    sys.exit(main())
