"""Paper Fig. 10 analog: weak scalability — runtime vs graph size.

Graph500 R-MAT generator with fixed out-degree 16 (as in §7.1.2), CPU-scaled
from 2^10 to 2^14 vertices; the paper's claim is close-to-linear runtime
growth, checked via the derived column (us per edge stays ~flat)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import rmat_edges


def main():
    prev = None
    for scale in (10, 11, 12, 13):
        g = rmat_edges(scale=scale, edge_factor=16, seed=0,
                       weights=True).dedup()
        part = DevicePartition.from_graph(g)
        eng = GREEngine(algorithms.pagerank_program())
        step = jax.jit(lambda s: eng.superstep(part, s))
        us = time_fn(step, eng.init_state(part), iters=3)
        per_edge = us / g.num_edges
        growth = "" if prev is None else f";growth={us / prev:.2f}x"
        emit(f"weak_pagerank_rmat{scale}", us,
             f"E={g.num_edges};us_per_edge={per_edge:.4f}{growth}")
        prev = us


if __name__ == "__main__":
    main()
