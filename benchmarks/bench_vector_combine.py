"""Vector-payload aggregation benchmark (the GNN/BC workload family).

Times one engine superstep of `gnn_aggregate_program` — a [E, D] → [V, D]
scatter-combine with D-dimensional feature payloads — through both combine
paths:

  xla    — fused gather → segment-sum (the default hot path);
  pallas — `segment_combine_pallas`: dst-sorted edge blocks reduced by
           block-local one-hot matmuls on the MXU (interpret mode on CPU,
           so the CPU number measures dispatch overhead, not MXU speed).

The D=64 payload is the acceptance shape: engine messages are feature
vectors, scalars are just D=().
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.algorithms import gnn_aggregate_program
from repro.core.engine import DevicePartition, EngineState, GREEngine
from repro.graph.generators import rmat_edges


def _state(part, h):
    v, d = part.num_masters, h.shape[-1]
    sd = jnp.zeros((part.num_slots, d), h.dtype).at[:v].set(h)
    return EngineState(
        vertex_data=jnp.zeros((v, d), h.dtype), scatter_data=sd,
        active_scatter=jnp.ones(part.num_slots, dtype=bool).at[v].set(False),
        step=jnp.zeros((), jnp.int32))


def run(scale: int = 10, edge_factor: int = 8, d_feat: int = 64,
        iters: int = 5, pallas: bool = True):
    g = rmat_edges(scale=scale, edge_factor=edge_factor, seed=0).dedup()
    part = DevicePartition.from_graph(g)
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(g.num_vertices, d_feat)), jnp.float32)
    program = gnn_aggregate_program(d_feat)
    paths = [("xla", GREEngine(program))]
    if pallas:
        paths.append(("pallas", GREEngine(program, use_pallas=True)))
    out = {}
    for name, eng in paths:
        step = jax.jit(lambda s, e=eng: e.superstep(part, s))
        us = time_fn(step, _state(part, h), iters=iters)
        eps = g.num_edges * d_feat / (us / 1e6)
        emit(f"vector_combine_d{d_feat}_rmat{scale}_{name}", us,
             f"V={g.num_vertices};E={g.num_edges};payload_elems_per_s={eps:.3g}",
             edges=g.num_edges)
        out[name] = us
    return out


def main():
    run(scale=10)
    run(scale=12, pallas=False)  # larger graph, XLA path only (CPU interpret
    #                              mode makes Pallas timing meaningless there)


if __name__ == "__main__":
    main()
