"""Pipelined vs synchronous exchange: superstep throughput under remote load.

Races the three Agent-Graph exchange schedules on a multi-shard PageRank
run (dense frontier — every edge active, so the combiner flush carries its
full payload every superstep):

  sync       — AgentExchange: one full-E scatter-combine, then the flush
               collective as a mid-superstep barrier;
  overlap2x  — AgentExchange(overlap=True): the pre-split schedule that
               rewrites `dst` to issue the flush early, at the cost of
               scanning the SAME edge array twice (2·E work);
  pipelined  — PipelinedAgentExchange over the static ingress edge split
               (`agent_graph.split_edge_tiles`) through the plan
               executor's deferred-merge loop (`repro.core.plan`):
               E edge-scans, compact ⊕ segment spaces, flush merged at
               the top of the next superstep.

The graph is hash-partitioned so a large fraction of edges terminate at
combiner agents (reported as `remote_frac`) — the regime the paper's §6.2
overlap targets.  Runs in a subprocess because the multi-device XLA_FLAGS
must be set before jax initializes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit

ROOT = Path(__file__).resolve().parent.parent

CHILD = r"""
import os
# One intra-op thread per simulated device: the k shards then execute truly
# concurrently (multi-threaded eigen oversubscribes small hosts and turns
# the schedule comparison into scheduler noise), which is what makes the
# flush-stall-vs-overlap difference measurable on CPU.
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%(k)d "
                           "--xla_cpu_multi_thread_eigen=false "
                           "intra_op_parallelism_threads=1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import time
import jax

from repro.graph.generators import rmat_edges
from repro.core.partition import hash_partition
from repro.core.agent_graph import build_agent_graph, split_edge_tiles
from repro.core.dist_engine import DistGREEngine
from repro.core import algorithms

scale, k, steps, iters = %(scale)d, %(k)d, %(steps)d, %(iters)d
g = rmat_edges(scale=scale, edge_factor=8, seed=11).dedup()
ag = build_agent_graph(g, hash_partition(g, k), k)
remote_frac = split_edge_tiles(ag).remote_fraction
mesh = jax.make_mesh((k,), ("graph",))

MODES = (("sync", False), ("overlap2x", True), ("pipelined", False))
fns = {}
for mode, overlap in MODES:
    eng = DistGREEngine(algorithms.pagerank_program(), mesh, ("graph",),
                        exchange="pipelined" if mode == "pipelined"
                        else "agent", overlap=overlap)
    topo = eng.device_topology(ag)
    state = eng.init_state(ag)
    fn = eng.make_run(ag, max_steps=steps)
    jax.block_until_ready(fn(topo, state))  # compile + warm
    fns[mode] = (fn, topo, state)

# Interleave measurement rounds across the schedules so machine-load drift
# (shared runners, 2-core laptops hosting k simulated devices) hits every
# mode equally; per-mode median over rounds.
samples = {mode: [] for mode, _ in MODES}
for _ in range(iters):
    for mode, _ in MODES:
        fn, topo, state = fns[mode]
        t0 = time.perf_counter()
        jax.block_until_ready(fn(topo, state))
        samples[mode].append(time.perf_counter() - t0)

# whole-run medians: us_per_call then clears the CI gate's noise floor
# (per-superstep numbers would sit under --min-us and never gate)
us = {m: sorted(s)[len(s) // 2] * 1e6 for m, s in samples.items()}
for mode, _ in MODES:
    print("RESULT " + json.dumps(
        {"mode": mode, "us_per_run": us[mode], "steps": steps,
         "remote_frac": remote_frac, "E": g.num_edges}), flush=True)
print("RESULT " + json.dumps(
    {"mode": "summary",
     "speedup_vs_sync": us["sync"] / us["pipelined"],
     "speedup_vs_overlap": us["overlap2x"] / us["pipelined"]}), flush=True)
"""


def run(scale: int = 12, k: int = 2, steps: int = 24, iters: int = 9):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT), str(ROOT / "src"), env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-c",
         CHILD % dict(scale=scale, k=k, steps=steps, iters=iters)],
        capture_output=True, text=True, timeout=1800, env=env, cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"bench child failed:\n{proc.stderr[-4000:]}")
    rows = [json.loads(line.split(" ", 1)[1])
            for line in proc.stdout.splitlines() if line.startswith("RESULT ")]
    summary = next(r for r in rows if r["mode"] == "summary")
    for r in rows:
        if r["mode"] == "summary":
            continue
        per_step = r["us_per_run"] / r["steps"]
        derived = (f"remote_frac={r['remote_frac']:.2f};k={k};"
                   f"supersteps={r['steps']};us_per_step={per_step:.1f}")
        if r["mode"] == "pipelined":
            derived += (f";speedup_vs_sync={summary['speedup_vs_sync']:.2f}"
                        f";speedup_vs_overlap="
                        f"{summary['speedup_vs_overlap']:.2f}")
        # gate=False: absolute times of k simulated devices on small CI
        # hosts are scheduler-bimodal run to run; the entries trend-track
        # (and fail compare.py if dropped) but don't ratio-gate.  The
        # schedule comparison itself is the interleaved within-run medians
        # in the derived speedups.
        emit(f"exchange_{r['mode']}_rmat{scale}_k{k}",
             r["us_per_run"], derived, edges=r["E"] * r["steps"],
             gate=False)
    return summary


def main():
    run()


if __name__ == "__main__":
    main()
