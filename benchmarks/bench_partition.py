"""Paper Fig. 11 / 12 / 13: Agent-Graph partition quality, plus the
replication-aware streaming partitioner race (docs/partitioning.md).

  Fig. 11a/b — agents per vertex + equivalent edge-cut vs the random-hash
               edge-cut line, across graphs;
  Fig. 12/13 — cut-factor scaling over k=2..16 partitions for a social-like
               (balanced degrees) and a web-like (fan-in) graph, with the
               PowerGraph vertex-cut (2·mirrors/V) comparison and the
               scatter/combiner skew (12b/13b);
  §5.1      — communication: agent messages vs vertex-cut 2R.

GRE-S = exact serial stream (batch 1); GRE-P = parallel loaders (batch 256).
HDRF  = degree-aware streaming placement (`repro.core.partition_stream`):
partial-degree-weighted affinity replicates hubs first, so the combiner
cut — `remote_dst_edge_fraction`, the exchange traffic the runtime pays
per superstep — drops well below the presence-only greedy heuristic on
power-law graphs.  The parent asserts the payoff floor (`RDF_FLOOR`,
default ≥15% lower remote-dst fraction than greedy at the web-like k=16
point) and `run_dist` records the end-to-end effect: the same BFS on a
device mesh moves measurably fewer exchange bytes on the HDRF placement.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

from benchmarks.common import emit
from repro.core.partition import (greedy_partition, hash_edge_cut,
                                  partition_quality)
from repro.core.partition_stream import hdrf_partition
from repro.graph.generators import rmat_edges

ROOT = Path(__file__).resolve().parent.parent

# acceptance floor: HDRF's remote-dst fraction vs greedy at the web-like
# k=16 point (observed ~0.47 hdrf vs ~0.92 greedy — a 46% drop)
RDF_FLOOR = 0.15


def graphs(scale: int = 12):
    social = rmat_edges(scale=scale, edge_factor=16, seed=0).dedup()
    web = rmat_edges(scale=scale, edge_factor=16, seed=1).dedup().reversed()
    return [("social", social), ("web", web)]


def run(scale: int = 12, ks=(4, 8, 16), rdf_floor: float = RDF_FLOOR):
    """Quality + wall-clock rows for greedy (GRE-S/GRE-P) and HDRF; the
    web-like k=16 HDRF-vs-greedy remote-dst fraction is the gate."""
    gated = {}
    for gname, g in graphs(scale):
        for k in ks:
            hline = hash_edge_cut(g, k)
            base_rdf = None
            for mode, batch in (("S", 1), ("P", 256)):
                if batch == 1 and g.num_edges > 40000 and k > 4:
                    continue  # exact stream is slow; sample one point
                t0 = time.time()
                part = greedy_partition(g, k, batch_size=batch)
                us = (time.time() - t0) * 1e6
                q = partition_quality(g, part)
                if mode == "P":
                    base_rdf = q.remote_dst_edge_fraction
                emit(f"partition_{gname}_k{k}_GRE-{mode}", us,
                     f"agents_per_vertex={q.agents_per_vertex:.3f};"
                     f"equiv_edge_cut={q.equivalent_edge_cut:.3f};"
                     f"hash_cut={hline:.3f};"
                     f"improvement={hline / max(q.equivalent_edge_cut, 1e-9):.2f}x;"
                     f"scatter_rate={q.scatter_rate:.2f};"
                     f"cut_factor={q.agents_per_vertex:.3f};"
                     f"vertexcut_factor={q.vertexcut_cut_factor:.3f};"
                     f"agent_comm={q.agent_comm};"
                     f"vertexcut_comm={q.vertexcut_comm};"
                     f"remote_dst={q.remote_dst_edge_fraction:.4f};"
                     f"repl_factor={q.replication_factor:.3f};"
                     f"balance={q.edge_balance:.3f}")
            stats = {}
            t0 = time.time()
            part = hdrf_partition(g, k, stats=stats)
            us = (time.time() - t0) * 1e6
            q = partition_quality(
                g, part, partitioner_state_bytes=stats["state_bytes"])
            rdf_drop = (1.0 - q.remote_dst_edge_fraction / max(base_rdf, 1e-9)
                        if base_rdf else 0.0)
            emit(f"partition_{gname}_k{k}_HDRF", us,
                 f"remote_dst={q.remote_dst_edge_fraction:.4f};"
                 f"repl_factor={q.replication_factor:.3f};"
                 f"agent_comm={q.agent_comm};"
                 f"balance={q.edge_balance:.3f};"
                 f"state_bytes={stats['state_bytes']};"
                 f"rdf_vs_greedy={-rdf_drop * 100:+.1f}%")
            if gname == "web" and base_rdf:
                gated[k] = (q.remote_dst_edge_fraction, base_rdf, rdf_drop)
    k_gate = max(gated) if gated else None
    if k_gate is not None:
        hdrf_rdf, greedy_rdf, drop = gated[k_gate]
        assert drop >= rdf_floor, (
            f"HDRF remote_dst_edge_fraction {hdrf_rdf:.4f} is only "
            f"{drop * 100:.1f}% below greedy's {greedy_rdf:.4f} at the "
            f"web-like k={k_gate} point (need >= {rdf_floor * 100:.0f}%)")
    return gated


DIST_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%(k)d "
                           "--xla_cpu_multi_thread_eigen=false "
                           "intra_op_parallelism_threads=1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import time
import numpy as np
import jax

from repro.graph.generators import rmat_edges
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core import algorithms

scale, k, iters = %(scale)d, %(k)d, %(iters)d
g = rmat_edges(scale=scale, edge_factor=16, seed=1).dedup().reversed()
mesh = jax.make_mesh((k,), ("graph",))

runs = {}
for name in ("greedy", "hdrf"):
    ag = build_agent_graph(g, name, k)
    # per-superstep exchange traffic of this placement: one f32 payload per
    # live combiner flush + scatter refresh message (the padded collective
    # buffers are the static upper bound the mesh actually allocates)
    msgs = int(np.sum(ag.num_combiner) + np.sum(ag.num_scatter))
    padded = 2 * k * k * (ag.c_x_pad + ag.s_x_pad) * 4
    eng = DistGREEngine(algorithms.bfs_program(), mesh, ("graph",),
                        exchange="agent", frontier="dense")
    topo = eng.device_topology(ag)
    state = eng.init_state(ag, source=0)
    fn = eng.make_run(ag, max_steps=64)
    final = jax.block_until_ready(fn(topo, state))  # compile + warm
    steps = int(np.asarray(final.step).max())
    runs[name] = (fn, topo, state, steps, msgs, padded)

samples = {m: [] for m in runs}
for _ in range(iters):
    for m, (fn, topo, state, *_ ) in runs.items():
        t0 = time.perf_counter()
        jax.block_until_ready(fn(topo, state))
        samples[m].append(time.perf_counter() - t0)
for m, (fn, topo, state, steps, msgs, padded) in runs.items():
    us = sorted(samples[m])[len(samples[m]) // 2] * 1e6
    print("RESULT " + json.dumps(
        {"mode": m, "us_per_run": us, "supersteps": steps,
         "exchange_msgs_per_step": msgs, "exchange_bytes_per_step": 4 * msgs,
         "padded_exchange_bytes": padded, "E": g.num_edges}), flush=True)
"""


def run_dist(scale: int = 10, k: int = 4, iters: int = 5):
    """End-to-end distributed BFS, greedy vs HDRF placement of the SAME
    web-like graph on the same mesh: fewer combiner/scatter agents means
    fewer exchange messages per superstep (emitted as
    `exchange_bytes_per_step`; the parent asserts the HDRF reduction) and
    the wall-clock rows record what that buys (`gate=False` — simulated
    devices on shared CI hosts are scheduler-bimodal; the within-run
    comparison is the signal)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT), str(ROOT / "src"), env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-c", DIST_CHILD % dict(scale=scale, k=k,
                                                 iters=iters)],
        capture_output=True, text=True, timeout=1800, env=env, cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"bench child failed:\n{proc.stderr[-4000:]}")
    rows = {r["mode"]: r for r in
            (json.loads(line.split(" ", 1)[1])
             for line in proc.stdout.splitlines()
             if line.startswith("RESULT "))}
    g_row, h_row = rows["greedy"], rows["hdrf"]
    for name, r in rows.items():
        other = h_row if name == "greedy" else g_row
        emit(f"partition_dist_bfs_{name}_k{k}", r["us_per_run"],
             f"supersteps={r['supersteps']};"
             f"exchange_bytes_per_step={r['exchange_bytes_per_step']};"
             f"padded_exchange_bytes={r['padded_exchange_bytes']};"
             f"vs_other={r['exchange_bytes_per_step'] / max(other['exchange_bytes_per_step'], 1):.2f}x",
             edges=r["E"] * max(r["supersteps"], 1), gate=False)
    assert (h_row["exchange_bytes_per_step"]
            < g_row["exchange_bytes_per_step"]), (
        f"HDRF moved {h_row['exchange_bytes_per_step']} exchange B/step vs "
        f"greedy's {g_row['exchange_bytes_per_step']} — no reduction")
    return rows


def main():
    run()
    run_dist()


if __name__ == "__main__":
    main()
