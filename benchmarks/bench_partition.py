"""Paper Fig. 11 / 12 / 13: Agent-Graph partition quality.

  Fig. 11a/b — agents per vertex + equivalent edge-cut vs the random-hash
               edge-cut line, across graphs;
  Fig. 12/13 — cut-factor scaling over k=2..16 partitions for a social-like
               (balanced degrees) and a web-like (fan-in) graph, with the
               PowerGraph vertex-cut (2·mirrors/V) comparison and the
               scatter/combiner skew (12b/13b);
  §5.1      — communication: agent messages vs vertex-cut 2R.

GRE-S = exact serial stream (batch 1); GRE-P = parallel loaders (batch 256).
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.partition import (greedy_partition, hash_edge_cut,
                                  partition_quality)
from repro.graph.generators import rmat_edges


def graphs():
    social = rmat_edges(scale=12, edge_factor=16, seed=0).dedup()
    web = rmat_edges(scale=12, edge_factor=16, seed=1).dedup().reversed()
    return [("social", social), ("web", web)]


def main():
    for gname, g in graphs():
        for k in (4, 8, 16):
            hline = hash_edge_cut(g, k)
            for mode, batch in (("S", 1), ("P", 256)):
                if batch == 1 and g.num_edges > 40000 and k > 4:
                    continue  # exact stream is slow; sample one point
                t0 = time.time()
                part = greedy_partition(g, k, batch_size=batch)
                us = (time.time() - t0) * 1e6
                q = partition_quality(g, part)
                emit(f"partition_{gname}_k{k}_GRE-{mode}", us,
                     f"agents_per_vertex={q.agents_per_vertex:.3f};"
                     f"equiv_edge_cut={q.equivalent_edge_cut:.3f};"
                     f"hash_cut={hline:.3f};"
                     f"improvement={hline / max(q.equivalent_edge_cut, 1e-9):.2f}x;"
                     f"scatter_rate={q.scatter_rate:.2f};"
                     f"cut_factor={q.agents_per_vertex:.3f};"
                     f"vertexcut_factor={q.vertexcut_cut_factor:.3f};"
                     f"agent_comm={q.agent_comm};"
                     f"vertexcut_comm={q.vertexcut_comm};"
                     f"balance={q.edge_balance:.3f}")


if __name__ == "__main__":
    main()
