"""Plan autotuner payoff: tuned `SuperstepPlan` vs the engine's defaults.

Two smoke-scale scenarios, both measured END-TO-END (full BFS to
quiescence, not isolated supersteps), because the autotuner's claim is
about whole-run plans:

* **circulant** — the sparse-frontier case where the default capacity
  heuristic (`num_slots / 16` without a probe histogram) over-allocates
  the compacted tile by ~an order of magnitude: the tuner's measured
  capacity axis (anchored on the probe frontier histogram) is where the
  speedup lives.  Acceptance: tuned >= 1.2x faster than the default
  plan.
* **power-law (Barabási–Albert)** — the case the defaults already
  handle well (frontier="auto" statically picks bucketed tiles, PR 4):
  the tuner must NOT lose.  The default plan is seeded into the search's
  final rung (`search.tune`), so the winner is never slower at probe
  time; this benchmark re-verifies the claim on an independent
  end-to-end measurement.  Acceptance: tuned <= 1.1x default (noise
  margin).

The search runs against a throwaway plan cache (each invocation is a
fresh tune — the cache-hit path is covered by tests/test_tuning.py) and
the tuned engine is built the way users build it: partition rebuilt for
the winner's bucket ladder, `GREEngine(prog, plan=winner)`.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import jax

from benchmarks.common import TimedUs, emit
from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import barabasi_albert_graph, circulant_graph
from repro.tuning import PlanSearchSpace, tune

# Small measured space for the bench: capacity is the axis that pays on
# these scenarios; one bucket ladder, XLA kernels (Pallas interpret-mode
# timings on CPU would drown the end-to-end signal).
BENCH_SPACE = PlanSearchSpace(
    strategies=("dense", "flat", "compact"),
    cap_multipliers=(0.5, 1.0, 2.0),
    bucket_bounds=(None,),
)


def _make_run(prog, g, plan, source, max_steps):
    """Jitted full-run thunk for one plan (None = engine defaults), on a
    partition built for that plan's bucket ladder."""
    if plan is None:
        eng = GREEngine(prog)
        part = DevicePartition.from_graph(g)
    else:
        eng = GREEngine(prog, plan=plan)
        part = DevicePartition.from_graph(g,
                                          bucket_bounds=plan.bucket_bounds)
    run_fn = jax.jit(lambda s: eng.run(part, s, max_steps))
    st = eng.init_state(part, source=source)
    return lambda: run_fn(st)


def _interleaved(thunks, iters):
    """Median us per thunk over rounds that alternate between them, so
    machine-load drift hits every plan equally (the same discipline as
    bench_exchange_overlap); dispersion rides along as `.noise`."""
    for fn in thunks.values():
        jax.block_until_ready(fn())  # compile + warm
    times = {k: [] for k in thunks}
    for _ in range(iters):
        for k, fn in thunks.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times[k].append(time.perf_counter() - t0)
    out = {}
    for k, ts in times.items():
        ts.sort()
        med = ts[len(ts) // 2]
        out[k] = TimedUs(med * 1e6, ts[-1] / max(med, 1e-12))
    return out


def _tuned_vs_default(name, prog, g, source, max_steps, iters, rungs,
                      num_edges):
    with tempfile.TemporaryDirectory() as td:
        res = tune(prog, g, source=source,
                   cache=Path(td) / "plans.json", space=BENCH_SPACE,
                   rungs=rungs)
    us = _interleaved(
        {"default": _make_run(prog, g, None, source, max_steps),
         "tuned": _make_run(prog, g, res.plan, source, max_steps)},
        iters)
    p = res.plan
    common = (f"plan={p.strategy}/cap={p.frontier_cap}/"
              f"bounds={p.bucket_bounds};probes={res.num_probes};"
              f"probe_us={res.probe_us:.0f};key={res.key}")
    emit(f"bfs_default_{name}", us["default"], common, edges=num_edges)
    emit(f"bfs_tuned_{name}", us["tuned"],
         f"{common};speedup_vs_default={us['default'] / us['tuned']:.2f}",
         edges=num_edges)
    return us


def run(scale: int = 12, degree: int = 16, iters: int = 3):
    """Circulant BFS: the tuner must beat the default plan >= 1.2x."""
    n = 1 << scale
    g = circulant_graph(n, degree=degree)
    max_steps = 2 * n // degree + 32
    us = _tuned_vs_default(f"circulant{scale}", algorithms.bfs_program(),
                           g, 0, max_steps, iters,
                           rungs=((2, 1), (max_steps, 2)),
                           num_edges=g.num_edges)
    speedup = us["default"] / us["tuned"]
    assert speedup >= 1.2, \
        (f"tuned plan only {speedup:.2f}x vs default on the circulant "
         f"sparse-frontier scenario (want >= 1.2x)")
    return us


def run_powerlaw(scale: int = 11, m: int = 8, iters: int = 3):
    """BA-graph BFS: the defaults are already good — the tuner must not
    lose (default plan is seeded into the final halving rung)."""
    n = 1 << scale
    g = barabasi_albert_graph(n, m=m, seed=0).dedup()
    us = _tuned_vs_default(f"ba{scale}", algorithms.bfs_program(), g, 0,
                           64, iters, rungs=((2, 1), (64, 3)),
                           num_edges=g.num_edges)
    assert us["tuned"] <= us["default"] * 1.1, \
        (f"tuned {us['tuned']:.0f}us slower than default "
         f"{us['default']:.0f}us on the power-law scenario")
    return us


def main():
    run(12)
    run_powerlaw(11)


if __name__ == "__main__":
    main()
