"""Serving gate: continuous lane batching vs re-init-per-batch.

A mixed short/long query stream over a two-component graph:

  component A — R-MAT power-law: BFS from any root converges in a handful
      of supersteps (the short, common queries);
  component B — a sparse circulant ring whose eccentricity is ~n/4
      supersteps (the long tail).

Re-init-per-batch — the static multi-source batching the engine already
had — pays the SLOWEST lane's supersteps for every batch: one long query
pins all D lanes for the ring's full diameter.  Continuous batching
(`repro.serving.GraphQueryBatcher`) retires each lane as ITS query
converges and admits the next from the queue, so short queries stream
through the lanes a long query is not using.  The gate asserts the
queries/s win is >= 1.5x (the measured margin on this stream shape is
~2-4x) and records per-query latency percentiles from the scheduler's
SLO metrics.

Standalone CI entry (the `serving` job):

  python -m benchmarks.bench_serving --smoke --json BENCH_serving.json
"""
from __future__ import annotations

import json
import platform
import sys

import numpy as np

from benchmarks.common import RESULTS, TimedUs, emit, time_fn
from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import circulant_graph, rmat_edges
from repro.graph.structures import Graph
from repro.serving import GraphQueryBatcher


def _two_component_graph(scale: int, ring: int):
    """R-MAT component on vertices [0, nA) + circulant ring on [nA, nA+ring)
    in ONE graph: same partition, radically different query depths."""
    a = rmat_edges(scale=scale, edge_factor=8, seed=7).dedup()
    b = circulant_graph(ring, degree=2, seed=0)
    src = np.concatenate([a.src, b.src + a.num_vertices])
    dst = np.concatenate([a.dst, b.dst + a.num_vertices])
    g = Graph(a.num_vertices + ring,
              src.astype(np.int32), dst.astype(np.int32))
    return g, a.num_vertices


def _stream(num_queries: int, n_short: int, ring: int, long_every: int,
            seed: int = 0):
    """Deterministic mixed stream: every `long_every`-th query roots in the
    ring component (long), the rest in the power-law component (short)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(num_queries):
        if i % long_every == long_every - 1:
            out.append(n_short + int(rng.integers(0, ring)))
        else:
            out.append(int(rng.integers(0, n_short)))
    return out


def run(scale: int = 11, ring: int = 1024, num_queries: int = 48,
        lanes: int = 8, steps_per_tick: int = 4, long_every: int = 5,
        iters: int = 3, min_speedup: float = 1.5):
    g, n_short = _two_component_graph(scale, ring)
    part = DevicePartition.from_graph(g)
    sources = _stream(num_queries, n_short, ring, long_every)
    program = algorithms.bfs_program(lanes)

    # --- continuous batching: one resident batcher, lanes recycle.  A
    # drained batcher is reusable (admission fully resets a lane), so the
    # timed unit re-submits the same stream without re-jitting anything.
    eng = GREEngine(program)
    batcher = GraphQueryBatcher(eng, part, steps_per_tick=steps_per_tick)

    def continuous_once():
        for s in sources:
            batcher.submit(s)
        done = batcher.run()
        assert len(done) == num_queries
        return done

    cont_us = time_fn(continuous_once, warmup=1, iters=iters)
    m = batcher.metrics()

    # --- baseline: static multi-source batches of `lanes`, re-initialized
    # per batch, each run until its SLOWEST lane converges.
    eng_b = GREEngine(program)
    max_steps = ring // 2 + 16

    def batched_once():
        outs = []
        for i in range(0, num_queries, lanes):
            batch = sources[i:i + lanes]
            batch = batch + [None] * (lanes - len(batch))
            st = eng_b.init_state(part, source=batch)
            outs.append(eng_b.run(part, st, max_steps=max_steps))
        return outs[-1].vertex_data.block_until_ready()

    batch_us = time_fn(batched_once, warmup=1, iters=iters)

    per_q_cont = TimedUs(cont_us / num_queries, cont_us.noise)
    per_q_batch = TimedUs(batch_us / num_queries, batch_us.noise)
    speedup = float(batch_us) / float(cont_us)
    qps_cont = num_queries / (cont_us / 1e6)
    qps_batch = num_queries / (batch_us / 1e6)
    emit(f"serving_continuous_mixed_s{scale}", per_q_cont,
         f"qps={qps_cont:.1f};p50_ms={m['latency_p50_s'] * 1e3:.1f};"
         f"p95_ms={m['latency_p95_s'] * 1e3:.1f};"
         f"occupancy={m['lane_occupancy']:.2f};speedup={speedup:.2f}")
    emit(f"serving_batched_mixed_s{scale}", per_q_batch,
         f"qps={qps_batch:.1f};Q={num_queries};D={lanes}")
    assert speedup >= min_speedup, (
        f"continuous batching {speedup:.2f}x < required {min_speedup}x "
        f"queries/s over re-init-per-batch")
    return speedup


def main():
    run()


def _standalone(argv) -> int:
    smoke = "--smoke" in argv
    json_path = None
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    print("name,us_per_call,derived")
    if smoke:
        run(scale=9, ring=512, num_queries=32, iters=3)
    else:
        run()
    if json_path:
        payload = {"mode": "smoke" if smoke else "full",
                   "python": platform.python_version(),
                   "machine": platform.machine(),
                   "results": RESULTS}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {len(RESULTS)} results to {json_path}",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(_standalone(sys.argv[1:]))
