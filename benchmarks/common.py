"""Shared benchmark utilities.

Every `emit` also records a machine-readable result into `RESULTS`
(`benchmarks/run.py --json` dumps them as the CI perf artifact); passing
`edges=` adds the cross-benchmark comparable ns/edge number.

Timing is the tuner's probe harness (`repro.tuning.evaluator.measure`) —
one clock discipline for autotuner probes and bench-gate numbers — and
`time_fn` results carry the run's max/median dispersion as `.noise`, so
the artifact records how repeatable each entry was ON THE MACHINE THAT
PRODUCED IT.  `compare.py` turns the baseline's recorded dispersion into
a per-entry regression margin instead of one hand-picked headroom.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.tuning.evaluator import measure

# Machine-readable results accumulated across one benchmark run
# (list of dicts: name, us_per_call, optional ns_per_edge/noise, derived).
RESULTS: list = []


class TimedUs(float):
    """A microseconds median that remembers its dispersion.  Behaves as a
    plain float everywhere (ratios, formatting, min/max) so benchmark
    arithmetic is unchanged; `emit` reads `.noise` off it to record the
    per-entry repeatability without every call site threading a second
    value."""

    noise: float

    def __new__(cls, us: float, noise: float = 1.0):
        obj = super().__new__(cls, us)
        obj.noise = noise
        return obj


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> TimedUs:
    """Median wall time per call in microseconds (blocking on outputs),
    with the max/median dispersion across the timed iterations attached
    as `.noise`."""
    m = measure(fn, *args, warmup=warmup, iters=iters)
    return TimedUs(m.us, m.noise)


def emit(name: str, us: float, derived: str = "",
         edges: Optional[int] = None, gate: bool = True,
         noise: Optional[float] = None):
    """`gate=False` marks entries whose ABSOLUTE time is scheduler-dominated
    (e.g. multi-device runs on oversubscribed CI hosts): they stay in the
    artifact for trend reading and still fail `compare.py` when missing,
    but are exempt from the regression ratio gate.

    `noise` (defaulting to the `.noise` a `time_fn` result carries)
    records the entry's repeated-run dispersion; committed into
    BENCH_baseline.json it becomes that entry's regression margin."""
    rec = {"name": name, "us_per_call": round(us, 3)}
    if edges:
        rec["ns_per_edge"] = round(us * 1e3 / edges, 6)
    if derived:
        rec["derived"] = derived
    if not gate:
        rec["gate"] = False
    if noise is None:
        noise = getattr(us, "noise", None)
    if noise is not None:
        rec["noise"] = round(float(noise), 3)
    RESULTS.append(rec)
    print(f"{name},{us:.1f},{derived}", flush=True)
