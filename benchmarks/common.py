"""Shared benchmark utilities.

Every `emit` also records a machine-readable result into `RESULTS`
(`benchmarks/run.py --json` dumps them as the CI perf artifact); passing
`edges=` adds the cross-benchmark comparable ns/edge number.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

# Machine-readable results accumulated across one benchmark run
# (list of dicts: name, us_per_call, optional ns_per_edge, derived).
RESULTS: list = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "",
         edges: Optional[int] = None, gate: bool = True):
    """`gate=False` marks entries whose ABSOLUTE time is scheduler-dominated
    (e.g. multi-device runs on oversubscribed CI hosts): they stay in the
    artifact for trend reading and still fail `compare.py` when missing,
    but are exempt from the regression ratio gate."""
    rec = {"name": name, "us_per_call": round(us, 3)}
    if edges:
        rec["ns_per_edge"] = round(us * 1e3 / edges, 6)
    if derived:
        rec["derived"] = derived
    if not gate:
        rec["gate"] = False
    RESULTS.append(rec)
    print(f"{name},{us:.1f},{derived}", flush=True)
