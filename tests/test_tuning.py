"""Plan autotuner (repro.tuning): search-space validity, fingerprint
quantization, cache semantics, and tuner determinism.

The measured half is substituted with deterministic fake evaluators
(`tune(..., evaluator=...)` injection point): a FIXED cost function makes
the winner a pure function of the space enumeration order, so these
tests pin the search's control flow — rung culling, default-plan
seeding, (us, index) tie-breaking, and the cache-hit short-circuit that
must run ZERO probes — without ever trusting wall clocks.
"""
import json

import numpy as np
import pytest

from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.core.plan import KernelPlan, SuperstepPlan
from repro.graph.generators import circulant_graph
from repro.tuning import (PlanCache, PlanSearchSpace, ProbeEvaluator,
                          SMOKE_SPACE, graph_fingerprint, plan_cache_key,
                          program_fingerprint, successive_halving, tune)


# ------------------------------------------------------------ fake evaluators
class CostModelEvaluator(ProbeEvaluator):
    """Deterministic cost: distance of the plan's capacity from a sweet
    spot, dense heavily penalized — no clocks, winner is reproducible."""

    SWEET = 64

    def evaluate(self, plan, probe_steps=2, iters=1):
        self.num_probes += 1
        if plan.strategy == "dense":
            return 1e6
        cap = plan.frontier_cap or 10 ** 4
        return 1000.0 + abs(cap - self.SWEET)


class ExplodingEvaluator(ProbeEvaluator):
    """Any probe execution is a test failure (the cache-hit contract)."""

    def evaluate(self, plan, probe_steps=2, iters=1):
        raise AssertionError("cache hit must not execute probes")


@pytest.fixture
def scenario():
    g = circulant_graph(1 << 9, degree=8)
    return algorithms.bfs_program(), g


# ------------------------------------------------------- space enumeration
def test_space_prunes_dense_duplicates():
    """Dense ignores caps and bounds: ONE candidate per (phase, kernel),
    not a cap x bounds grid of identical compiled programs."""
    space = PlanSearchSpace()
    cands = space.candidates(num_slots=4096, base_cap=64)
    dense = [p for p in cands if p.strategy == "dense"]
    assert len(dense) == 1
    assert dense[0].frontier_cap is None and dense[0].bucket_bounds is None


def test_space_flat_ignores_bucket_bounds():
    cands = PlanSearchSpace().candidates(num_slots=4096, base_cap=64)
    assert all(p.bucket_bounds is None for p in cands
               if p.strategy == "flat")
    # compact DOES sweep the ladders
    compact_bounds = {p.bucket_bounds for p in cands
                      if p.strategy == "compact"}
    assert len(compact_bounds) == len(PlanSearchSpace().bucket_bounds)


def test_space_caps_clamped_and_deduped():
    """Capacities never exceed num_slots, and multipliers that collide
    after clamping/rounding produce ONE candidate."""
    cands = PlanSearchSpace(
        cap_multipliers=(1.0, 2.0, 100.0, 200.0),
        bucket_bounds=(None,)).candidates(num_slots=256, base_cap=64)
    flat_caps = sorted(p.frontier_cap for p in cands
                       if p.strategy == "flat")
    assert flat_caps == [64, 128, 256]  # 100x and 200x both clamp to 256
    assert all(c <= 256 for c in flat_caps)


def test_space_pipelined_requires_split_tiles():
    space = PlanSearchSpace(phases=("sync", "pipelined"))
    solo = space.candidates(num_slots=4096, base_cap=64)
    assert all(p.phases == "sync" for p in solo)
    dist = space.candidates(num_slots=4096, base_cap=64,
                            has_split_tiles=True)
    assert any(p.phases == "pipelined" for p in dist)


def test_space_dense_frontier_forces_dense_strategy():
    """Iterative programs (halts=False) never compact — the space must
    not waste probes on strategies their engines cannot take."""
    cands = PlanSearchSpace().candidates(num_slots=4096, base_cap=64,
                                         dense_frontier=True)
    assert cands and all(p.strategy == "dense" for p in cands)
    assert all(p.dense_frontier for p in cands)


def test_space_async_axis_requires_split_tiles_and_monotone():
    """The staleness axis: async candidates appear once per ring depth in
    `staleness_choices`, and ONLY when the scenario has split edge tiles
    AND a monotone program — bounded staleness corrupts sum-monoid fixed
    points, so the tuner must never even probe them."""
    space = PlanSearchSpace(phases=("sync", "pipelined", "async"),
                            staleness_choices=(2, 4))
    both = space.candidates(num_slots=4096, base_cap=64,
                            has_split_tiles=True, monotone=True)
    depths = {p.staleness for p in both if p.phases == "async"}
    assert depths == {2, 4}
    assert all(p.staleness == 0 for p in both if p.phases != "async")
    # sum-monoid scenario: the async axis vanishes, pipelined survives
    non_mono = space.candidates(num_slots=4096, base_cap=64,
                                has_split_tiles=True, monotone=False)
    assert any(p.phases == "pipelined" for p in non_mono)
    assert all(p.phases != "async" for p in non_mono)
    # single-shard scenario: no split tiles, no async (nor pipelined)
    solo = space.candidates(num_slots=4096, base_cap=64, monotone=True)
    assert all(p.phases == "sync" for p in solo)


def test_space_prunes_noop_kernel():
    """KernelPlan(False, False) is not a real route (the dynamic-table
    bit only exists on the Pallas path)."""
    space = PlanSearchSpace(kernels=(KernelPlan(use_pallas=False,
                                                dynamic_table=False),))
    assert space.candidates(num_slots=4096, base_cap=64) == ()


# ------------------------------------------------------- fingerprint keys
def test_fingerprint_quantizes_size():
    """Graphs within a log2 bin share a key; an order of magnitude apart
    do not."""
    a = graph_fingerprint(10_000, 160_000)
    assert a == graph_fingerprint(10_300, 165_000)  # ~3% larger: same bin
    assert a != graph_fingerprint(100_000, 1_600_000)


def test_fingerprint_skew_and_density_facets():
    uniform = graph_fingerprint(4096, 65536, max_out_degree=16)
    hub = graph_fingerprint(4096, 65536, max_out_degree=4096)
    assert uniform != hub
    sparse = graph_fingerprint(4096, 65536, frontier_hist=[1, 16])
    flood = graph_fingerprint(4096, 65536, frontier_hist=[1, 2000])
    assert sparse != flood
    assert "fd" not in graph_fingerprint(4096, 65536)  # no hist, no facet


def test_program_and_mesh_facets_split_keys(scenario):
    prog, g = scenario
    part = DevicePartition.from_graph(g)
    assert (program_fingerprint(prog)
            != program_fingerprint(algorithms.pagerank_program()))
    k1 = plan_cache_key(part=part, program=prog, mesh_size=1)
    k8 = plan_cache_key(part=part, program=prog, mesh_size=8)
    assert k1 != k8


# ------------------------------------------------------------- plan cache
def test_cache_foreign_version_falls_back_clean(tmp_path):
    """A version-drifted cache file (e.g. a CI artifact restored across a
    schema bump) must degrade to an empty cache — lookups miss (fresh
    search fallback), and the next store rewrites at the current version —
    rather than crash the consumer."""
    path = tmp_path / "plans.json"
    path.write_text(json.dumps(
        {"version": 99, "entries": {"k": {"plan": {"bogus": 1}}}}))
    cache = PlanCache(path)
    with pytest.warns(UserWarning, match="version"):
        assert cache.lookup("k") is None          # stale entry ignored
    cache.store("k2", SuperstepPlan(strategy="flat", frontier_cap=16))
    reread = json.loads(path.read_text())
    assert reread["version"] == 1                 # rewritten at current
    assert list(reread["entries"]) == ["k2"]


def test_cache_store_merges_concurrent_writers(tmp_path):
    """Two caches on one file: the second store must not clobber the
    first writer's entry (re-read + merge before the atomic rewrite)."""
    path = tmp_path / "plans.json"
    a, b = PlanCache(path), PlanCache(path)
    a.store("ka", SuperstepPlan(strategy="flat", frontier_cap=32))
    b.store("kb", SuperstepPlan(strategy="compact", frontier_cap=64))
    fresh = PlanCache(path)
    assert sorted(fresh.keys()) == ["ka", "kb"]
    assert fresh.lookup("ka").frontier_cap == 32


# -------------------------------------------------------- search + tune()
def test_successive_halving_deterministic_tiebreak():
    """Equal measurements resolve by candidate index — first enumerated
    wins, every time."""
    class Flat:
        num_probes = 0

        def evaluate(self, plan, steps, iters):
            return 100.0
    cands = [SuperstepPlan(strategy="flat", frontier_cap=c)
             for c in (8, 16, 32, 64)]
    for _ in range(3):
        best, scores = successive_halving(cands, Flat(),
                                          rungs=((2, 1), (8, 1)))
        assert best == 0


def test_successive_halving_reseeds_must_keep_into_final_rung():
    """A default plan culled by the cheap rung still gets a final-rung
    measurement (the never-slower-than-default guarantee needs it)."""
    class CheapRungLies:
        def __init__(self):
            self.rung_calls = []

        def evaluate(self, plan, steps, iters):
            self.rung_calls.append((plan.frontier_cap, steps))
            # cheap rung: default (cap None -> 0) looks worst; final
            # rung: it is actually best
            cap = plan.frontier_cap or 0
            return (1000 - cap) if steps == 2 else cap + 1
    cands = [SuperstepPlan(strategy="flat", frontier_cap=c)
             for c in (8, 16, 32)] + [SuperstepPlan()]  # default, cap None
    ev = CheapRungLies()
    best, scores = successive_halving(cands, ev, rungs=((2, 1), (8, 1)),
                                      must_keep=(3,))
    assert best == 3  # the re-seeded default won the honest final rung
    assert (None, 8) in ev.rung_calls


def test_tune_fixed_evaluator_stable_winner(scenario, tmp_path):
    """Same scenario, same space, fresh caches, deterministic evaluator:
    identical winner both times."""
    prog, g = scenario
    winners = []
    for i in range(2):
        res = tune(prog, g, cache=tmp_path / f"c{i}.json",
                   space=SMOKE_SPACE,
                   evaluator=CostModelEvaluator(prog, g))
        assert not res.from_cache and res.num_probes > 0
        winners.append(res.plan)
    assert winners[0] == winners[1]
    assert winners[0].strategy != "dense"  # the cost model's 1e6 penalty


def test_tune_cache_hit_runs_zero_probes(scenario, tmp_path):
    prog, g = scenario
    path = tmp_path / "plans.json"
    first = tune(prog, g, cache=path, space=SMOKE_SPACE,
                 evaluator=CostModelEvaluator(prog, g))
    ev = ExplodingEvaluator(prog, g)  # evaluate() raises if ever called
    hit = tune(prog, g, cache=path, space=SMOKE_SPACE, evaluator=ev)
    assert hit.from_cache and hit.num_probes == 0 and ev.num_probes == 0
    assert hit.plan == first.plan and hit.key == first.key
    # force=True re-searches even on a hit
    again = tune(prog, g, cache=path, space=SMOKE_SPACE, force=True,
                 evaluator=CostModelEvaluator(prog, g))
    assert not again.from_cache and again.plan == first.plan


def test_tune_stores_default_measurement(scenario, tmp_path):
    """The cache entry carries its provenance: winner AND default probe
    times plus the space size searched."""
    prog, g = scenario
    res = tune(prog, g, cache=tmp_path / "c.json", space=SMOKE_SPACE,
               evaluator=CostModelEvaluator(prog, g))
    entry = PlanCache(tmp_path / "c.json").entry(res.key)
    assert entry["probe_us"] <= entry["default_us"]
    assert entry["space_size"] > 1


# ------------------------------------------------------ engine integration
def test_engine_auto_tuned_adopts_cached_plan(scenario, tmp_path):
    prog, g = scenario
    path = tmp_path / "plans.json"
    res = tune(prog, g, cache=path, space=SMOKE_SPACE,
               evaluator=CostModelEvaluator(prog, g))
    eng = GREEngine(prog, plan="auto-tuned", plan_cache=path)
    part = DevicePartition.from_graph(g)
    state = eng.init_state(part, source=0)
    assert eng.frontier == res.plan.strategy
    assert eng.frontier_cap == res.plan.frontier_cap
    # adopted plan changes speed, never semantics
    ref = GREEngine(prog).run(part, GREEngine(prog).init_state(
        part, source=0), 200)
    got = eng.run(part, state, 200)
    np.testing.assert_array_equal(np.asarray(got.vertex_data),
                                  np.asarray(ref.vertex_data))


def test_engine_auto_tuned_miss_keeps_defaults(scenario, tmp_path):
    prog, g = scenario
    eng = GREEngine(prog, plan="auto-tuned",
                    plan_cache=tmp_path / "empty.json")
    part = DevicePartition.from_graph(g)
    eng.init_state(part, source=0)
    assert eng.frontier == "auto" and eng.frontier_cap is None
    assert not eng._auto_plan_pending


# ------------------------------------------- mutation: fingerprint refresh
def test_fingerprint_counts_live_edges_not_padded_length():
    """The stale-plan regression (docs/incremental.md): `apply_edge_delta`
    tombstones edges WITHOUT changing the padded column length, so a
    fingerprint keyed on `src.shape[0]` would keep serving the
    pre-mutation plan forever.  Halving the live set at identical padded
    length must change the key."""
    from repro.graph.structures import EdgeDelta
    from repro.tuning import partition_fingerprint
    g = circulant_graph(1 << 9, degree=8)
    part = DevicePartition.from_graph(g)
    rng = np.random.default_rng(0)
    pick = rng.choice(g.num_edges, size=g.num_edges // 2, replace=False)
    half, rep = part.apply_edge_delta(EdgeDelta(
        rem_src=np.asarray(g.src)[pick], rem_dst=np.asarray(g.dst)[pick]))
    assert not rep.compacted
    assert np.asarray(half.src).shape == np.asarray(part.src).shape
    assert partition_fingerprint(half) != partition_fingerprint(part)


def test_refresh_plan_absorbs_small_delta(scenario, tmp_path):
    """log2 quantization means a small churn batch stays in the same
    fingerprint bin: `refresh_plan` reports no key change and the adopted
    plan stands — mutation-heavy serving must not thrash the cache."""
    from repro.graph.structures import EdgeDelta
    prog, g = scenario
    path = tmp_path / "plans.json"
    tune(prog, g, cache=path, space=SMOKE_SPACE,
         evaluator=CostModelEvaluator(prog, g))
    eng = GREEngine(prog, plan="auto-tuned", plan_cache=path)
    part = DevicePartition.from_graph(g)
    eng.init_state(part, source=0)
    adopted = (eng.frontier, eng.frontier_cap)
    rng = np.random.default_rng(1)
    pick = rng.choice(g.num_edges, size=5, replace=False)
    small, _ = part.apply_edge_delta(EdgeDelta(
        rem_src=np.asarray(g.src)[pick], rem_dst=np.asarray(g.dst)[pick]))
    assert eng.refresh_plan(small) is False
    assert (eng.frontier, eng.frontier_cap) == adopted


def test_refresh_plan_rekeys_large_delta_and_adopts(scenario, tmp_path):
    """A delta that shifts a fingerprint bin re-keys the engine and adopts
    whatever the cache holds under the NEW key — the fix for serving a
    plan tuned on a graph that no longer exists."""
    from repro.graph.structures import EdgeDelta
    from repro.tuning import plan_cache_key as key_of
    prog, g = scenario
    path = tmp_path / "plans.json"
    tune(prog, g, cache=path, space=SMOKE_SPACE,
         evaluator=CostModelEvaluator(prog, g))
    eng = GREEngine(prog, plan="auto-tuned", plan_cache=path)
    part = DevicePartition.from_graph(g)
    eng.init_state(part, source=0)
    old_key = eng._plan_key
    assert old_key is not None
    rng = np.random.default_rng(2)
    pick = rng.choice(g.num_edges, size=g.num_edges // 2, replace=False)
    big, _ = part.apply_edge_delta(EdgeDelta(
        rem_src=np.asarray(g.src)[pick], rem_dst=np.asarray(g.dst)[pick]))
    new_key = key_of(part=big, program=prog, mesh_size=1,
                     frontier_hist=eng._plan_hist)
    assert new_key != old_key
    plan2 = SuperstepPlan(strategy="flat", frontier_cap=16)
    PlanCache(path).store(new_key, plan2)
    assert eng.refresh_plan(big) is True
    assert eng._plan_key == new_key
    assert eng.frontier == "flat" and eng.frontier_cap == 16
    # engines that never consulted a cache have nothing to refresh
    plain = GREEngine(prog)
    assert plain.refresh_plan(big) is False


def test_dist_engine_plan_maps_phase_to_exchange(scenario):
    import jax
    from repro.core.dist_engine import DistGREEngine
    prog, _ = scenario
    mesh = jax.make_mesh((1,), ("graph",))
    dist = DistGREEngine(prog, mesh, ("graph",), exchange="pipelined")
    dist.adopt_plan(SuperstepPlan(strategy="flat", frontier_cap=32,
                                  phases="sync"))
    assert dist.exchange == "agent"  # sync plan demotes pipelined
    assert dist.local.frontier_cap == 32
    dist.adopt_plan(SuperstepPlan(phases="pipelined"))
    assert dist.exchange == "pipelined"


def test_dist_engine_auto_tuned_consults_mesh_keyed_cache(scenario,
                                                         tmp_path):
    """The distributed engine resolves plan="auto-tuned" against the
    mesh-size-qualified AgentGraph fingerprint (no frontier-density
    facet — the histogram is a per-shard measurement), and the adopted
    plan never changes results."""
    import jax
    from repro.core.agent_graph import build_agent_graph
    from repro.core.dist_engine import DistGREEngine
    from repro.core.partition import greedy_partition
    from repro.tuning import plan_cache_key as key_of
    prog, g = scenario
    mesh = jax.make_mesh((1,), ("graph",))
    ag = build_agent_graph(g, greedy_partition(g, 1), 1)
    path = tmp_path / "plans.json"
    stored = SuperstepPlan(strategy="flat", frontier_cap=32)
    PlanCache(path).store(key_of(agent_graph=ag, program=prog,
                                 mesh_size=1), stored)
    dist = DistGREEngine(prog, mesh, ("graph",), plan="auto-tuned",
                         plan_cache=path)
    out, _ = dist.run(ag, source=0, max_steps=200)
    assert dist.local.frontier == "flat" and dist.local.frontier_cap == 32
    assert not dist._auto_plan_pending
    ref, _ = DistGREEngine(prog, mesh, ("graph",)).run(ag, source=0,
                                                       max_steps=200)
    np.testing.assert_array_equal(np.nan_to_num(out, posinf=-1.0),
                                  np.nan_to_num(ref, posinf=-1.0))
