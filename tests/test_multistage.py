"""Betweenness Centrality (paper §4.2 multi-stage extension) vs networkx."""
import networkx as nx
import numpy as np

from repro.core.multistage import betweenness_centrality
from repro.graph.generators import erdos_renyi_edges, grid_graph, rmat_edges


def _check(graph, tol=1e-4):
    nxg = nx.DiGraph()
    nxg.add_nodes_from(range(graph.num_vertices))
    nxg.add_edges_from(zip(graph.src.tolist(), graph.dst.tolist()))
    want = nx.betweenness_centrality(nxg, normalized=False)
    got = betweenness_centrality(graph)
    ref = np.array([want[i] for i in range(graph.num_vertices)])
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol)


def test_bc_grid():
    _check(grid_graph(4, 5))


def test_bc_random():
    _check(erdos_renyi_edges(40, 160, seed=1).dedup())


def test_bc_scale_free():
    _check(rmat_edges(scale=6, edge_factor=4, seed=2).dedup())


def test_bc_sampled_is_bounded():
    g = rmat_edges(scale=8, edge_factor=8, seed=0).dedup()
    approx = betweenness_centrality(g, sources=range(0, g.num_vertices, 8))
    assert np.isfinite(approx).all() and (approx >= 0).all()
