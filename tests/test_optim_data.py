"""Optimizer + data pipeline unit tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.tokens import TokenStream
from repro.optim.adamw import AdamW, cosine_warmup, global_norm


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state = opt.update(g, state, params)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_clipping_caps_update_norm():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"x": jnp.full(4, 1e9)}
    new, state = opt.update(huge, state, params)
    assert np.isfinite(np.asarray(new["x"])).all()


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_cosine_warmup_shape():
    s = cosine_warmup(10, 100)
    assert float(s(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)


def test_adamw_dtype_preserved():
    opt = AdamW(lr=1e-2)
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new, state = opt.update(g, state, params)
    assert new["w"].dtype == jnp.bfloat16
    assert state.m["w"].dtype == jnp.float32  # moments stay fp32


# --------------------------------------------------------------------- data
def test_token_stream_rank_slices_compose():
    """World-split batches concatenate to the single-rank global batch —
    the determinism contract used for elastic restart."""
    st = TokenStream(vocab=97, batch=8, seq_len=16, seed=5)
    full = st.batch_at(3)
    parts = [st.batch_at(3, rank=r, world=4) for r in range(4)]
    # each rank's slice is deterministic and reproducible
    again = [st.batch_at(3, rank=r, world=4) for r in range(4)]
    for a, b in zip(parts, again):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert full["tokens"].shape == (8, 16)
    assert parts[0]["tokens"].shape == (2, 16)


def test_token_stream_labels_shifted():
    st = TokenStream(vocab=50, batch=2, seq_len=8, seed=1)
    b = st.batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 8)
    assert b["tokens"].max() < 50 and b["tokens"].min() >= 0
