"""Docs hygiene: the link checker passes on the repo's own docs, and its
failure modes actually fail (dead links, wiki refs, missing repo paths)."""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from tools import check_docs  # noqa: E402


def test_repo_docs_have_no_dead_references(capsys):
    assert check_docs.main(["--root", str(ROOT)]) == 0
    out = capsys.readouterr().out
    assert "0 dead reference(s)" in out


def test_docs_index_exists_and_links_every_doc():
    docs = ROOT / "docs"
    index = (docs / "README.md").read_text()
    for doc in docs.glob("*.md"):
        if doc.name != "README.md":
            assert f"({doc.name})" in index, f"docs/README.md misses {doc.name}"


def _run(tmp_path, text):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "page.md").write_text(text)
    return check_docs.main(["--root", str(tmp_path)])


def test_dead_markdown_link_fails(tmp_path):
    assert _run(tmp_path, "see [other](missing.md)") == 1


def test_anchor_and_external_links_pass(tmp_path):
    assert _run(tmp_path, "[a](#section) [b](https://example.com/x.md) "
                          "[self](page.md)") == 0


def test_unresolved_wiki_ref_fails(tmp_path):
    assert _run(tmp_path, "as described in [[nonexistent-doc]]") == 1


def test_wiki_ref_to_sibling_doc_passes(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "other.md").write_text("hi")
    (tmp_path / "docs" / "page.md").write_text("see [[other]]")
    assert check_docs.main(["--root", str(tmp_path)]) == 0


def test_missing_repo_path_fails(tmp_path):
    assert _run(tmp_path, "the hot loop is `src/made/up/file.py`") == 1


def test_existing_repo_path_passes(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "real.py").write_text("")
    assert _run(tmp_path, "see `src/real.py` (globs like docs/*.md skip)") == 0


def test_empty_docs_dir_fails(tmp_path):
    (tmp_path / "docs").mkdir()
    assert check_docs.main(["--root", str(tmp_path)]) == 1
