"""Replication-aware streaming partitioning + chunked ingress
(docs/partitioning.md).

Covers the three contracts the PR leans on:

  * chunked ingress == monolithic ingress, BITWISE, for every chunk size
    (`build_agent_graph` and `DevicePartition.from_graph` over the
    chunk-source protocol), including a synthetic out-of-core source that
    never materializes the full edge list;
  * HDRF invariants — balance within the cap, replication responding
    monotonically to lambda at the endpoints, determinism under a fixed
    seed, loader state inside the documented O(V·k/8 + V + k) bound;
  * the packed-bitset greedy loader places every edge exactly where the
    old `[k, V]`-bool loader did (including coordinated multi-loader
    merges), and partitioner identity flows into the plan-cache
    fingerprint.

The distributed conformance row (BFS/SSSP on an HDRF placement vs
greedy/hash, through the real mesh exchange) runs in a subprocess — the
multi-device XLA_FLAGS must be set before jax initializes.
"""
import dataclasses
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.agent_graph import build_agent_graph
from repro.core.engine import DevicePartition
from repro.core.partition import (DELTA, greedy_partition,
                                  merge_loader_states, partition_quality)
from repro.core.partition_stream import (PARTITIONERS, bitset_popcount,
                                         bitset_rows, bitset_set,
                                         greedy_state_bytes, hdrf_partition,
                                         hdrf_state_bytes, make_bitset,
                                         partition_edges)
from repro.graph.generators import circulant_graph, rmat_edges
from repro.graph.structures import EdgeChunk, EdgeChunkSource, Graph

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _rmat(scale=9, seed=1, weights=True):
    return rmat_edges(scale=scale, edge_factor=8, seed=seed,
                      weights=weights).dedup()


def _assert_ag_equal(a, b, label=""):
    da, db = dataclasses.asdict(a), dataclasses.asdict(b)
    for name, va in da.items():
        vb = db[name]
        if isinstance(va, dict):
            for pn in va:
                assert np.array_equal(va[pn], vb[pn]), (label, name, pn)
        elif isinstance(va, np.ndarray):
            assert np.array_equal(va, vb), (label, name)
        else:
            assert va == vb, (label, name, va, vb)


# ------------------------------------------------------- packed bitsets
def test_bitset_roundtrip_matches_bool_matrix():
    rng = np.random.default_rng(0)
    rows, bits = 37, 130           # straddles word boundaries
    ref = np.zeros((rows, bits), dtype=bool)
    bs = make_bitset(rows, bits)
    r = rng.integers(0, rows, 500)
    b = rng.integers(0, bits, 500)
    ref[r, b] = True
    bitset_set(bs, r, b)
    probe = rng.integers(0, rows, 64)
    got = bitset_rows(bs, probe, bits)        # [bits, 64]
    assert np.array_equal(got.astype(bool).T, ref[probe])
    assert bitset_popcount(bs) == int(ref.sum())


# ------------------------------------------- packed greedy == bool greedy
def _bool_reference_greedy(graph, k, batch_size, seed):
    """The pre-packing [k, V]-bool loader, verbatim Eq. 8 semantics."""
    V, E = graph.num_vertices, graph.num_edges
    part = np.zeros(E, dtype=np.int32)
    hs = np.zeros((k, V), dtype=bool)
    hd = np.zeros((k, V), dtype=bool)
    ne = np.zeros(k, dtype=np.int64)
    rng = np.random.default_rng(seed)
    for lo in range(0, E, batch_size):
        hi = min(lo + batch_size, E)
        u, v = graph.src[lo:hi], graph.dst[lo:hi]
        f = hs[:, u].astype(np.float64)
        g = hd[:, v].astype(np.float64)
        mx, mn = ne.max(), ne.min()
        score = f + g + ((mx - ne) / (DELTA + mx - mn))[:, None]
        score += rng.random(score.shape) * 1e-9
        idx = np.argmax(score, axis=0).astype(np.int32)
        part[lo:hi] = idx
        hs[idx, u] = True
        hd[idx, v] = True
        np.add.at(ne, idx, 1)
    return part


@pytest.mark.parametrize("k,batch", [(4, 1), (8, 64), (16, 256)])
def test_packed_greedy_matches_bool_reference(k, batch):
    g = _rmat(scale=8, weights=False)
    got = greedy_partition(g, k, batch_size=batch, seed=3)
    ref = _bool_reference_greedy(g, k, batch_size=batch, seed=3)
    assert np.array_equal(got, ref)


def test_packed_merge_matches_bool_merge():
    """merge_loader_states OR-merges packed uint64 states the way it
    OR-merged bool states (and the load-baseline algebra is unchanged)."""
    g = _rmat(scale=8, weights=False)
    p1 = greedy_partition(g, 4, batch_size=32, num_loaders=3, sync_every=2)
    p2 = greedy_partition(g, 4, batch_size=32, num_loaders=3, sync_every=2)
    assert np.array_equal(p1, p2)          # deterministic through merges
    # direct merge algebra on packed rows
    k, words = 4, 8
    sts = [dict(has_src=np.zeros((k, words), np.uint64),
                has_dst=np.zeros((k, words), np.uint64),
                ne=np.arange(k, dtype=np.int64) + 10 * i)
           for i in range(2)]
    sts[0]["has_src"][1, 3] = np.uint64(0b1010)
    sts[1]["has_src"][1, 3] = np.uint64(0b0110)
    merged = merge_loader_states(sts, np.zeros(k, np.int64), 2)
    assert sts[0]["has_src"][1, 3] == np.uint64(0b1110)
    assert np.array_equal(sts[1]["has_src"], sts[0]["has_src"])
    assert np.array_equal(merged, np.arange(k) * 2 + 10)


# -------------------------------------------------------- HDRF invariants
def test_hdrf_deterministic_and_in_range():
    g = _rmat(weights=False)
    k = 8
    a = hdrf_partition(g, k, seed=5)
    b = hdrf_partition(g, k, seed=5)
    assert np.array_equal(a, b)
    assert a.min() >= 0 and a.max() < k
    assert a.shape == (g.num_edges,)


def test_hdrf_balance_within_cap():
    g = _rmat(scale=10, seed=1, weights=False).reversed()
    for k in (4, 16):
        q = partition_quality(g, hdrf_partition(g, k, lam=1.0))
        assert q.edge_balance <= 1.25, (k, q.edge_balance)


def test_hdrf_lambda_endpoints():
    """λ is the replication-vs-balance dial: raising it from the default
    to balance-dominated must increase replication, and turning it on at
    all must improve balance over pure affinity."""
    g = _rmat(scale=10, seed=1, weights=False).reversed()
    k = 8
    reps, bals = {}, {}
    for lam in (0.0, 1.0, 16.0):
        s = {}
        p = hdrf_partition(g, k, lam=lam, stats=s)
        reps[lam] = s["replication"]
        bals[lam] = partition_quality(g, p).edge_balance
    assert reps[16.0] > reps[1.0], reps
    assert bals[1.0] <= bals[0.0], bals


def test_hdrf_state_within_documented_bound():
    g = _rmat(weights=False)
    V = g.num_vertices
    for k in (4, 16, 64):
        s = {}
        hdrf_partition(g, k, stats=s)
        assert s["state_bytes"] == hdrf_state_bytes(V, k)
        # O(V·k/8 + V + k): word granularity costs at most 8 extra B/vertex
        assert s["state_bytes"] <= V * (-(-k // 8) + 8) + 4 * V + 8 * k
        assert s["replication_factor"] == s["replication"] / V
    # packed greedy model vs its measured arrays (2 bitsets + loads)
    words = (V + 63) >> 6
    assert greedy_state_bytes(V, 16) == 2 * 16 * words * 8 + 8 * 16


def test_hdrf_beats_greedy_replication_on_powerlaw():
    """The tentpole's quality claim at test scale: degree-aware placement
    replicates less than presence-only greedy on a fan-in heavy graph."""
    g = _rmat(scale=10, seed=1, weights=False).reversed()
    k = 16
    qh = partition_quality(g, hdrf_partition(g, k))
    qg = partition_quality(g, greedy_partition(g, k, batch_size=256))
    assert qh.replication_factor < qg.replication_factor
    assert qh.remote_dst_edge_fraction < qg.remote_dst_edge_fraction


def test_partition_edges_registry():
    g = _rmat(weights=False)
    for name in PARTITIONERS:
        p = partition_edges(g, 4, method=name)
        assert p.shape == (g.num_edges,)
    with pytest.raises(ValueError, match="unknown partitioner"):
        partition_edges(g, 4, method="metis")


# ------------------------------------------- chunked == monolithic ingress
@pytest.mark.parametrize("maker,partitioner", [
    (lambda: _rmat(scale=9), "greedy"),
    (lambda: _rmat(scale=9), "hdrf"),
    (lambda: circulant_graph(400, degree=8, weights=True), "hdrf"),
])
def test_chunked_build_agent_graph_bitwise(maker, partitioner):
    g = maker()
    k = 4
    part = partition_edges(g, k, method=partitioner)
    mono = build_agent_graph(g, part, k)
    for cs in (1, 97, 1024, g.num_edges, 10 * g.num_edges):
        chunked = build_agent_graph(g.chunk_source(cs), part, k)
        _assert_ag_equal(mono, chunked, f"{partitioner} cs={cs}")


def test_chunked_build_transpose_bitwise():
    g = _rmat(scale=9)
    part = greedy_partition(g, 4, batch_size=64)
    mono = build_agent_graph(g, part, 4, transpose=True)
    chunked = build_agent_graph(g.chunk_source(333), part, 4, transpose=True)
    _assert_ag_equal(mono, chunked, "transpose")


def test_chunked_device_partition_bitwise():
    g = circulant_graph(300, degree=6, weights=True)
    base = DevicePartition.from_graph(g, edge_slack=16)
    for cs in (1, 41, 512, g.num_edges):
        c = DevicePartition.from_graph(g, edge_slack=16, chunk_size=cs)
        for name in ("src", "dst", "edge_mask", "csr_indptr", "csr_eidx",
                     "bucket_id"):
            assert np.array_equal(np.asarray(getattr(base, name)),
                                  np.asarray(getattr(c, name))), (cs, name)
        assert np.array_equal(np.asarray(base.edge_props["weight"]),
                              np.asarray(c.edge_props["weight"])), cs
        assert base.bucket_sizes == c.bucket_sizes


def test_out_of_core_chunk_source():
    """An EdgeChunkSource that GENERATES chunks on the fly (nothing ever
    holds the full edge list) builds the same AgentGraph as the
    materialized graph — the protocol the billion-edge ingress rides."""
    V, n_chunks, per = 256, 7, 400

    def chunks():
        for c in range(n_chunks):
            rng = np.random.default_rng(100 + c)   # restartable: re-derived
            yield EdgeChunk(src=rng.integers(0, V, per),
                            dst=rng.integers(0, V, per),
                            props={}, offset=c * per)

    source = EdgeChunkSource(num_vertices=V, num_edges=n_chunks * per,
                             prop_dtypes={}, chunks=chunks)
    mat = Graph(V, np.concatenate([c.src for c in chunks()]),
                np.concatenate([c.dst for c in chunks()]), {})
    k = 4
    part = hdrf_partition(source, k)
    assert np.array_equal(part, hdrf_partition(mat, k, chunk_size=per))
    _assert_ag_equal(build_agent_graph(mat, part, k),
                     build_agent_graph(source, part, k), "out-of-core")


def test_build_accepts_partitioner_name_and_records_it():
    g = _rmat(weights=False)
    ag = build_agent_graph(g, "hdrf", 4)
    ref = build_agent_graph(g, hdrf_partition(g, 4), 4)
    assert ag.partitioner == "hdrf"
    assert np.array_equal(ag.src, ref.src)
    assert np.array_equal(ag.dst, ref.dst)
    raw = build_agent_graph(g, hdrf_partition(g, 4), 4)
    assert raw.partitioner == ""
    with pytest.raises(ValueError, match="unknown partitioner"):
        build_agent_graph(g, "metis", 4)


def test_edge_part_length_mismatch_raises():
    g = _rmat(weights=False)
    with pytest.raises(ValueError, match="entries"):
        build_agent_graph(g, np.zeros(g.num_edges - 1, np.int32), 4)


# ------------------------------------------------- fingerprint integration
def test_partitioner_in_plan_fingerprint():
    from repro.tuning.fingerprint import (agent_graph_fingerprint,
                                          graph_fingerprint, plan_cache_key)
    from repro.core import algorithms
    g = _rmat(weights=False)
    ag_h = build_agent_graph(g, "hdrf", 4)
    ag_g = build_agent_graph(g, "greedy", 4)
    assert "p:hdrf" in agent_graph_fingerprint(ag_h)
    assert "p:greedy" in agent_graph_fingerprint(ag_g)
    prog = algorithms.bfs_program()
    assert (plan_cache_key(agent_graph=ag_h, program=prog) !=
            plan_cache_key(agent_graph=ag_g, program=prog))
    # raw-placement graphs keep the legacy token-free key
    assert "p:" not in graph_fingerprint(100, 1000)


def test_quality_reports_replication_and_state_bytes():
    g = _rmat(weights=False)
    s = {}
    part = hdrf_partition(g, 4, stats=s)
    q = partition_quality(g, part,
                          partitioner_state_bytes=s["state_bytes"])
    assert q.partitioner_state_bytes == s["state_bytes"]
    assert q.replication_factor == q.vertexcut_replicas / g.num_vertices


# --------------------------------------------- distributed conformance row
CONFORMANCE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "__SRC__")
import numpy as np
import jax

from repro.graph.generators import rmat_edges
from repro.core.engine import GREEngine, DevicePartition
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core import algorithms

g = rmat_edges(scale=8, edge_factor=8, seed=5, weights=True).dedup()
k = 4
mesh = jax.make_mesh((k,), ("graph",))
sp = DevicePartition.from_graph(g)

def null_run(program, source=None, max_steps=200):
    eng = GREEngine(program)
    st = eng.run(sp, eng.init_state(sp, source=source), max_steps=max_steps)
    return np.asarray(st.vertex_data)

failures = []
bfs_ref = null_run(algorithms.bfs_program(), source=0)
sssp_ref = null_run(algorithms.sssp_program(), source=0, max_steps=300)
fix = lambda x: np.nan_to_num(x, posinf=-1.0)
for name in ("hdrf", "greedy", "hash"):
    ag = build_agent_graph(g, name, k)
    assert ag.partitioner == name
    for prog, ref, steps in ((algorithms.bfs_program(), bfs_ref, 200),
                             (algorithms.sssp_program(), sssp_ref, 300)):
        eng = DistGREEngine(prog, mesh, ("graph",), exchange="agent")
        out, _ = eng.run(ag, source=0, max_steps=steps)
        if not np.array_equal(fix(out), fix(ref)):
            failures.append(f"{prog.name} on {name}")
assert not failures, failures
print("PARTITION_CONFORMANCE_OK")
"""


@pytest.mark.slow
def test_traversals_bitwise_across_partitioners(tmp_path):
    """BFS/SSSP through the real mesh exchange return bitwise-identical
    results whether the edges were placed by HDRF, greedy, or hash — the
    placement changes the traffic, never the answer."""
    script = tmp_path / "partition_conformance.py"
    script.write_text(CONFORMANCE.replace("__SRC__", SRC))
    proc = subprocess.run([sys.executable, str(script)], capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "PARTITION_CONFORMANCE_OK" in proc.stdout
