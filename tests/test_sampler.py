"""Neighbor sampler (minibatch_lg pipeline) + coordinated partitioning."""
import numpy as np

from repro.core.partition import greedy_partition, hash_edge_cut, partition_quality
from repro.graph.generators import rmat_edges
from repro.graph.sampler import NeighborSampler


def test_sampler_budgets_and_validity():
    g = rmat_edges(scale=9, edge_factor=8, seed=0).dedup()
    s = NeighborSampler(g, fanout=(5, 3), seed=1)
    sub = s.sample(n_seeds=16, step=0)
    n_pad, e_pad = s.budget(16)
    assert sub.node_ids.shape == (n_pad,)
    assert sub.src.shape == sub.dst.shape == (e_pad,)
    assert sub.num_nodes <= n_pad and sub.num_edges <= e_pad
    # every sampled edge is a real edge of the graph
    real = set(zip(g.src.tolist(), g.dst.tolist()))
    ids = sub.node_ids
    for a, b, ok in zip(sub.src, sub.dst, sub.edge_mask):
        if ok:
            assert (int(ids[a]), int(ids[b])) in real
    # fanout respected: each node receives at most f1 in-edges per hop
    deg = np.bincount(sub.dst[sub.edge_mask], minlength=len(ids))
    assert deg.max() <= 5
    # edges are dst-sorted (the combine key)
    d = sub.dst[sub.edge_mask]
    assert np.all(np.diff(d) >= 0)
    # seeds are included and marked
    assert sub.seed_mask.sum() == 16


def test_sampler_deterministic_and_rank_independent():
    g = rmat_edges(scale=8, edge_factor=8, seed=0).dedup()
    s = NeighborSampler(g, fanout=(4, 2), seed=7)
    a = s.sample(8, step=3, rank=1)
    b = s.sample(8, step=3, rank=1)
    np.testing.assert_array_equal(a.node_ids, b.node_ids)
    np.testing.assert_array_equal(a.src, b.src)
    c = s.sample(8, step=3, rank=2)
    assert not np.array_equal(a.node_ids, c.node_ids)


def test_sampler_batch_stacks():
    g = rmat_edges(scale=8, edge_factor=8, seed=0).dedup()
    s = NeighborSampler(g, fanout=(4, 2), seed=7)
    batch = s.batch(8, step=0, world=4)
    n_pad, e_pad = s.budget(8)
    assert batch["src"].shape == (4, e_pad)
    assert batch["node_ids"].shape == (4, n_pad)


def test_coordinated_beats_or_matches_oblivious():
    """Paper Fig. 12a ordering: GRE-S best, coordinated ~ between, oblivious
    parallel worst — coordinated must not be worse than oblivious."""
    g = rmat_edges(scale=9, edge_factor=8, seed=3).dedup()
    k = 8
    q_obl = partition_quality(g, greedy_partition(
        g, k, batch_size=64, num_loaders=4, sync_every=0))
    q_coord = partition_quality(g, greedy_partition(
        g, k, batch_size=64, num_loaders=4, sync_every=2))
    assert q_coord.equivalent_edge_cut <= q_obl.equivalent_edge_cut * 1.05
    assert q_coord.equivalent_edge_cut < hash_edge_cut(g, k)
