"""Agent-Graph partitioning: Eq. 7-8 heuristic quality + paper §5.1 claims."""
import numpy as np
import pytest

from repro.core.agent_graph import build_agent_graph
from repro.core.partition import (assign_owners, greedy_partition,
                                  hash_edge_cut, hash_partition,
                                  merge_loader_states, partition_quality,
                                  rebalance_owners)
from repro.graph.generators import rmat_edges


@pytest.fixture(scope="module")
def graph():
    return rmat_edges(scale=9, edge_factor=8, seed=3, weights=False).dedup()


def test_greedy_beats_hash_on_edge_cut(graph):
    """Fig. 11b: agent-graph equivalent edge-cut is far below the random
    hash-sharding edge-cut (the paper's red dashed line, ≈ 1 − 1/k)."""
    k = 8
    q_greedy = partition_quality(graph, greedy_partition(graph, k, 16))
    cut_hash = hash_edge_cut(graph, k)
    assert cut_hash > 0.8  # sanity: 1 - 1/8 = 0.875
    assert q_greedy.equivalent_edge_cut < 0.5 * cut_hash  # paper: 2~11x


def test_edge_balance_constraint(graph):
    """Eq. 7: max partition load within (1+eps) of mean."""
    k = 8
    q = partition_quality(graph, greedy_partition(graph, k, 64))
    assert q.edge_balance < 1.5


def test_agent_comm_leq_vertexcut(graph):
    """Paper §5.1: |Vs| + |Vc| <= 2R — agent exchange never sends more than
    PowerGraph's mirror synchronization for the SAME placement."""
    for k in (2, 4, 8):
        part = greedy_partition(graph, k, 64)
        q = partition_quality(graph, part)
        assert q.agent_comm <= q.vertexcut_comm


def test_scatter_combiner_skew_on_fan_in_graph(graph):
    """Fig. 12b/13b: scatter/combiner rates are skewed (the phenomenon
    PowerGraph's symmetric mirrors cannot represent)."""
    q = partition_quality(graph, greedy_partition(graph, 8, 64))
    assert abs(q.scatter_rate - 0.5) > 0.05


def test_partition_deterministic(graph):
    p1 = greedy_partition(graph, 4, 64, seed=7)
    p2 = greedy_partition(graph, 4, 64, seed=7)
    np.testing.assert_array_equal(p1, p2)


def test_exact_serial_stream_mode(graph):
    """batch_size=1 (exact GRE-S serial stream) beats both the hash edge-cut
    and the batched GRE-P approximation (paper Fig. 12a ordering)."""
    small = rmat_edges(scale=7, edge_factor=6, seed=5).dedup()
    q_s = partition_quality(small, greedy_partition(small, 4, batch_size=1))
    q_p = partition_quality(small, greedy_partition(small, 4, batch_size=64))
    assert q_s.equivalent_edge_cut < hash_edge_cut(small, 4)
    assert q_s.equivalent_edge_cut <= q_p.equivalent_edge_cut * 1.1


def test_agent_graph_structure(graph):
    k = 4
    part = greedy_partition(graph, k, 64)
    ag = build_agent_graph(graph, part, k)
    # every real edge appears exactly once across partitions
    assert int(ag.edge_mask.sum()) == graph.num_edges
    # local ids in range
    assert ag.src.max() <= ag.sink and ag.dst.max() <= ag.sink
    # id mapping is a bijection on real vertices
    assert np.array_equal(np.sort(ag.old2new), np.flatnonzero(
        np.isin(np.arange(ag.k * ag.cap), ag.old2new)))
    back = ag.new2old[ag.old2new]
    np.testing.assert_array_equal(back, np.arange(graph.num_vertices))
    # exchange lists pair up: every (i -> j) combiner send has a matching
    # master slot recorded on j, same multiplicity
    sink = ag.sink
    for i in range(k):
        for j in range(k):
            n_send = int((ag.comb_send_slot[i, j] != sink).sum())
            n_recv = int((ag.comb_recv_master[j, i] != sink).sum())
            assert n_send == n_recv


def test_owner_assignment_covers_all(graph):
    part = greedy_partition(graph, 4, 64)
    owner = assign_owners(graph, part, 4)
    assert owner.shape == (graph.num_vertices,)
    assert owner.min() >= 0 and owner.max() < 4


def test_rebalance_all_at_cap_is_a_noop():
    """Adversarial exactly-at-capacity input (every partition holds exactly
    `cap` masters): nothing to move, nothing to receive — must return the
    input unchanged instead of crashing on an empty receiver list."""
    k, cap = 4, 8
    owner = np.repeat(np.arange(k, dtype=np.int32), cap)
    out = rebalance_owners(owner, k, cap)
    np.testing.assert_array_equal(out, owner)


def test_rebalance_drains_receivers_to_exact_capacity():
    """v == k*cap with ALL vertices piled on partition 0: the receiver list
    drains to empty exactly as the last overflow vertex lands — the
    boundary the old code crashed on (`min([])`) whenever the final move
    filled the last under-cap partition."""
    k, cap = 4, 8
    owner = np.zeros(k * cap, dtype=np.int32)
    out = rebalance_owners(owner, k, cap)
    counts = np.bincount(out, minlength=k)
    np.testing.assert_array_equal(counts, np.full(k, cap))


def test_rebalance_respects_cap_and_keeps_settled():
    rng = np.random.default_rng(7)
    for trial in range(20):
        k = int(rng.integers(1, 8))
        v = int(rng.integers(1, 120))
        cap = -(-v // k) + int(rng.integers(0, 3))
        owner = rng.integers(0, k, size=v).astype(np.int32)
        out = rebalance_owners(owner, k, cap)
        counts = np.bincount(out, minlength=k)
        assert counts.max(initial=0) <= cap
        assert counts.sum() == v
        orig = np.bincount(owner, minlength=k)
        for i in range(k):
            if orig[i] <= cap:       # moves only shed overflow
                assert np.all(out[owner == i] == i)


def test_rebalance_rejects_infeasible():
    with pytest.raises(ValueError, match="cannot rebalance"):
        rebalance_owners(np.zeros(9, np.int32), 2, 4)


def test_assign_owners_ties_break_lowest():
    """Two partitions with equal incident-edge counts for a vertex: the
    lowest partition id wins, deterministically."""
    from repro.graph.structures import Graph
    # vertex 2 has one edge on partition 1 and one on partition 0 -> tie
    g = Graph(4, np.array([0, 3]), np.array([2, 2]))
    owner = assign_owners(g, np.array([1, 0], dtype=np.int32), 2)
    assert owner[2] == 0
    # ... regardless of which stream position carries which partition
    owner = assign_owners(g, np.array([0, 1], dtype=np.int32), 2)
    assert owner[2] == 0


def test_coordinated_merge_recovers_global_edge_count():
    """After every coordinated sync, each loader's load vector must sum to
    the TOTAL edges placed across all loaders — the balance term of Eq. 8
    sees the true global Ne (the old `sum // num_loaders` merge shrank it
    L-fold, compressing the (Max - Ne) spread against edge affinity)."""
    rng = np.random.default_rng(3)
    k, loaders, V = 4, 3, 16
    states = [dict(has_src=np.zeros((k, V), dtype=bool),
                   has_dst=np.zeros((k, V), dtype=bool),
                   ne=np.zeros(k, dtype=np.int64)) for _ in range(loaders)]
    merged = np.zeros(k, dtype=np.int64)
    total = 0
    for _ in range(5):
        for s in states:
            batch = int(rng.integers(1, 9))
            np.add.at(s["ne"], rng.integers(0, k, size=batch), 1)
            total += batch
        merged = merge_loader_states(states, merged, loaders)
        assert int(merged.sum()) == total
        for s in states:
            assert int(s["ne"].sum()) == total


def test_coordinated_mode_end_to_end(graph):
    part = greedy_partition(graph, 4, batch_size=64, seed=1,
                            num_loaders=3, sync_every=1)
    assert part.shape == (graph.num_edges,)
    assert part.min() >= 0 and part.max() < 4
    q = partition_quality(graph, part)
    assert q.agent_comm <= q.vertexcut_comm


def test_tile_scan_factors_show_bucketing_viability():
    """On a power-law placement the flat [cap, max_deg] tile's worst-case
    gather out-scans the edge shard (the old static dense fallback) while
    the degree-bucketed bound stays under it — the partition-quality view
    of why repro.core.frontier buckets by degree."""
    from repro.graph.generators import barabasi_albert_graph
    g = barabasi_albert_graph(4096, m=8, seed=3).dedup()
    q = partition_quality(g, np.zeros(g.num_edges, dtype=np.int64), k=1)
    assert q.local_max_out_degree >= 256          # hubs exist
    assert q.degree_skew > 5.0
    assert q.flat_tile_scan_factor >= 1.0         # flat can never win
    assert q.bucket_tile_scan_factor < 1.0        # bucketed still engages
    assert q.bucket_tile_scan_factor < q.flat_tile_scan_factor
