"""Single-shard Scatter-Combine engine vs exact oracles (networkx/numpy)."""
import networkx as nx
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import ring_graph, rmat_edges


@pytest.fixture(scope="module")
def graph():
    return rmat_edges(scale=8, edge_factor=8, seed=1, weights=True).dedup()


@pytest.fixture(scope="module")
def nxg(graph):
    g = nx.DiGraph()
    g.add_nodes_from(range(graph.num_vertices))
    for s, d, w in zip(graph.src, graph.dst, graph.edge_props["weight"]):
        g.add_edge(int(s), int(d), weight=float(w))
    return g


def test_pagerank_matches_paper_formula(graph):
    """GRE's PageRank is the fixed point of Eq. 2 (non-normalized form)."""
    part = DevicePartition.from_graph(graph)
    eng = GREEngine(algorithms.pagerank_program())
    out = eng.run(part, eng.init_state(part), max_steps=50)
    pr = np.asarray(out.vertex_data)

    prv = np.ones(graph.num_vertices, np.float32)
    outdeg = np.maximum(graph.out_degree(), 1).astype(np.float32)
    for _ in range(50):
        s = np.zeros(graph.num_vertices, np.float32)
        np.add.at(s, graph.dst, (prv / outdeg)[graph.src])
        prv = 0.15 + 0.85 * s
    np.testing.assert_allclose(pr, prv, rtol=1e-4, atol=1e-4)


def test_sssp_matches_dijkstra(graph, nxg):
    part = DevicePartition.from_graph(graph)
    eng = GREEngine(algorithms.sssp_program())
    out = eng.run(part, eng.init_state(part, source=0), max_steps=300)
    dist = np.asarray(out.vertex_data)
    ref = np.full(graph.num_vertices, np.inf)
    for v, d in nx.single_source_dijkstra_path_length(
            nxg, 0, weight="weight").items():
        ref[v] = d
    assert np.array_equal(np.isinf(ref), np.isinf(dist))
    mask = ~np.isinf(ref)
    np.testing.assert_allclose(dist[mask], ref[mask], rtol=1e-6)


def test_sssp_halts_before_max_steps(graph):
    part = DevicePartition.from_graph(graph)
    eng = GREEngine(algorithms.sssp_program())
    out = eng.run(part, eng.init_state(part, source=0), max_steps=10_000)
    assert int(out.step) < 10_000  # assert_to_halt terminated the BSP loop


def test_cc_matches_networkx(graph, nxg):
    gu = graph.as_undirected()
    part = DevicePartition.from_graph(gu)
    eng = GREEngine(algorithms.cc_program())
    out = eng.run(part, eng.init_state(part), max_steps=500)
    label = np.asarray(out.vertex_data).astype(np.int64)
    for comp in nx.connected_components(nxg.to_undirected()):
        labels = {label[v] for v in comp}
        assert labels == {min(comp)}


def test_bfs_matches_networkx(graph, nxg):
    part = DevicePartition.from_graph(graph)
    eng = GREEngine(algorithms.bfs_program())
    out = eng.run(part, eng.init_state(part, source=0), max_steps=200)
    depth = np.asarray(out.vertex_data)
    ref = np.full(graph.num_vertices, np.inf)
    for v, d in nx.single_source_shortest_path_length(nxg, 0).items():
        ref[v] = d
    assert np.array_equal(np.where(np.isinf(ref), -1, ref),
                          np.where(np.isinf(depth), -1, depth))


def test_gas_equals_scatter_combine(graph):
    """Paper §2.2: the fused one-sided path computes the same result as the
    two-phase GAS emulation with intermediate edge storage."""
    part = DevicePartition.from_graph(graph)
    eng = GREEngine(algorithms.pagerank_program())
    st_sc = eng.init_state(part)
    st_gas = eng.init_state(part)
    edge_state = jnp.zeros(part.src.shape[0], jnp.float32)
    for _ in range(5):
        st_sc = eng.superstep(part, st_sc)
        (st_gas, edge_state) = eng.gas_superstep(part, st_gas, edge_state)
    np.testing.assert_allclose(np.asarray(st_sc.vertex_data),
                               np.asarray(st_gas.vertex_data), rtol=1e-6)


def test_degree_program(graph):
    part = DevicePartition.from_graph(graph)
    eng = GREEngine(algorithms.degree_program())
    st = eng.superstep(part, eng.init_state(part))
    np.testing.assert_array_equal(np.asarray(st.vertex_data),
                                  graph.in_degree().astype(np.float32))


def test_ring_sssp_exact_steps():
    """On a directed ring the frontier advances one vertex per superstep."""
    g = ring_graph(16, weights=True)
    part = DevicePartition.from_graph(g)
    eng = GREEngine(algorithms.sssp_program())
    out = eng.run(part, eng.init_state(part, source=0), max_steps=100)
    np.testing.assert_allclose(np.asarray(out.vertex_data),
                               np.arange(16, dtype=np.float32))


def test_engine_with_pallas_kernel_matches_xla(graph):
    """The Pallas segment_combine kernel (interpret mode) slots into the
    engine via use_pallas and reproduces the XLA path exactly."""
    part = DevicePartition.from_graph(graph)
    eng_x = GREEngine(algorithms.pagerank_program())
    eng_p = GREEngine(algorithms.pagerank_program(), use_pallas=True)
    st_x = eng_x.init_state(part)
    st_p = eng_p.init_state(part)
    for _ in range(3):
        st_x = eng_x.superstep(part, st_x)
        st_p = eng_p.superstep(part, st_p)
    np.testing.assert_allclose(np.asarray(st_x.vertex_data),
                               np.asarray(st_p.vertex_data),
                               rtol=1e-5, atol=1e-5)
