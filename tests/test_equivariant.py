"""E(3) machinery: Wigner matrices, CG tensors, model invariances."""
import numpy as np
import pytest

import jax.numpy as jnp
import jax

from repro.nn.equivariant import (_random_rotation, cg_tensor, real_sh_np,
                                  valid_paths, wigner_d)

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("l", [0, 1, 2])
def test_wigner_is_representation(l):
    R1, R2 = _random_rotation(RNG), _random_rotation(RNG)
    D12 = wigner_d(l, R1 @ R2)
    np.testing.assert_allclose(D12, wigner_d(l, R1) @ wigner_d(l, R2),
                               atol=1e-8)


@pytest.mark.parametrize("l1,l2,l3", valid_paths(2))
def test_cg_equivariance(l1, l2, l3):
    C = cg_tensor(l1, l2, l3)
    assert np.linalg.norm(C) > 0.99
    for _ in range(3):
        R = _random_rotation(RNG)
        D1, D2, D3 = wigner_d(l1, R), wigner_d(l2, R), wigner_d(l3, R)
        lhs = np.einsum("kij,ia,jb->kab", C, D1, D2)
        rhs = np.einsum("kc,cab->kab", D3, C)
        np.testing.assert_allclose(lhs, rhs, atol=1e-8)


def test_invalid_paths_are_zero():
    assert np.linalg.norm(cg_tensor(0, 0, 1)) == 0
    assert np.linalg.norm(cg_tensor(0, 1, 2)) == 0
    assert np.linalg.norm(cg_tensor(2, 0, 1)) == 0


def test_sh_rotation_consistency():
    pts = RNG.normal(size=(10, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    R = _random_rotation(RNG)
    sh = real_sh_np(pts, 2)
    sh_rot = real_sh_np(pts @ R.T, 2)
    for l in (0, 1, 2):
        np.testing.assert_allclose(sh_rot[l], sh[l] @ wigner_d(l, R).T,
                                   atol=1e-8)


def test_mace_rotation_translation_invariance():
    from repro.configs.base import GNNConfig
    from repro.graph.generators import random_geometric_molecule
    from repro.models.mace import init_mace, mace_energy
    cfg = GNNConfig("m", "mace", 2, 16, l_max=2, n_rbf=8)
    pos_np, src, dst = random_geometric_molecule(20, 60, seed=0)
    key = jax.random.PRNGKey(0)
    params = init_mace(key, cfg, n_species=8)
    species = jax.random.randint(key, (20,), 0, 5)
    args = (species, jnp.asarray(src), jnp.asarray(dst),
            jnp.ones(60, bool), cfg)
    e1 = mace_energy(params, jnp.asarray(pos_np), *args)
    R = jnp.asarray(_random_rotation(RNG), jnp.float32)
    e2 = mace_energy(params, jnp.asarray(pos_np) @ R.T + 2.5, *args)
    assert abs(float(e1 - e2)) < 1e-3 * (abs(float(e1)) + 1)


def test_dimenet_rotation_translation_invariance():
    from repro.configs.base import GNNConfig
    from repro.graph.generators import random_geometric_molecule
    from repro.models.dimenet import build_triplets, dimenet_forward, init_dimenet
    cfg = GNNConfig("d", "dimenet", 3, 32, n_bilinear=4, n_spherical=7,
                    n_radial=6)
    pos_np, src, dst = random_geometric_molecule(20, 60, seed=0)
    kj, ji, tm = build_triplets(src, dst, 20)
    key = jax.random.PRNGKey(0)
    params = init_dimenet(key, cfg)
    args = (jnp.zeros(20, jnp.int32), jnp.asarray(src), jnp.asarray(dst),
            jnp.ones(60, bool), jnp.asarray(kj), jnp.asarray(ji),
            jnp.asarray(tm), cfg)
    o1 = dimenet_forward(params, jnp.asarray(pos_np), *args).sum()
    R = jnp.asarray(_random_rotation(RNG), jnp.float32)
    o2 = dimenet_forward(params, jnp.asarray(pos_np) @ R.T - 1.0, *args).sum()
    assert abs(float(o1 - o2)) < 1e-4 * (abs(float(o1)) + 1)
