"""MoE scatter-combine dispatch vs the dense no-drop oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.nn.moe import moe_ffn, moe_ffn_reference, moe_init

RNG = np.random.default_rng(2)


@pytest.mark.parametrize("gated", [True, False])
@pytest.mark.parametrize("t,e,k", [(64, 8, 2), (128, 16, 4), (32, 4, 1)])
def test_moe_matches_reference_with_ample_capacity(t, e, k, gated):
    key = jax.random.PRNGKey(0)
    params = moe_init(key, 32, 64, e, gated)
    x = jnp.asarray(RNG.normal(size=(t, 32)), jnp.float32)
    out, aux = moe_ffn(params, x, k, e, capacity_factor=float(e),  # no drops
                       activation="silu")
    want = moe_ffn_reference(params, x, k, e, activation="silu")
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 the dispatch drops overflow tokens but output
    magnitude stays comparable (no NaN/garbage)."""
    key = jax.random.PRNGKey(1)
    params = moe_init(key, 16, 32, 8, True)
    x = jnp.asarray(RNG.normal(size=(256, 16)), jnp.float32)
    out, _ = moe_ffn(params, x, 2, 8, capacity_factor=1.0)
    ref_out = moe_ffn_reference(params, x, 2, 8)
    assert not bool(jnp.isnan(out).any())
    # most tokens unaffected by drops
    close = jnp.mean(jnp.all(jnp.abs(out - ref_out) < 1e-4, axis=-1))
    assert float(close) > 0.5


def test_moe_grads_flow_to_all_parts():
    key = jax.random.PRNGKey(2)
    params = moe_init(key, 16, 32, 4, True)
    x = jnp.asarray(RNG.normal(size=(64, 16)), jnp.float32)

    def loss(p):
        out, aux = moe_ffn(p, x, 2, 4, capacity_factor=4.0)
        return (out ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(params)
    for name, gr in g.items():
        assert float(jnp.abs(gr).sum()) > 0, f"zero grad for {name}"


def test_moe_sharded_equals_local():
    """Simulated 1-device 'sharding': n_shards=1 with shard_index=0 must be
    identical to the plain local call (the multi-shard case is covered by
    the qwen/granite dry-run cells)."""
    key = jax.random.PRNGKey(3)
    params = moe_init(key, 16, 32, 8, True)
    x = jnp.asarray(RNG.normal(size=(64, 16)), jnp.float32)
    a, _ = moe_ffn(params, x, 2, 8, capacity_factor=2.0)
    b, _ = moe_ffn(params, x, 2, 8, capacity_factor=2.0,
                   shard_index=jnp.zeros((), jnp.int32), n_shards=1)
    np.testing.assert_allclose(a, b, rtol=1e-6)
