"""End-to-end behaviour: the training loss actually goes down, serving
generates, and the distributed graph engine solves a real workload through
the full public API (the paper's PageRank-on-R-MAT scenario, CPU-scaled)."""
import numpy as np


def test_lm_training_reduces_loss():
    from repro.launch import train
    loss = train.main(["--arch", "smollm-135m", "--steps", "60",
                       "--batch", "8", "--seq", "64", "--lr", "1e-2"])
    assert loss < 6.5  # ln(1024)=6.93 at random init; must have learned


def test_serving_generates_tokens():
    from repro.launch import serve
    gen = serve.main(["--arch", "smollm-135m", "--batch", "2",
                      "--prompt-len", "16", "--gen", "4"])
    assert gen.shape == (2, 4)
    assert np.asarray(gen).min() >= 0


def test_paper_workload_end_to_end():
    """Paper §7 scenario at CPU scale: greedy-partition an R-MAT graph,
    build the agent-graph, run PageRank + SSSP via the public API, and check
    the partition-quality claims hold on this graph."""
    from repro.core import algorithms
    from repro.core.agent_graph import build_agent_graph
    from repro.core.engine import DevicePartition, GREEngine
    from repro.core.partition import (greedy_partition, hash_partition,
                                      partition_quality)
    from repro.graph.generators import rmat_edges

    g = rmat_edges(scale=9, edge_factor=8, seed=0, weights=True).dedup()
    part = greedy_partition(g, 8, batch_size=64)
    q = partition_quality(g, part)
    qh = partition_quality(g, hash_partition(g, 8))
    assert q.equivalent_edge_cut < qh.equivalent_edge_cut   # Fig. 11b
    assert q.agent_comm <= q.vertexcut_comm                 # §5.1 bound
    ag = build_agent_graph(g, part, 8)
    assert int(ag.edge_mask.sum()) == g.num_edges

    sp = DevicePartition.from_graph(g)
    eng = GREEngine(algorithms.pagerank_program())
    out = eng.run(sp, eng.init_state(sp), max_steps=30)
    pr = np.asarray(out.vertex_data)
    assert np.isfinite(pr).all() and pr.min() >= 0.15 - 1e-5
