"""ExchangeBackend equivalence: the canonical superstep must produce the
same results whichever communication substrate is plugged in.

Backend equivalence runs in a subprocess (the 8-device XLA_FLAGS must be set
before jax initializes); the Pallas-vs-XLA vector-payload combine checks run
in-process.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "__SRC__")
import numpy as np
import jax

from repro.graph.generators import rmat_edges
from repro.core.engine import GREEngine, DevicePartition
from repro.core.partition import greedy_partition
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core import algorithms

g = rmat_edges(scale=8, edge_factor=8, seed=5, weights=True).dedup()
k = 8
ag = build_agent_graph(g, greedy_partition(g, k, batch_size=64), k)
mesh = jax.make_mesh((8,), ("graph",))
sp = DevicePartition.from_graph(g)

failures = []

# --- NullExchange reference: the single-shard canonical superstep ---
def null_run(program, source=None, max_steps=100):
    eng = GREEngine(program)
    st = eng.run(sp, eng.init_state(sp, source=source), max_steps=max_steps)
    return np.asarray(st.vertex_data)

BACKENDS = [("agent", False), ("agent", True), ("dense", False)]

# PageRank (sum monoid): distributed two-stage ⊕ reorders float adds, so
# equivalence is to float tolerance; min-monoid programs are bitwise.
pr_ref = null_run(algorithms.pagerank_program(), max_steps=20)
for mode, overlap in BACKENDS:
    eng = DistGREEngine(algorithms.pagerank_program(), mesh, ("graph",),
                        exchange=mode, overlap=overlap)
    pr, _ = eng.run(ag, max_steps=20)
    if not np.allclose(pr, pr_ref, rtol=1e-5, atol=1e-6):
        failures.append(f"pagerank {mode} overlap={overlap}")

# SSSP (min monoid): bitwise-identical across every backend.
ss_ref = null_run(algorithms.sssp_program(), source=0, max_steps=300)
for mode, overlap in BACKENDS:
    eng = DistGREEngine(algorithms.sssp_program(), mesh, ("graph",),
                        exchange=mode, overlap=overlap)
    dist, _ = eng.run(ag, source=0, max_steps=300)
    if not np.array_equal(np.nan_to_num(dist, posinf=-1.0),
                          np.nan_to_num(ss_ref, posinf=-1.0)):
        failures.append(f"sssp {mode} overlap={overlap}")

# Multi-source batched BFS (payload (D,), ⊕ = elementwise min): one pass
# must equal D independent single-source passes, on the single shard AND
# through every distributed backend.
D, sources = 4, [0, 7, 33, 101]
ms_ref = np.stack([null_run(algorithms.bfs_program(), source=s,
                            max_steps=100) for s in sources], axis=1)
ms_one = null_run(algorithms.bfs_program(num_sources=D), source=sources,
                  max_steps=100)
if not np.array_equal(np.nan_to_num(ms_one, posinf=-1.0),
                      np.nan_to_num(ms_ref, posinf=-1.0)):
    failures.append("bfs multi-source single-shard")
for mode, overlap in BACKENDS:
    eng = DistGREEngine(algorithms.bfs_program(num_sources=D), mesh,
                        ("graph",), exchange=mode, overlap=overlap)
    depths, _ = eng.run(ag, source=sources, max_steps=100)
    if not np.array_equal(np.nan_to_num(depths, posinf=-1.0),
                          np.nan_to_num(ms_ref, posinf=-1.0)):
        failures.append(f"bfs multi-source {mode} overlap={overlap}")

# Compacted-frontier x backend equivalence lives in the systematic matrix
# of tests/test_conformance.py (incl. the overlap=True dst-rewrite row).

# CC (min monoid, undirected): bitwise-identical across every backend.
gu = g.as_undirected().dedup()
agu = build_agent_graph(gu, greedy_partition(gu, k, batch_size=64), k)
spu = DevicePartition.from_graph(gu)
se = GREEngine(algorithms.cc_program())
cc_ref = np.asarray(se.run(spu, se.init_state(spu), max_steps=300).vertex_data)
for mode, overlap in BACKENDS:
    eng = DistGREEngine(algorithms.cc_program(), mesh, ("graph",),
                        exchange=mode, overlap=overlap)
    label, _ = eng.run(agu, max_steps=300)
    if not np.array_equal(label, cc_ref):
        failures.append(f"cc {mode} overlap={overlap}")

assert not failures, failures
print("EXCHANGE_OK")
"""


@pytest.mark.slow
def test_backends_agree_on_rmat(tmp_path):
    script = tmp_path / "exchange_check.py"
    script.write_text(SCRIPT.replace("__SRC__", SRC))
    proc = subprocess.run([sys.executable, str(script)], capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "EXCHANGE_OK" in proc.stdout


# ---------------------------------------------------------------- kernels
@pytest.mark.parametrize("op", ["min", "max"])
def test_pallas_vector_payload_matches_xla(op):
    """Pallas vs XLA segment_combine for min/max monoids, D=16 payloads."""
    from repro.core.vertex_program import MONOIDS, segment_combine
    rng = np.random.default_rng(7)
    e, d, v = 1024, 16, 200
    dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    xla = segment_combine(msgs, jnp.asarray(dst), v, MONOIDS[op],
                          indices_are_sorted=True)
    pls = segment_combine(msgs, jnp.asarray(dst), v, MONOIDS[op],
                          use_pallas=True)
    fix = lambda x: jnp.nan_to_num(x, posinf=1e30, neginf=-1e30)
    np.testing.assert_allclose(np.asarray(fix(pls)), np.asarray(fix(xla)),
                               rtol=1e-6, atol=1e-6)


def test_engine_vector_payload_aggregation_matches_segment_sum():
    """gnn_aggregate_program through the canonical superstep == segment_sum,
    on XLA and Pallas combine paths."""
    import jax
    from repro.core.algorithms import gnn_aggregate_program
    from repro.core.engine import DevicePartition, GREEngine
    from repro.graph.generators import rmat_edges
    from repro.models.gnn import GraphBatch, engine_propagate

    g = rmat_edges(scale=7, edge_factor=8, seed=2).dedup()
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.normal(size=(g.num_vertices, 32)), jnp.float32)
    want = jax.ops.segment_sum(jnp.take(h, jnp.asarray(g.src), axis=0),
                               jnp.asarray(g.dst), g.num_vertices)
    batch = GraphBatch(
        node_feats=h, src=jnp.asarray(g.src, jnp.int32),
        dst=jnp.asarray(g.dst, jnp.int32),
        edge_mask=jnp.ones(g.num_edges, dtype=bool),
        labels=jnp.zeros(g.num_vertices, jnp.int32),
        train_mask=jnp.ones(g.num_vertices, dtype=bool))
    for use_pallas in (False, True):
        got = engine_propagate(batch, use_pallas=use_pallas)(h, None)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
