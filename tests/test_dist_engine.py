"""Distributed engine == single-shard engine, on 8 simulated devices.

Runs in a subprocess because the 8-device XLA_FLAGS must be set before jax
initializes (tests themselves keep the default 1-device runtime)."""
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "__SRC__")
import numpy as np
import jax

from repro.graph.generators import rmat_edges
from repro.core.engine import GREEngine, DevicePartition
from repro.core.partition import greedy_partition
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core import algorithms

g = rmat_edges(scale=8, edge_factor=8, seed=3, weights=True).dedup()
k = 8
ag = build_agent_graph(g, greedy_partition(g, k, batch_size=64), k)
mesh = jax.make_mesh((8,), ("graph",))
sp = DevicePartition.from_graph(g)

failures = []
for mode, overlap in (("agent", False), ("agent", True), ("dense", False)):
    eng = DistGREEngine(algorithms.pagerank_program(), mesh, ("graph",),
                        exchange=mode, overlap=overlap)
    pr, _ = eng.run(ag, max_steps=20)
    se = GREEngine(algorithms.pagerank_program())
    st = se.run(sp, se.init_state(sp), max_steps=20)
    if not np.allclose(pr, np.asarray(st.vertex_data), rtol=1e-4, atol=1e-4):
        failures.append(f"pagerank {mode} overlap={overlap}")

    eng = DistGREEngine(algorithms.sssp_program(), mesh, ("graph",),
                        exchange=mode, overlap=overlap)
    dist, _ = eng.run(ag, source=0, max_steps=300)
    se = GREEngine(algorithms.sssp_program())
    st = se.run(sp, se.init_state(sp, source=0), max_steps=300)
    ref = np.asarray(st.vertex_data)
    if not np.allclose(np.where(np.isinf(ref), -1, ref),
                       np.where(np.isinf(dist), -1, dist)):
        failures.append(f"sssp {mode} overlap={overlap}")

# CC on the undirected graph, agent mode
gu = g.as_undirected().dedup()
agu = build_agent_graph(gu, greedy_partition(gu, k, batch_size=64), k)
eng = DistGREEngine(algorithms.cc_program(), mesh, ("graph",))
label, _ = eng.run(agu, max_steps=300)
se = GREEngine(algorithms.cc_program())
spu = DevicePartition.from_graph(gu)
st = se.run(spu, se.init_state(spu), max_steps=300)
if not np.array_equal(label, np.asarray(st.vertex_data)):
    failures.append("cc agent")

assert not failures, failures
print("DIST_OK")
"""


@pytest.mark.slow
def test_distributed_engine_equals_single_shard(tmp_path):
    script = tmp_path / "dist_check.py"
    script.write_text(SCRIPT.replace("__SRC__", SRC))
    proc = subprocess.run([sys.executable, str(script)], capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "DIST_OK" in proc.stdout
