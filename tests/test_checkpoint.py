"""Checkpoint/restart: round trips, async writes, graph-engine snapshots
(paper §6.3 semantics), and crash-resume via the training launcher."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import (CheckpointManager, graph_engine_restore,
                                      graph_engine_snapshot)

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
            "c": [jnp.ones(3), jnp.zeros((2, 2), jnp.bfloat16)]}


def test_round_trip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = _tree()
    mgr.save(5, tree, metadata={"note": "x"})
    restored, step = mgr.restore(jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree))
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_write_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    mgr.wait()
    assert sorted(mgr.all_steps()) == [3, 4]
    assert mgr.latest_step() == 4


def test_graph_engine_snapshot_drops_agents():
    """Paper §6.3: only native vertex states + bitmap are checkpointed;
    agent slots are temporal and rebuilt to the monoid identity."""
    from repro.core.engine import EngineState
    cap, slots = 4, 10
    st = EngineState(
        vertex_data=jnp.arange(cap, dtype=jnp.float32),
        scatter_data=jnp.arange(slots, dtype=jnp.float32),
        active_scatter=jnp.ones(slots, bool),
        step=jnp.asarray(7, jnp.int32))
    snap = graph_engine_snapshot(st, cap)
    assert snap["scatter_data"].shape == (cap,)
    restored = graph_engine_restore(snap, slots, identity=jnp.inf)
    np.testing.assert_array_equal(np.asarray(restored.scatter_data[:cap]),
                                  np.arange(cap, dtype=np.float32))
    assert np.all(np.isinf(np.asarray(restored.scatter_data[cap:])))
    assert not np.any(np.asarray(restored.active_scatter[cap:]))
    assert int(restored.step) == 7


def test_restore_resume_continues_from_snapshot():
    """The paper's restart contract, end to end: a crashed run resumed from
    its snapshot reaches the same final loss as an uninterrupted run."""
    import tempfile
    from repro.launch import train

    with tempfile.TemporaryDirectory() as d1:
        loss_full = train.main(["--arch", "smollm-135m", "--steps", "8",
                                "--batch", "2", "--seq", "64",
                                "--ckpt", d1, "--ckpt-every", "4"])
    with tempfile.TemporaryDirectory() as d2:
        with pytest.raises(SystemExit):
            train.main(["--arch", "smollm-135m", "--steps", "8",
                        "--batch", "2", "--seq", "64",
                        "--ckpt", d2, "--ckpt-every", "4",
                        "--fail-at", "6"])
        loss_resumed = train.main(["--arch", "smollm-135m", "--steps", "8",
                                   "--batch", "2", "--seq", "64",
                                   "--ckpt", d2, "--ckpt-every", "4"])
    assert abs(loss_full - loss_resumed) < 2e-3


@pytest.mark.slow
def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Snapshot on a 4-device mesh, restore onto 2 devices (subprocess)."""
    script = tmp_path / "elastic.py"
    script.write_text(f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {SRC!r})
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager

mesh4 = jax.make_mesh((4,), ("data",))
x = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                   NamedSharding(mesh4, P("data", None)))
mgr = CheckpointManager({str(tmp_path)!r}, async_write=False)
mgr.save(1, {{"x": x}})

mesh2 = jax.make_mesh((2, 2), ("data", "model"))
like = {{"x": jax.ShapeDtypeStruct((8, 4), jnp.float32)}}
sh = {{"x": NamedSharding(mesh2, P("model", "data"))}}
restored, _ = mgr.restore(like, shardings=sh)
np.testing.assert_array_equal(np.asarray(restored["x"]),
                              np.arange(32.0).reshape(8, 4))
assert restored["x"].sharding.spec == P("model", "data")
print("ELASTIC_OK")
""")
    proc = subprocess.run([sys.executable, str(script)], capture_output=True,
                          text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ELASTIC_OK" in proc.stdout
