"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.segment_combine import build_block_table, segment_combine_pallas

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("e,d,v", [(1000, 8, 64), (512, 1, 300),
                                   (2048, 128, 512), (77, 16, 33),
                                   (256, 32, 256), (4096, 64, 128)])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_segment_combine_sweep(e, d, v, op):
    dst = np.sort(RNG.integers(0, v, e)).astype(np.int32)
    msgs = jnp.asarray(RNG.normal(size=(e, d)), jnp.float32)
    out = ops.segment_combine(msgs, jnp.asarray(dst), v, op)
    want = ref.segment_combine_ref(msgs, jnp.asarray(dst), v, op)
    fix = lambda x: jnp.nan_to_num(x, posinf=1e30, neginf=-1e30)
    np.testing.assert_allclose(fix(out), fix(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_combine_dtypes(dtype):
    dst = np.sort(RNG.integers(0, 50, 400)).astype(np.int32)
    msgs = jnp.asarray(RNG.normal(size=(400, 16)), dtype)
    out = ops.segment_combine(msgs, jnp.asarray(dst), 50, "sum")
    want = ref.segment_combine_ref(msgs.astype(jnp.float32),
                                   jnp.asarray(dst), 50, "sum")
    np.testing.assert_allclose(np.asarray(out, np.float32), want,
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=20, deadline=None)
@given(e=st.integers(1, 500), v=st.integers(1, 200),
       d=st.sampled_from([1, 4, 32]), seed=st.integers(0, 2**16))
def test_segment_combine_hypothesis(e, v, d, seed):
    rng = np.random.default_rng(seed)
    dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    out = ops.segment_combine(msgs, jnp.asarray(dst), v, "sum")
    want = ref.segment_combine_ref(msgs, jnp.asarray(dst), v, "sum")
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_block_table_covers_all_edges():
    dst = np.sort(RNG.integers(0, 1000, 5000)).astype(np.int32)
    table = build_block_table(dst, 1000, block_e=256, block_v=128)
    n_e = -(-5000 // 256)
    # every edge block with any dst in a v-range appears in that row
    for i in range(table.shape[0]):
        lo, hi = i * 128, (i + 1) * 128
        need = {int(j) for j in range(n_e)
                if ((dst[j * 256:(j + 1) * 256] >= lo)
                    & (dst[j * 256:(j + 1) * 256] < hi)).any()}
        have = {int(x) for x in table[i] if x < n_e}
        assert need <= have


@pytest.mark.parametrize("b,sq,sk,kv,g,h,causal",
                         [(2, 128, 128, 2, 2, 64, True),
                          (1, 256, 256, 1, 4, 32, True),
                          (2, 128, 128, 2, 1, 64, False),
                          (1, 64, 192, 2, 2, 32, False)])
def test_flash_attention_sweep(b, sq, sk, kv, g, h, causal):
    q = jnp.asarray(RNG.normal(size=(b, sq, kv, g, h)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, sk, kv, h)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, sk, kv, h)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kv * g, sq, h)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kv, g, sk, h)).reshape(-1, sk, h)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kv, g, sk, h)).reshape(-1, sk, h)
    want = ref.flash_attention_ref(qf, kf, vf, causal).reshape(
        b, kv, g, sq, h).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jnp.asarray(RNG.normal(size=(1, 128, 1, 2, 32)), dtype)
    k = jnp.asarray(RNG.normal(size=(1, 128, 1, 32)), dtype)
    v = jnp.asarray(RNG.normal(size=(1, 128, 1, 32)), dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    qf = q.astype(jnp.float32).transpose(0, 2, 3, 1, 4).reshape(2, 128, 32)
    kf = jnp.broadcast_to(k.astype(jnp.float32).transpose(0, 2, 1, 3)[:, :, None],
                          (1, 1, 2, 128, 32)).reshape(2, 128, 32)
    vf = jnp.broadcast_to(v.astype(jnp.float32).transpose(0, 2, 1, 3)[:, :, None],
                          (1, 1, 2, 128, 32)).reshape(2, 128, 32)
    want = ref.flash_attention_ref(qf, kf, vf, True).reshape(
        1, 1, 2, 128, 32).transpose(0, 3, 1, 2, 4)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), want,
                               rtol=tol, atol=tol)


def test_embedding_bag_weighted():
    table = jnp.asarray(RNG.normal(size=(500, 16)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, 500, 200).astype(np.int32))
    bags = jnp.asarray(np.sort(RNG.integers(0, 40, 200)).astype(np.int32))
    w = jnp.asarray(RNG.normal(size=200), jnp.float32)
    out = ops.embedding_bag(table, ids, bags, 40, weights=w)
    want = ref.embedding_bag_ref(table, ids, bags, 40, weights=w)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
