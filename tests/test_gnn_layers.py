"""GAT / GraphSAGE layers on the scatter-combine primitive."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.graph.generators import rmat_edges
from repro.models.gnn import (gat_layer, gat_layer_init, sage_layer,
                              sage_layer_init)


@pytest.fixture(scope="module")
def setup():
    g = rmat_edges(scale=7, edge_factor=6, seed=0).dedup()
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (g.num_vertices, 16))
    return g, h, key


def test_gat_softmax_normalizes(setup):
    """Per-destination attention weights sum to 1 (for nodes with edges)."""
    g, h, key = setup
    params = gat_layer_init(key, 16, 8, n_heads=2)
    src = jnp.asarray(g.src, jnp.int32)
    dst = jnp.asarray(g.dst, jnp.int32)
    mask = jnp.ones(g.num_edges, bool)
    out = gat_layer(params, h, src, dst, mask, g.num_vertices, n_heads=2)
    assert out.shape == (g.num_vertices, 16)
    assert not bool(jnp.isnan(out).any())
    # constant-feature invariance: with identical z rows, attention output
    # equals the (elu of the) shared value for any in-degree > 0
    hc = jnp.ones_like(h)
    outc = gat_layer(params, hc, src, dst, mask, g.num_vertices, n_heads=2)
    zc = (hc @ params["w"]).reshape(g.num_vertices, 2, 8)
    indeg = np.bincount(g.dst, minlength=g.num_vertices)
    rows = indeg > 0
    want = jax.nn.elu(zc.reshape(g.num_vertices, 16))
    np.testing.assert_allclose(np.asarray(outc)[rows],
                               np.asarray(want)[rows], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("agg", ["mean", "max"])
def test_sage_matches_numpy(setup, agg):
    g, h, key = setup
    params = sage_layer_init(key, 16, 8)
    src = jnp.asarray(g.src, jnp.int32)
    dst = jnp.asarray(g.dst, jnp.int32)
    mask = jnp.ones(g.num_edges, bool)
    out = sage_layer(params, h, src, dst, mask, g.num_vertices, agg)
    hn = np.asarray(h)
    aggd = np.zeros((g.num_vertices, 16))
    for v in range(g.num_vertices):
        nbrs = g.src[g.dst == v]
        if len(nbrs):
            aggd[v] = (hn[nbrs].mean(0) if agg == "mean"
                       else hn[nbrs].max(0))
    want = np.maximum(hn @ np.asarray(params["w_self"])
                      + aggd @ np.asarray(params["w_nbr"]), 0)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4, atol=1e-4)


def test_gat_grads_finite(setup):
    g, h, key = setup
    params = gat_layer_init(key, 16, 8, n_heads=2)
    src = jnp.asarray(g.src, jnp.int32)
    dst = jnp.asarray(g.dst, jnp.int32)
    mask = jnp.ones(g.num_edges, bool)

    def loss(p):
        return (gat_layer(p, h, src, dst, mask, g.num_vertices,
                          n_heads=2) ** 2).mean()

    grads = jax.grad(loss)(params)
    for gname, gr in grads.items():
        assert np.isfinite(np.asarray(gr)).all(), gname
