"""Property-based tests (hypothesis) for the system's core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.partition import (assign_owners, greedy_partition,
                                  partition_quality, rebalance_owners)
from repro.core.vertex_program import MONOIDS, segment_combine
from repro.graph.generators import erdos_renyi_edges
from repro.optim import compression


# ---------------------------------------------------------------- ⊕ monoid
@settings(max_examples=30, deadline=None)
@given(e=st.integers(1, 300), v=st.integers(1, 100),
       op=st.sampled_from(["sum", "min", "max"]), seed=st.integers(0, 9999))
def test_combine_is_permutation_invariant(e, v, op, seed):
    """Paper §2.2's key fact: ⊕ commutative+associative ⇒ message arrival
    order cannot change the result (what lets GRE drop vLock on TPU)."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, v, e).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=(e,)), jnp.float32)
    perm = rng.permutation(e)
    m = MONOIDS[op]
    a = segment_combine(msgs, jnp.asarray(dst), v, m)
    b = segment_combine(msgs[perm], jnp.asarray(dst[perm]), v, m)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(e=st.integers(2, 200), v=st.integers(1, 50), seed=st.integers(0, 9999))
def test_combine_is_two_level_associative(e, v, seed):
    """Agent-graph exactness: combining per-partition partials then combining
    the partials equals the flat combine (⊕ associativity, §5.1)."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, v, e).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=(e,)), jnp.float32)
    m = MONOIDS["sum"]
    flat = segment_combine(msgs, jnp.asarray(dst), v, m)
    half = e // 2
    p1 = segment_combine(msgs[:half], jnp.asarray(dst[:half]), v, m)
    p2 = segment_combine(msgs[half:], jnp.asarray(dst[half:]), v, m)
    np.testing.assert_allclose(np.asarray(p1 + p2), np.asarray(flat),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- partitioning
@settings(max_examples=10, deadline=None)
@given(n=st.integers(16, 128), m=st.integers(32, 512),
       k=st.sampled_from([2, 4, 8]), seed=st.integers(0, 999))
def test_partition_invariants(n, m, k, seed):
    g = erdos_renyi_edges(n, m, seed=seed).dedup()
    if g.num_edges == 0:
        return
    part = greedy_partition(g, k, batch_size=32, seed=seed)
    assert part.min() >= 0 and part.max() < k
    q = partition_quality(g, part)
    # §5.1 bound holds on EVERY graph, not just scale-free ones
    assert q.agent_comm <= q.vertexcut_comm
    assert 0.0 <= q.equivalent_edge_cut <= 2.0
    assert q.num_scatters + q.num_combiners == q.agent_comm


@settings(max_examples=30, deadline=None)
@given(v=st.integers(1, 200), k=st.integers(1, 8), slack=st.integers(0, 3),
       seed=st.integers(0, 999))
def test_rebalance_owners_respects_cap(v, k, slack, seed):
    """Placement invariant: any feasible owner vector rebalances to at most
    `cap` masters per partition with every vertex still owned — including
    the adversarial exactly-at-capacity case (v == k * cap), where the
    receiver list drains to empty and the old code crashed on `min([])`."""
    cap = -(-v // k) + slack          # k * cap >= v: always feasible
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, k, size=v).astype(np.int32)
    out = rebalance_owners(owner, k, cap)
    counts = np.bincount(out, minlength=k)
    assert counts.max(initial=0) <= cap
    assert counts.sum() == v
    assert out.min(initial=0) >= 0 and out.max(initial=0) < k
    # untouched partitions keep their assignment (moves only shed overflow)
    orig = np.bincount(owner, minlength=k)
    for i in range(k):
        if orig[i] <= cap:
            assert np.all(out[owner == i] == i)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 8), cap=st.integers(1, 32), extra=st.integers(1, 16),
       seed=st.integers(0, 99))
def test_rebalance_owners_rejects_infeasible(k, cap, extra, seed):
    """More vertices than k*cap total slots must raise a clear ValueError
    up front, not crash mid-move with an exhausted receiver list."""
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, k, size=k * cap + extra).astype(np.int32)
    with pytest.raises(ValueError, match="cannot rebalance"):
        rebalance_owners(owner, k, cap)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 64), m=st.integers(4, 128),
       k=st.sampled_from([2, 4]), seed=st.integers(0, 999))
def test_assign_owners_ties_break_lowest(n, m, k, seed):
    """Master placement determinism: the owner is the partition with the
    most incident edges, and an exact tie goes to the LOWEST partition id
    (argmax-first semantics) — reorderings of equally-good partitions must
    not change the layout a warm-started state depends on."""
    g = erdos_renyi_edges(n, m, seed=seed).dedup()
    part = (np.arange(g.num_edges) % k).astype(np.int32)
    owner = assign_owners(g, part, k)
    counts = np.zeros((k, n), dtype=np.int64)
    np.add.at(counts, (part, g.src), 1)
    np.add.at(counts, (part, g.dst), 1)
    for v in range(n):
        if counts[:, v].sum() == 0:
            assert owner[v] == v % k          # isolated vertices hash
        else:
            best = counts[:, v].max()
            assert owner[v] == int(np.flatnonzero(counts[:, v] == best)[0])


@settings(max_examples=20, deadline=None)
@given(k=st.sampled_from([2, 4]), loaders=st.sampled_from([2, 3]),
       rounds=st.integers(1, 4), seed=st.integers(0, 999))
def test_greedy_coordinated_merge_preserves_edge_count(k, loaders, rounds,
                                                       seed):
    """Coordinated-mode state merges must hand every loader the TRUE global
    per-partition edge count for the balance term: after every sync, each
    loader's load vector sums to exactly the number of edges placed so far
    across ALL loaders (the old `sum // num_loaders` shortcut shrank it
    L-fold, compressing the (Max - Ne) spread Eq. 8 balances with).
    Drives `merge_loader_states` — the function `greedy_partition`'s
    coordinated mode calls at each sync point — through several rounds of
    interleaved placements."""
    from repro.core.partition import merge_loader_states
    rng = np.random.default_rng(seed)
    V = 16
    states = [dict(has_src=np.zeros((k, V), dtype=bool),
                   has_dst=np.zeros((k, V), dtype=bool),
                   ne=np.zeros(k, dtype=np.int64)) for _ in range(loaders)]
    merged = np.zeros(k, dtype=np.int64)
    total = 0
    for _ in range(rounds):
        for s in states:                       # each loader places a batch
            batch = int(rng.integers(0, 9))
            idx = rng.integers(0, k, size=batch)
            np.add.at(s["ne"], idx, 1)
            s["has_src"][idx, rng.integers(0, V, size=batch)] = True
            total += batch
        merged = merge_loader_states(states, merged, loaders)
        assert int(merged.sum()) == total
        for s in states:
            assert int(s["ne"].sum()) == total


@settings(max_examples=6, deadline=None)
@given(n=st.integers(32, 96), m=st.integers(64, 256),
       seed=st.integers(0, 99))
def test_greedy_coordinated_mode_end_to_end(n, m, seed):
    """The coordinated loader path produces a valid full placement (every
    edge assigned, ids in range) — the merge must never lose or duplicate
    stream positions."""
    g = erdos_renyi_edges(n, m, seed=seed).dedup()
    if g.num_edges < 4:
        return
    part = greedy_partition(g, 4, batch_size=8, seed=seed,
                            num_loaders=3, sync_every=1)
    assert part.shape == (g.num_edges,)
    assert part.min() >= 0 and part.max() < 4
    """Engine correctness is topology-independent: random graphs, k=2."""
    from repro.core import algorithms
    from repro.core.agent_graph import build_agent_graph
    from repro.core.engine import DevicePartition, GREEngine

    g = erdos_renyi_edges(n, m, seed=seed).dedup()
    if g.num_edges < 2:
        return
    part = greedy_partition(g, 2, batch_size=16, seed=seed)
    ag = build_agent_graph(g, part, 2)
    assert int(ag.edge_mask.sum()) == g.num_edges
    # single-shard oracle still exact on this graph
    sp = DevicePartition.from_graph(g)
    eng = GREEngine(algorithms.pagerank_program())
    out = eng.run(sp, eng.init_state(sp), max_steps=5)
    assert not bool(jnp.isnan(out.vertex_data).any())


# ----------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 500), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 9999))
def test_error_feedback_bounds_quantization(n, scale, seed):
    """Single-step int8 quantization error <= 1 quantum; the residual is
    carried forward exactly (error feedback invariant)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)}
    e0 = compression.init_error(g)
    q, s, e1 = compression.compress(g, e0)
    deq = compression.decompress(q, s)
    err = np.asarray(g["w"] - deq["w"])
    quantum = float(s["w"])
    assert np.all(np.abs(err) <= quantum * (0.5 + 1e-5))
    np.testing.assert_allclose(np.asarray(e1["w"]), err, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_error_feedback_mean_converges(seed):
    """Accumulated dequantized signal tracks the true sum (EF property)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    err = compression.init_error(g)
    acc = np.zeros(64)
    for _ in range(20):
        q, s, err = compression.compress(g, err)
        acc += np.asarray(compression.decompress(q, s)["w"])
    np.testing.assert_allclose(acc / 20, np.asarray(g["w"]),
                               rtol=0.02, atol=0.02)
