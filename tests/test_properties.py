"""Property-based tests (hypothesis) for the system's core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.partition import greedy_partition, partition_quality
from repro.core.vertex_program import MONOIDS, segment_combine
from repro.graph.generators import erdos_renyi_edges
from repro.optim import compression


# ---------------------------------------------------------------- ⊕ monoid
@settings(max_examples=30, deadline=None)
@given(e=st.integers(1, 300), v=st.integers(1, 100),
       op=st.sampled_from(["sum", "min", "max"]), seed=st.integers(0, 9999))
def test_combine_is_permutation_invariant(e, v, op, seed):
    """Paper §2.2's key fact: ⊕ commutative+associative ⇒ message arrival
    order cannot change the result (what lets GRE drop vLock on TPU)."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, v, e).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=(e,)), jnp.float32)
    perm = rng.permutation(e)
    m = MONOIDS[op]
    a = segment_combine(msgs, jnp.asarray(dst), v, m)
    b = segment_combine(msgs[perm], jnp.asarray(dst[perm]), v, m)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(e=st.integers(2, 200), v=st.integers(1, 50), seed=st.integers(0, 9999))
def test_combine_is_two_level_associative(e, v, seed):
    """Agent-graph exactness: combining per-partition partials then combining
    the partials equals the flat combine (⊕ associativity, §5.1)."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, v, e).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=(e,)), jnp.float32)
    m = MONOIDS["sum"]
    flat = segment_combine(msgs, jnp.asarray(dst), v, m)
    half = e // 2
    p1 = segment_combine(msgs[:half], jnp.asarray(dst[:half]), v, m)
    p2 = segment_combine(msgs[half:], jnp.asarray(dst[half:]), v, m)
    np.testing.assert_allclose(np.asarray(p1 + p2), np.asarray(flat),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- partitioning
@settings(max_examples=10, deadline=None)
@given(n=st.integers(16, 128), m=st.integers(32, 512),
       k=st.sampled_from([2, 4, 8]), seed=st.integers(0, 999))
def test_partition_invariants(n, m, k, seed):
    g = erdos_renyi_edges(n, m, seed=seed).dedup()
    if g.num_edges == 0:
        return
    part = greedy_partition(g, k, batch_size=32, seed=seed)
    assert part.min() >= 0 and part.max() < k
    q = partition_quality(g, part)
    # §5.1 bound holds on EVERY graph, not just scale-free ones
    assert q.agent_comm <= q.vertexcut_comm
    assert 0.0 <= q.equivalent_edge_cut <= 2.0
    assert q.num_scatters + q.num_combiners == q.agent_comm


@settings(max_examples=10, deadline=None)
@given(n=st.integers(16, 100), m=st.integers(16, 256), seed=st.integers(0, 99))
def test_agent_graph_runs_any_graph(n, m, seed):
    """Engine correctness is topology-independent: random graphs, k=2."""
    from repro.core import algorithms
    from repro.core.agent_graph import build_agent_graph
    from repro.core.engine import DevicePartition, GREEngine

    g = erdos_renyi_edges(n, m, seed=seed).dedup()
    if g.num_edges < 2:
        return
    part = greedy_partition(g, 2, batch_size=16, seed=seed)
    ag = build_agent_graph(g, part, 2)
    assert int(ag.edge_mask.sum()) == g.num_edges
    # single-shard oracle still exact on this graph
    sp = DevicePartition.from_graph(g)
    eng = GREEngine(algorithms.pagerank_program())
    out = eng.run(sp, eng.init_state(sp), max_steps=5)
    assert not bool(jnp.isnan(out.vertex_data).any())


# ----------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 500), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 9999))
def test_error_feedback_bounds_quantization(n, scale, seed):
    """Single-step int8 quantization error <= 1 quantum; the residual is
    carried forward exactly (error feedback invariant)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)}
    e0 = compression.init_error(g)
    q, s, e1 = compression.compress(g, e0)
    deq = compression.decompress(q, s)
    err = np.asarray(g["w"] - deq["w"])
    quantum = float(s["w"])
    assert np.all(np.abs(err) <= quantum * (0.5 + 1e-5))
    np.testing.assert_allclose(np.asarray(e1["w"]), err, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_error_feedback_mean_converges(seed):
    """Accumulated dequantized signal tracks the true sum (EF property)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    err = compression.init_error(g)
    acc = np.zeros(64)
    for _ in range(20):
        q, s, err = compression.compress(g, err)
        acc += np.asarray(compression.decompress(q, s)["w"])
    np.testing.assert_allclose(acc / 20, np.asarray(g["w"]),
                               rtol=0.02, atol=0.02)
