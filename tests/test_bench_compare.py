"""The CI perf gate (benchmarks/compare.py): regression and missing-key
semantics.  Runs the comparator in-process on synthetic result files."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import compare  # noqa: E402


def _write(path, names_us, ungated=(), noise=None):
    payload = {"results": [
        {"name": n, "us_per_call": us,
         **({"gate": False} if n in ungated else {}),
         **({"noise": noise[n]} if noise and n in noise else {})}
        for n, us in names_us.items()]}
    path.write_text(json.dumps(payload))
    return str(path)


BASE = {"a": 10000.0, "b": 20000.0, "c": 30000.0, "tiny": 100.0}


def test_gate_passes_on_parity(tmp_path):
    base = _write(tmp_path / "base.json", BASE)
    cur = _write(tmp_path / "cur.json", BASE)
    assert compare.main([base, cur]) == 0


def test_gate_fails_on_relative_regression(tmp_path):
    base = _write(tmp_path / "base.json", BASE)
    cur = _write(tmp_path / "cur.json",
                 {**BASE, "b": BASE["b"] * 4})  # b regresses vs the rest
    assert compare.main([base, cur]) == 1


def test_gate_normalizes_uniform_machine_drift(tmp_path):
    """A uniformly 2x-slower runner is machine drift, not a regression."""
    base = _write(tmp_path / "base.json", BASE)
    cur = _write(tmp_path / "cur.json",
                 {n: us * 2 for n, us in BASE.items()})
    assert compare.main([base, cur]) == 0


def test_gate_fails_on_missing_benchmark(tmp_path):
    """A benchmark present in the baseline but dropped from the run must
    FAIL — silently vanishing benchmarks would hide the regressions they
    were gating."""
    base = _write(tmp_path / "base.json", BASE)
    cur = _write(tmp_path / "cur.json",
                 {n: us for n, us in BASE.items() if n != "b"})
    assert compare.main([base, cur]) == 1


def test_gate_missing_subfloor_benchmark_still_fails(tmp_path):
    """Missing-key detection is not subject to the noise floor."""
    base = _write(tmp_path / "base.json", BASE)
    cur = _write(tmp_path / "cur.json",
                 {n: us for n, us in BASE.items() if n != "tiny"})
    assert compare.main([base, cur]) == 1


def test_gate_added_benchmark_is_not_fatal(tmp_path):
    base = _write(tmp_path / "base.json", BASE)
    cur = _write(tmp_path / "cur.json", {**BASE, "new": 5000.0})
    assert compare.main([base, cur]) == 0


def test_gate_false_entry_never_ratio_gates(tmp_path):
    """Entries opted out at emit time ('gate': false) are exempt from the
    regression gate even when above the noise floor..."""
    base = _write(tmp_path / "base.json", BASE, ungated=("b",))
    cur = _write(tmp_path / "cur.json", {**BASE, "b": BASE["b"] * 4},
                 ungated=("b",))
    assert compare.main([base, cur]) == 0


def test_gate_false_entry_missing_still_fails(tmp_path):
    """...but dropping them from the run still fails — the trajectory
    record must not silently vanish."""
    base = _write(tmp_path / "base.json", BASE, ungated=("b",))
    cur = _write(tmp_path / "cur.json",
                 {n: us for n, us in BASE.items() if n != "b"})
    assert compare.main([base, cur]) == 1


# --------------------------------------------------- per-entry noise margins

def test_noise_margin_widens_gate_for_noisy_entry(tmp_path):
    """A 1.6x slowdown on an entry whose baseline recorded 1.6x dispersion
    is within its own measured repeatability — no regression; the same
    slowdown on a quiet entry (noise 1.02 -> margin at the 1.25x floor)
    fails."""
    noisy = _write(tmp_path / "noisy.json", BASE, noise={"b": 1.6})
    quiet = _write(tmp_path / "quiet.json", BASE, noise={"b": 1.02})
    cur = _write(tmp_path / "cur.json", {**BASE, "b": BASE["b"] * 1.6})
    assert compare.main([noisy, cur]) == 0
    assert compare.main([quiet, cur]) == 1


def test_noise_margin_is_capped(tmp_path):
    """A pathologically noisy baseline (noise 10x) cannot disable its own
    gate: the margin is clamped at --cap (default 2.5x)."""
    base = _write(tmp_path / "base.json", BASE, noise={"b": 10.0})
    cur = _write(tmp_path / "cur.json", {**BASE, "b": BASE["b"] * 4})
    assert compare.main([base, cur]) == 1


def test_no_noise_falls_back_to_uniform_threshold(tmp_path):
    """Entries without a recorded dispersion keep the legacy uniform
    --threshold semantics."""
    base = _write(tmp_path / "base.json", BASE)
    cur = _write(tmp_path / "cur.json", {**BASE, "b": BASE["b"] * 1.4})
    assert compare.main([base, cur]) == 0
    assert compare.main([base, cur, "--threshold", "1.3"]) == 1


def test_only_prefix_subsets_both_files(tmp_path):
    """--only gates just the selected slice: a current run producing only
    serving_* entries passes against a full baseline (no missing-entry
    failure for the rest), and a regression INSIDE the slice still fails."""
    base = _write(tmp_path / "base.json",
                  {**BASE, "serving_x": 50000.0, "serving_y": 60000.0})
    cur_ok = _write(tmp_path / "cur.json",
                    {"serving_x": 50000.0, "serving_y": 60000.0})
    assert compare.main([base, cur_ok, "--only", "serving_"]) == 0
    cur_bad = _write(tmp_path / "cur2.json",
                     {"serving_x": 50000.0, "serving_y": 600000.0})
    assert compare.main([base, cur_bad, "--only", "serving_"]) == 1


def test_skip_prefix_excludes_from_missing_check(tmp_path):
    """--skip removes a slice from both files: the main bench job can gate
    everything EXCEPT serving_* without the serving entries (absent from
    its artifact) counting as missing — but a skipped slice present and
    regressed stays invisible too (the serving job owns that gate)."""
    base = _write(tmp_path / "base.json", {**BASE, "serving_x": 50000.0})
    cur = _write(tmp_path / "cur.json", BASE)          # no serving_x
    assert compare.main([base, cur]) == 1              # missing w/o --skip
    assert compare.main([base, cur, "--skip", "serving_"]) == 0
    cur_reg = _write(tmp_path / "cur2.json",
                     {**BASE, "serving_x": 500000.0})
    assert compare.main([base, cur_reg, "--skip", "serving_"]) == 0
