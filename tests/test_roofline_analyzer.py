"""The HLO roofline analyzer: trip-count awareness + flop accounting."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch import roofline as rl


def _analyze(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return rl.analyze(compiled.as_text())


def test_plain_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    res = _analyze(lambda a, b: a @ b, a, b)
    want = 2 * 256 * 512 * 128
    assert res["flops_per_device"] == pytest.approx(want, rel=0.01)


def test_scan_body_multiplied_by_trip_count():
    """The whole reason this analyzer exists: XLA cost_analysis counts a
    while body once; ours multiplies by the parsed trip count."""
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)

    def make(n):
        def fn(w, x):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out
        return fn

    f4 = _analyze(make(4), w, x)["flops_per_device"]
    f16 = _analyze(make(16), w, x)["flops_per_device"]
    assert f16 / f4 == pytest.approx(4.0, rel=0.1)
    per_layer = 2 * 8 * 128 * 128
    assert f16 == pytest.approx(16 * per_layer, rel=0.2)


def test_nested_scan_trip_counts_compose():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def fn(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    res = _analyze(fn, w, x)
    want = 5 * 3 * 2 * 4 * 64 * 64
    assert res["flops_per_device"] == pytest.approx(want, rel=0.2)


def test_shape_parsing():
    assert rl.shape_bytes("f32[16,4]{1,0}") == 256
    assert rl.shape_bytes("bf16[8]{0}") == 16
    assert rl.shape_bytes("(f32[4]{0}, s32[2]{0})") == 24
    assert rl.shape_elems("f32[3,5]{1,0}") == 15
    assert rl.shape_bytes("pred[7]{0}") == 7


def test_dominant_term_and_times():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    res = _analyze(lambda a: a @ a, a)
    assert res["dominant"] in ("compute", "memory", "collective")
    assert res["bound_time_s"] == max(res["compute_time_s"],
                                      res["memory_time_s"],
                                      res["collective_time_s"])
    assert res["link_bytes_per_device"] == 0  # single device: no collectives
