"""Incremental re-convergence on evolving graphs: the mutation property
suite (docs/incremental.md).

The invariant under test is the one the whole delta-ingress design hangs
on: for min-monoid traversal programs the fixed point is UNIQUE and the
superstep operator idempotent, so a warm start from the previous fixed
point — fresh init values on the invalidated region, scatter activity on
the affected seeds only — must land BITWISE on the same fixed point as a
from-scratch run of the mutated graph.  The property test drives random
INTERLEAVED add/remove batch sequences (add-only, remove-only, and mixed
batches) through `DevicePartition.apply_edge_delta` +
`GREEngine.warm_start_state` on power-law (R-MAT) and circulant graphs
and checks, after EVERY batch:

  * warm == cold, bitwise (the conformance invariant, per batch);
  * the delta-tile invariants hold on the mutated partition — tombstones
    repointed at the sink, the CSR position index partitions the live
    edge set exactly, degree buckets consistent with live degrees,
    `out_degree` aux matching the live columns;
  * the affected-seed set is a sound superset: every vertex whose final
    value moved is either reset at warm start or reachable from a seeded
    vertex over the mutated live edges (nothing outside the seeds'
    influence cone may change, else the warm run silently depended on
    stale scatter state).

Each hypothesis test has a fixed-seed twin so the suite still runs where
`hypothesis` is absent (same pattern as tests/test_conformance.py).
"""
import numpy as np
import pytest

from repro.core import algorithms
from repro.core.engine import DevicePartition, GREEngine
from repro.graph.generators import circulant_graph, rmat_edges
from repro.graph.structures import EdgeDelta


def _graph(kind, scale, edge_factor, seed):
    if kind == "circulant":
        return circulant_graph(1 << scale, degree=edge_factor, weights=True,
                               seed=seed)
    return rmat_edges(scale=scale, edge_factor=edge_factor, seed=seed,
                      weights=True).dedup()


def _random_delta(g, rng):
    """One churn batch: add-only, remove-only, or mixed (interleaved over
    the sequence).  Add weights are small integers — exact in f32."""
    mode = rng.integers(0, 3)   # 0 = mixed, 1 = add-only, 2 = remove-only
    n = g.num_vertices
    n_add = 0 if mode == 2 else int(rng.integers(1, max(2, g.num_edges // 8)))
    n_rem = 0 if mode == 1 else int(rng.integers(1, max(2, g.num_edges // 8)))
    n_rem = min(n_rem, g.num_edges - 1)   # never empty the graph
    pick = rng.choice(g.num_edges, size=n_rem, replace=False)
    add_s = rng.integers(0, n, size=n_add)
    add_d = rng.integers(0, n, size=n_add)
    if n_add:   # in-batch duplicate (src, dst) rows are rejected by ingress
        _, first = np.unique(add_s.astype(np.int64) * n + add_d,
                             return_index=True)
        keep = np.sort(first)
        add_s, add_d = add_s[keep], add_d[keep]
    props = {k: rng.integers(1, 100, size=add_s.size).astype(np.float32)
             for k in g.edge_props}
    return EdgeDelta(add_src=add_s, add_dst=add_d, add_props=props,
                     rem_src=np.asarray(g.src)[pick],
                     rem_dst=np.asarray(g.dst)[pick])


def check_tile_invariants(part):
    """The delta-tile contract any mutation sequence must preserve."""
    n, slots = part.num_masters, part.num_slots
    sink = n
    src, dst = np.asarray(part.src), np.asarray(part.dst)
    mask = np.asarray(part.edge_mask)
    # tombstones + padding: BOTH endpoints repointed at the identity sink
    assert np.all(src[~mask] == sink) and np.all(dst[~mask] == sink)
    # live edges reference master slots only
    assert np.all(src[mask] < n) and np.all(dst[mask] < n)
    if part.edges_sorted_by_dst:
        assert np.all(np.diff(dst[mask]) >= 0), "dst-sort contract broken"
    # the CSR position index partitions the live set EXACTLY: each slot's
    # range reads its own live out-edges, every live edge appears once
    indptr = np.asarray(part.csr_indptr)
    eidx = np.asarray(part.csr_eidx)
    total = int(indptr[-1])
    assert total == int(mask.sum())
    seen = eidx[:total]
    assert np.array_equal(np.sort(seen), np.flatnonzero(mask))
    for v in range(slots):
        rows = eidx[indptr[v]:indptr[v + 1]]
        assert np.all(src[rows] == v) and np.all(mask[rows])
    # degree bounds: static facets are upper bounds on live degrees
    deg = np.diff(indptr)
    assert (deg.max() if deg.size else 0) <= part.csr_max_deg
    bid = np.asarray(part.bucket_id)
    assert np.array_equal(bid >= 0, deg[:slots] > 0)
    for b, (cap, mdeg) in enumerate(zip(part.bucket_sizes,
                                        part.bucket_max_deg)):
        members = np.flatnonzero(bid == b)
        assert members.size <= cap
        if members.size:
            assert deg[members].max() <= mdeg
    # aux out-degree tracks the live columns
    want = np.bincount(src[mask], minlength=slots)[:n]
    assert np.array_equal(np.asarray(part.aux["out_degree"]),
                          want.astype(np.float32))


def _reach(n, src, dst, seeds):
    r = seeds.copy()
    while True:
        nxt = r.copy()
        np.logical_or.at(nxt, dst, r[src])
        if np.array_equal(nxt, r):
            return r
        r = nxt


def _check_mutation_sequence(kind, scale, edge_factor, seed, batches=3):
    g = _graph(kind, scale, edge_factor, seed)
    prog = algorithms.sssp_program()
    eng = GREEngine(prog, frontier="auto", frontier_cap=32)
    ref_eng = GREEngine(prog)   # cold-recompute reference, dense scan
    part = DevicePartition.from_graph(g, edge_slack=16)
    state = eng.run(part, eng.init_state(part, source=0), 300)
    rng = np.random.default_rng(seed + 7)
    for _ in range(batches):
        delta = _random_delta(g, rng)
        g = g.apply_edge_delta(delta)
        new_part, report = part.apply_edge_delta(delta)
        check_tile_invariants(new_part)
        prev_vd = np.asarray(state.vertex_data)
        wstate = eng.warm_start_state(new_part, state, report, source=0)
        warm_init = np.asarray(wstate.vertex_data)
        n = new_part.num_masters
        seeds = np.asarray(wstate.active_scatter)[:n]
        out = eng.run(new_part, wstate, 300)
        warm = np.asarray(out.vertex_data)
        # 1. incremental == from-scratch, bitwise, after EVERY batch
        ref_part = DevicePartition.from_graph(g)
        cold = np.asarray(ref_eng.run(
            ref_part, ref_eng.init_state(ref_part, source=0), 300
        ).vertex_data)
        np.testing.assert_array_equal(warm, cold)
        # 2. affected seeds cover the changed vertices: anything that moved
        #    was reset at warm start or sits in a seed's influence cone
        if report.num_adds:
            assert seeds[np.unique(report.added_src)].all()
        lsrc = np.asarray(new_part.src)[np.asarray(new_part.edge_mask)]
        ldst = np.asarray(new_part.dst)[np.asarray(new_part.edge_mask)]
        cone = _reach(n, lsrc.astype(np.int64), ldst.astype(np.int64),
                      seeds.astype(bool))
        changed = warm != prev_vd
        assert not np.any(changed & ~cone & ~(warm_init != prev_vd))
        part, state = new_part, out


# --------------------------------------------------------- fixed-seed twins
@pytest.mark.parametrize("kind", ["rmat", "circulant"])
def test_mutation_sequence_fixed(kind):
    _check_mutation_sequence(kind, 6, 4, seed=3)


def test_empty_delta_is_noop():
    """A delta with nothing in it must re-converge in zero supersteps and
    leave the fixed point untouched (the warm seed set is empty)."""
    g = _graph("rmat", 6, 4, 3)
    eng = GREEngine(algorithms.sssp_program())
    part = DevicePartition.from_graph(g)
    state = eng.run(part, eng.init_state(part, source=0), 300)
    new_part, out, report = eng.rerun_incremental(
        part, state, EdgeDelta(), source=0)
    assert report.num_adds == 0 and report.num_removed == 0
    assert not report.compacted
    np.testing.assert_array_equal(np.asarray(out.vertex_data),
                                  np.asarray(state.vertex_data))


def test_slack_append_in_place_then_compact():
    """Adds consume slack WITHOUT regrowing the padded edge length (no
    recompile); once the slack is exhausted the partition compacts with
    x1.25 headroom and flags it in the report."""
    g = _graph("rmat", 6, 4, 3)
    part = DevicePartition.from_graph(g, edge_slack=8)
    e_pad = int(np.asarray(part.src).shape[0])
    rng = np.random.default_rng(0)
    small = EdgeDelta(
        add_src=rng.integers(0, g.num_vertices, size=8),
        add_dst=rng.integers(0, g.num_vertices, size=8),
        add_props={"weight": np.ones(8, np.float32)})
    p2, r2 = part.apply_edge_delta(small)
    assert not r2.compacted
    assert int(np.asarray(p2.src).shape[0]) == e_pad   # same static shape
    check_tile_invariants(p2)
    p3, r3 = p2.apply_edge_delta(small)                # slack now exhausted
    assert r3.compacted
    assert int(np.asarray(p3.src).shape[0]) > e_pad
    assert int(np.asarray(p3.src).shape[0]) % 8 == 0
    check_tile_invariants(p3)


def test_tombstones_identity_pinned():
    """Removal without compaction: the padded length is unchanged and the
    retired rows are repointed at the sink so even mask-blind scans
    (dense frontier) deliver identity messages only."""
    g = _graph("rmat", 6, 4, 3)
    part = DevicePartition.from_graph(g)
    rng = np.random.default_rng(1)
    pick = rng.choice(g.num_edges, size=10, replace=False)
    delta = EdgeDelta(rem_src=np.asarray(g.src)[pick],
                      rem_dst=np.asarray(g.dst)[pick])
    p2, rep = part.apply_edge_delta(delta)
    assert rep.num_removed == 10 and not rep.compacted
    assert np.asarray(p2.src).shape == np.asarray(part.src).shape
    assert int(np.asarray(p2.edge_mask).sum()) == g.num_edges - 10
    check_tile_invariants(p2)


def test_unsupported_programs_refuse_warm_start():
    """sum+halts traversals (forward-push PPR) have no sound warm start —
    delivered residual mass cannot be re-attributed — and halting min
    programs without an invalidation policy cannot absorb REMOVALS.
    Both must refuse loudly instead of converging to a wrong fixed
    point."""
    g = _graph("rmat", 6, 4, 3)
    part = DevicePartition.from_graph(g)
    pick = np.asarray([0])
    rem = EdgeDelta(rem_src=np.asarray(g.src)[pick],
                    rem_dst=np.asarray(g.dst)[pick])
    eng = GREEngine(algorithms.ppr_push_program(2), frontier="dense")
    state = eng.init_state(part, source=[0, 1])
    with pytest.raises(ValueError, match="warm"):
        eng.rerun_incremental(part, state, EdgeDelta(), source=[0, 1])
    import dataclasses as dc
    stripped = dc.replace(algorithms.bfs_program(), invalidation=None)
    eng2 = GREEngine(stripped)
    st2 = eng2.run(part, eng2.init_state(part, source=0), 300)
    with pytest.raises(ValueError, match="invalidation"):
        eng2.rerun_incremental(part, st2, rem, source=0)
    # adds-only is fine without an invalidation policy
    add = EdgeDelta(add_src=[1], add_dst=[2],
                    add_props={"weight": [1.0]})
    _, out, _ = eng2.rerun_incremental(part, st2, add, source=0)
    assert np.isfinite(np.asarray(out.vertex_data)).any()


def test_pagerank_warm_start_converges_close():
    """Iterative dense-frontier programs (PageRank) warm-start by carrying
    the previous values verbatim — no invalidation needed, every vertex
    re-scatters — and must land within tolerance of the cold run (power
    iteration's fixed point is attracting, not bitwise-path-stable)."""
    g = _graph("rmat", 6, 4, 3)
    prog = algorithms.pagerank_program()
    eng = GREEngine(prog, frontier="dense")
    part = DevicePartition.from_graph(g)
    state = eng.run(part, eng.init_state(part), 50)
    rng = np.random.default_rng(2)
    pick = rng.choice(g.num_edges, size=6, replace=False)
    delta = EdgeDelta(add_src=rng.integers(0, g.num_vertices, size=6),
                      add_dst=rng.integers(0, g.num_vertices, size=6),
                      add_props={"weight": np.ones(6, np.float32)},
                      rem_src=np.asarray(g.src)[pick],
                      rem_dst=np.asarray(g.dst)[pick])
    new_part, out, _ = eng.rerun_incremental(part, state, delta, max_steps=50)
    ref_part = DevicePartition.from_graph(g.apply_edge_delta(delta))
    cold = np.asarray(eng.run(ref_part, eng.init_state(ref_part), 50)
                      .vertex_data)
    np.testing.assert_allclose(np.asarray(out.vertex_data), cold,
                               rtol=0, atol=2e-3)


# ------------------------------------------------- delta ingress validation
def _apply_paths(g):
    """The three delta-ingress surfaces that must agree: the immutable
    Graph rebuild, the single-shard padded tiles, and the distributed
    Agent-Graph — each validates the SAME contract up front."""
    from repro.core.agent_graph import apply_edge_delta as ag_apply
    from repro.core.agent_graph import build_agent_graph
    from repro.core.partition import greedy_partition
    ag = build_agent_graph(g, greedy_partition(g, 2, batch_size=16), 2)
    return {
        "graph": lambda d: g.apply_edge_delta(d),
        "part": lambda d: DevicePartition.from_graph(g).apply_edge_delta(d),
        "agent": lambda d: ag_apply(ag, d),
    }


@pytest.mark.parametrize("path", ["graph", "part", "agent"])
def test_delta_rejects_out_of_range_ids(path):
    """Vertex ids outside [0, V) in ANY of the four id arrays must raise a
    ValueError naming the offending rows — before any state is touched.
    (The old ingress only asserted on add ids, and on the padded-tile
    path an out-of-range REMOVAL id silently matched nothing.)"""
    g = _graph("rmat", 6, 4, 3)
    n = g.num_vertices
    apply = _apply_paths(g)[path]
    bad_add = EdgeDelta(add_src=[1, n], add_dst=[2, 3],
                        add_props={"weight": [1.0, 1.0]})
    with pytest.raises(ValueError, match=r"add_src.*out-of-range.*rows"
                                         r".*\[1\]"):
        apply(bad_add)
    neg = EdgeDelta(add_src=[1], add_dst=[-2],
                    add_props={"weight": [1.0]})
    with pytest.raises(ValueError, match="add_dst.*out-of-range"):
        apply(neg)
    bad_rem = EdgeDelta(rem_src=[int(g.src[0])], rem_dst=[n + 7])
    with pytest.raises(ValueError, match="rem_dst.*out-of-range"):
        apply(bad_rem)


@pytest.mark.parametrize("path", ["graph", "part", "agent"])
def test_delta_rejects_duplicate_add_rows(path):
    """The same (src, dst) pair twice in ONE batch is ambiguous (which
    row's props win?) and must be rejected with the duplicate rows named.
    Multi-edges built across SEPARATE batches stay legal."""
    g = _graph("rmat", 6, 4, 3)
    apply = _apply_paths(g)[path]
    dup = EdgeDelta(add_src=[4, 5, 4], add_dst=[9, 9, 9],
                    add_props={"weight": [1.0, 2.0, 3.0]})
    with pytest.raises(ValueError, match=r"repeats.*rows.*\[2\]"):
        apply(dup)


def test_delta_multi_edge_across_batches_still_legal():
    """Positive control for the duplicate check: applying the SAME add
    batch twice builds a legal multi-edge — only in-batch repeats raise."""
    g = _graph("rmat", 6, 4, 3)
    one = EdgeDelta(add_src=[4], add_dst=[9], add_props={"weight": [1.0]})
    g2 = g.apply_edge_delta(one).apply_edge_delta(one)
    assert g2.num_edges == g.num_edges + 2
    part = DevicePartition.from_graph(g, edge_slack=8)
    p2, _ = part.apply_edge_delta(one)
    p3, _ = p2.apply_edge_delta(one)
    assert int(np.asarray(p3.edge_mask).sum()) == g.num_edges + 2


@pytest.mark.parametrize("path", ["graph", "part", "agent"])
def test_delta_rejects_removal_of_dead_edge(path):
    """A removal row matching no live edge (never present, or already
    tombstoned by an earlier batch) must raise with the rows and pairs
    named — silently matching nothing desynchronizes replicas that DID
    hold the edge."""
    g = _graph("rmat", 6, 4, 3)
    apply = _apply_paths(g)[path]
    live = set(zip(np.asarray(g.src).tolist(), np.asarray(g.dst).tolist()))
    s, d = next((a, b) for a in range(g.num_vertices)
                for b in range(g.num_vertices) if (a, b) not in live)
    ghost = EdgeDelta(rem_src=[int(g.src[0]), s],
                      rem_dst=[int(g.dst[0]), d])
    with pytest.raises(ValueError, match=r"rows \[1\] match no live edge"):
        apply(ghost)


def test_delta_validation_identical_across_paths():
    """The distributed path must reject exactly what the single-shard
    path rejects, with the SAME message — divergent validation is how
    shards drift."""
    g = _graph("rmat", 6, 4, 3)
    paths = _apply_paths(g)
    n = g.num_vertices
    deltas = [
        EdgeDelta(add_src=[1, n + 3], add_dst=[2, 3],
                  add_props={"weight": [1.0, 1.0]}),
        EdgeDelta(add_src=[4, 4], add_dst=[9, 9],
                  add_props={"weight": [1.0, 2.0]}),
    ]
    for delta in deltas:
        msgs = set()
        for name, apply in paths.items():
            with pytest.raises(ValueError) as ei:
                apply(delta)
            msgs.add(str(ei.value))
        assert len(msgs) == 1, msgs


def test_delta_already_tombstoned_edge_rejected_on_second_removal():
    """Padded-tile sequence: removing an edge, then removing it again in a
    later batch, must fail the second time (it is no longer live)."""
    g = _graph("rmat", 6, 4, 3)
    part = DevicePartition.from_graph(g)
    rem = EdgeDelta(rem_src=[int(g.src[0])], rem_dst=[int(g.dst[0])])
    p2, rep = part.apply_edge_delta(rem)
    assert rep.num_removed >= 1
    with pytest.raises(ValueError, match="no live edge"):
        p2.apply_edge_delta(rem)


# ------------------------------------------------------- hypothesis sweep
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(kind=st.sampled_from(["rmat", "circulant"]),
           scale=st.integers(5, 6), edge_factor=st.integers(2, 6),
           seed=st.integers(0, 999))
    def test_mutation_sequence_random(kind, scale, edge_factor, seed):
        _check_mutation_sequence(kind, scale, edge_factor, seed)
