"""Pipelined exchange == synchronous exchange, on every benchmark program.

Equivalence contract (docs/exchange.md): the pipelined schedule defers the
merge of remote ⊕ partials to the top of the next superstep but folds the
SAME partials — min-monoid traversal (BFS/SSSP/CC) must be BITWISE
identical to the synchronous backends and the single-shard engine;
sum-monoid (PageRank) agrees to float tolerance across backends (the
two-stage ⊕ reorders float adds), and bitwise against the synchronous
AgentExchange (the edge tiles preserve per-segment reduction order).

The in-process tests run the full pipelined machinery — `split_edge_tiles`,
`PipelinedAgentExchange`, the plan executor's deferred-merge loop
(`repro.core.plan.execute_plan`) under `shard_map` — on a 1-device mesh
(remote tile empty, flush collective degenerate).  The
multi-shard case needs the 8-device XLA_FLAGS set before jax initializes,
so it runs in a subprocess (slow suite), exercising real cross-shard
flushes and multi-source vector payloads; pipelined x frontier-strategy
rows live in the `tests/test_conformance.py` matrix.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.core import algorithms
from repro.core.agent_graph import build_agent_graph, split_edge_tiles
from repro.core.dist_engine import DistGREEngine
from repro.core.engine import DevicePartition, GREEngine
from repro.core.partition import greedy_partition, hash_partition
from repro.graph.generators import rmat_edges

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _single_shard(program, g, source=None, max_steps=300):
    part = DevicePartition.from_graph(g)
    eng = GREEngine(program)
    st = eng.run(part, eng.init_state(part, source=source), max_steps)
    return np.asarray(st.vertex_data)


def _pipelined(program, g, source=None, max_steps=300, **kw):
    ag = build_agent_graph(g, greedy_partition(g, 1, batch_size=64), 1)
    mesh = jax.make_mesh((1,), ("graph",))
    eng = DistGREEngine(program, mesh, ("graph",), exchange="pipelined", **kw)
    out, _ = eng.run(ag, source=source, max_steps=max_steps)
    return out


def _fix(x):
    return np.nan_to_num(x, posinf=-1.0)


# --------------------------------------------------------- edge-tile split
def test_split_edge_tiles_partitions_every_real_edge():
    """Remote + local tiles cover the edge shard exactly once, destinations
    relabeled into the compact combiner/master spaces."""
    g = rmat_edges(scale=7, edge_factor=8, seed=2).dedup()
    k = 4
    ag = build_agent_graph(g, hash_partition(g, k), k)
    split = split_edge_tiles(ag)
    remote, local = split.remote, split.local
    for i in range(k):
        n_r = int(remote.mask[i].sum())
        n_l = int(local.mask[i].sum())
        assert n_r + n_l == int(ag.edge_mask[i].sum())
        assert (remote.dst[i][remote.mask[i]] < ag.c_pad).all()
        assert (local.dst[i][local.mask[i]] < ag.cap).all()
        # padding lands on each tile's identity slot
        assert (remote.dst[i][~remote.mask[i]] == ag.c_pad).all()
        assert (local.dst[i][~local.mask[i]] == ag.cap).all()
        # tiles keep the canonical dst-sorted order (bitwise-sum contract)
        assert (np.diff(remote.dst[i]) >= 0).all()
        assert (np.diff(local.dst[i]) >= 0).all()
    assert 0.0 < split.remote_fraction < 1.0


def test_split_remote_fraction_matches_partition_quality():
    """With a shared owner vector (build_agent_graph additionally rebalances
    overflowing partitions), the ingress split's remote fraction IS the
    partition-quality metric."""
    from repro.core.partition import (assign_owners, partition_quality,
                                     rebalance_owners)
    g = rmat_edges(scale=7, edge_factor=8, seed=3).dedup()
    k = 4
    edge_part = hash_partition(g, k)
    cap = -(-g.num_vertices // k)          # masters per partition,
    cap = -(-cap // 8) * 8                 # padded as in build_agent_graph
    owner = rebalance_owners(assign_owners(g, edge_part, k), k, cap)
    ag = build_agent_graph(g, edge_part, k, owner=owner)
    split = split_edge_tiles(ag)
    q = partition_quality(g, edge_part, owner=owner, k=k)
    assert split.remote_fraction == pytest.approx(
        q.remote_dst_edge_fraction, abs=1e-9)


# ----------------------------------------- pipelined vs single-shard (k=1)
def test_sssp_pipelined_bitwise():
    g = rmat_edges(scale=7, edge_factor=8, seed=4, weights=True).dedup()
    ref = _single_shard(algorithms.sssp_program(), g, source=0)
    got = _pipelined(algorithms.sssp_program(), g, source=0)
    np.testing.assert_array_equal(_fix(got), _fix(ref))


def test_cc_pipelined_bitwise():
    g = rmat_edges(scale=6, edge_factor=8, seed=5).dedup().as_undirected()
    ref = _single_shard(algorithms.cc_program(), g)
    got = _pipelined(algorithms.cc_program(), g)
    np.testing.assert_array_equal(got, ref)


def test_pagerank_pipelined_close():
    g = rmat_edges(scale=7, edge_factor=8, seed=6).dedup()
    ref = _single_shard(algorithms.pagerank_program(), g, max_steps=20)
    got = _pipelined(algorithms.pagerank_program(), g, max_steps=20)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


def test_bfs_multi_source_pipelined_bitwise():
    g = rmat_edges(scale=6, edge_factor=8, seed=7).dedup()
    sources = [0, 5, 17]
    ref = np.stack([_single_shard(algorithms.bfs_program(), g, source=s)
                    for s in sources], axis=1)
    got = _pipelined(algorithms.bfs_program(num_sources=3), g,
                     source=sources)
    np.testing.assert_array_equal(_fix(got), _fix(ref))


# Pipelined x frontier-strategy equivalence (incl. the compacted gather on
# the split tiles and random power-law sweeps) lives in the systematic
# matrix of tests/test_conformance.py.


# ------------------------------------------------- multi-shard (subprocess)
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "__SRC__")
import numpy as np
import jax

from repro.graph.generators import rmat_edges
from repro.core.engine import GREEngine, DevicePartition
from repro.core.partition import hash_partition
from repro.core.agent_graph import build_agent_graph, split_edge_tiles
from repro.core.dist_engine import DistGREEngine
from repro.core import algorithms

k = 8
g = rmat_edges(scale=8, edge_factor=8, seed=5, weights=True).dedup()
# hash partition: high remote-edge fraction, the pipelined flush's regime
edge_part = hash_partition(g, k)
ag = build_agent_graph(g, edge_part, k)
assert split_edge_tiles(ag).remote_fraction > 0.3
mesh = jax.make_mesh((8,), ("graph",))
sp = DevicePartition.from_graph(g)

failures = []

def sync_vs_pipelined(program, agraph, source=None, max_steps=300, **kw):
    outs = {}
    for mode in ("agent", "pipelined"):
        eng = DistGREEngine(program, mesh, ("graph",), exchange=mode, **kw)
        outs[mode], _ = eng.run(agraph, source=source, max_steps=max_steps)
    return outs["agent"], outs["pipelined"]

fix = lambda x: np.nan_to_num(x, posinf=-1.0)

# SSSP: bitwise across sync/pipelined AND vs the single-shard engine.
se = GREEngine(algorithms.sssp_program())
ref = np.asarray(se.run(sp, se.init_state(sp, source=0), 300).vertex_data)
sync, pipe = sync_vs_pipelined(algorithms.sssp_program(), ag, source=0)
if not np.array_equal(fix(pipe), fix(sync)):
    failures.append("sssp pipelined != sync agent")
if not np.array_equal(fix(pipe), fix(ref)):
    failures.append("sssp pipelined != single-shard")

# (compact-frontier x pipelined rows live in test_conformance.py's matrix)

# PageRank: bitwise vs sync agent (tiles preserve per-segment float-add
# order), tolerance vs single shard (two-stage vs one-stage ⊕).
pe = GREEngine(algorithms.pagerank_program())
pref = np.asarray(pe.run(sp, pe.init_state(sp), 20).vertex_data)
sync, pipe = sync_vs_pipelined(algorithms.pagerank_program(), ag,
                               max_steps=20)
if not np.array_equal(pipe, sync):
    failures.append("pagerank pipelined != sync agent (bitwise)")
if not np.allclose(pipe, pref, rtol=1e-5, atol=1e-6):
    failures.append("pagerank pipelined != single-shard (tolerance)")

# Multi-source batched BFS: (D,) payloads through the pipelined flush.
D, sources = 4, [0, 7, 33, 101]
sync, pipe = sync_vs_pipelined(algorithms.bfs_program(num_sources=D), ag,
                               source=sources, max_steps=100)
if not np.array_equal(fix(pipe), fix(sync)):
    failures.append("bfs multi-source pipelined != sync agent")

# CC on the undirected graph.
gu = g.as_undirected().dedup()
agu = build_agent_graph(gu, hash_partition(gu, k), k)
spu = DevicePartition.from_graph(gu)
ce = GREEngine(algorithms.cc_program())
cref = np.asarray(ce.run(spu, ce.init_state(spu), 300).vertex_data)
sync, pipe = sync_vs_pipelined(algorithms.cc_program(), agu)
if not np.array_equal(pipe, sync) or not np.array_equal(pipe, cref):
    failures.append("cc pipelined mismatch")

assert not failures, failures
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipelined_multi_shard_agrees(tmp_path):
    script = tmp_path / "pipeline_check.py"
    script.write_text(SCRIPT.replace("__SRC__", SRC))
    proc = subprocess.run([sys.executable, str(script)], capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "PIPELINE_OK" in proc.stdout
