"""Continuous-batching scheduler: correctness vs offline generation."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.train import reduced_lm_config
from repro.configs import get_config
from repro.models import transformer as tfm
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def model():
    cfg, _ = get_config("smollm-135m")
    cfg = reduced_lm_config(cfg, layers=2, d_model=64, n_heads=4, n_kv=2,
                            d_head=16, d_ff=96, vocab=256)
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _offline_greedy(params, cfg, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        logits, _ = tfm.lm_forward(params, jnp.asarray([toks]), cfg)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_scheduler_matches_offline_generation(model):
    params, cfg = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, size=l).astype(np.int32)
               for l in (5, 9, 7)]
    sched = ContinuousBatcher(params, cfg, batch_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    sched.run()
    for r, p in zip(reqs, prompts):
        assert r.done and len(r.out) == 6
        want = _offline_greedy(params, cfg, p.tolist(), 6)
        assert r.out == want, (r.uid, r.out, want)


def test_scheduler_more_requests_than_slots(model):
    params, cfg = model
    rng = np.random.default_rng(1)
    sched = ContinuousBatcher(params, cfg, batch_slots=2, max_len=24)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, size=4).astype(np.int32),
                    max_new=3) for i in range(5)]
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)


# ---------------- graph serving: continuous batching over payload lanes ----
import subprocess
import sys
from pathlib import Path

from repro.core import algorithms
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core.engine import DevicePartition, GREEngine
from repro.core.partition import greedy_partition
from repro.graph.generators import circulant_graph, rmat_edges
from repro.serving import GraphQueryBatcher, ServingFrontend, poisson_ticks

SRC = str(Path(__file__).resolve().parent.parent / "src")
D = 4
GRAPH_BACKENDS = ("null", "agent", "pipelined")


@pytest.fixture(scope="module")
def rmat():
    return rmat_edges(scale=8, edge_factor=6, seed=3, weights=True).dedup()


def _graph_batcher(backend, program, g, **kw):
    """Serving stack on one of the three in-process backends: the
    single-shard engine, or the 1-device mesh with the sync / pipelined
    Agent-Graph exchanges (the same surfaces the conformance matrix
    locks down)."""
    if backend == "null":
        eng = GREEngine(program, **kw)
        return GraphQueryBatcher(eng, DevicePartition.from_graph(g))
    ag = build_agent_graph(g, greedy_partition(g, 1, batch_size=64), 1)
    mesh = jax.make_mesh((1,), ("graph",))
    eng = DistGREEngine(program, mesh, ("graph",), exchange=backend, **kw)
    return GraphQueryBatcher(eng, ag)


def _fix(x):
    return np.nan_to_num(x, posinf=-1.0)


def test_lazy_import_without_models():
    """`import repro.serving` must not drag in the transformer stack —
    the graph scheduler serves without the models extras (the LM batcher
    resolves lazily on attribute access)."""
    code = (
        "import sys; import repro.serving\n"
        "assert 'repro.models.transformer' not in sys.modules, 'eager LM'\n"
        "assert repro.serving.GraphQueryBatcher is not None\n"
        "from repro.serving import ContinuousBatcher\n"
        "assert 'repro.models.transformer' in sys.modules\n"
    )
    subprocess.run([sys.executable, "-c", code], check=True,
                   env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                        "JAX_PLATFORMS": "cpu"})


def test_lane_masked_seeding(rmat):
    """None entries leave their lanes unseeded: identity state, inactive
    halt bit — the admission substrate."""
    eng = GREEngine(algorithms.bfs_program(D))
    part = DevicePartition.from_graph(rmat)
    st = eng.init_state(part, source=[5, None, None, 9], lane_tracking=True)
    vd = np.asarray(st.vertex_data)
    assert vd[5, 0] == 0.0 and vd[9, 3] == 0.0
    assert np.all(np.isinf(vd[:, 1])) and np.all(np.isinf(vd[:, 2]))
    assert np.asarray(st.lane_active).tolist() == [True, False, False, True]


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_recycled_lane_bitwise_equals_fresh(backend, rmat):
    """THE recycling invariant: a query answered in a recycled lane (with
    unrelated queries running in neighbor lanes) is bit-identical to the
    same query served alone in a fresh batcher."""
    sources = [0, 3, 17, 42, 99, 7, 55, 123]
    b = _graph_batcher(backend, algorithms.bfs_program(D), rmat)
    for s in sources:
        b.submit(s)
    done = b.run()
    assert [q.status for q in done] == ["done"] * len(sources)
    assert len({q.uid for q in done}) == len(sources)
    for q in done:
        fresh = _graph_batcher(backend, algorithms.bfs_program(D), rmat)
        fresh.submit(q.source)
        (ref,) = fresh.run()
        assert np.array_equal(_fix(ref.result), _fix(q.result)), q.uid


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_unconverged_lane_never_retired(backend):
    """Per-lane halt must not fire early: a long-diameter BFS (circulant
    ring) retires only after >= eccentricity supersteps, with the full
    correct depth map."""
    n = 128
    g = circulant_graph(n, degree=2, weights=True, seed=0)
    b = _graph_batcher(backend, algorithms.bfs_program(D), g)
    q = b.submit(0)
    b.run()
    assert q.status == "done"
    depths = _fix(q.result)
    # ring of ±1 and ±2 offsets: depth grows to ~n/4; the lane must have
    # stayed resident for at least the graph's eccentricity many supersteps
    ecc = int(depths.max())
    assert ecc > 10
    assert q.supersteps_used >= ecc
    fresh = _graph_batcher(backend, algorithms.bfs_program(D), g)
    fresh.submit(0)
    (ref,) = fresh.run()
    assert np.array_equal(_fix(ref.result), depths)


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_budget_eviction_keeps_neighbors_intact(backend):
    """A query that exhausts its superstep budget is marked evicted (no
    result) and its lane reset — WITHOUT corrupting queries running in
    the other lanes."""
    n = 128
    g = circulant_graph(n, degree=2, weights=True, seed=0)
    b = _graph_batcher(backend, algorithms.bfs_program(D), g)
    victims = [b.submit(s) for s in (0, 31)]
    doomed = b.submit(64, max_supersteps=3)      # ring ecc >> 3
    late = b.submit(97)                          # recycles the evicted lane
    b.run()
    assert doomed.status == "evicted" and doomed.result is None
    for q in victims + [late]:
        assert q.status == "done"
        fresh = _graph_batcher(backend, algorithms.bfs_program(D), g)
        fresh.submit(q.source)
        (ref,) = fresh.run()
        assert np.array_equal(_fix(ref.result), _fix(q.result)), q.uid


def test_ppr_recycling_bitwise(rmat):
    """The sum-monoid traversal: forward-push PPR lanes recycle bitwise
    too (the admit path normalizes stale scatter rows — a re-activated
    vertex must not re-deliver already-delivered residual shares)."""
    prog = algorithms.ppr_push_program(D)
    b = _graph_batcher("null", prog, rmat, frontier="dense")
    sources = [0, 3, 17, 42, 99, 8]
    for s in sources:
        b.submit(s)
    done = b.run()
    assert [q.status for q in done] == ["done"] * len(sources)
    for q in done:
        fresh = _graph_batcher("null", prog, rmat, frontier="dense")
        fresh.submit(q.source)
        (ref,) = fresh.run()
        assert np.array_equal(ref.result, q.result), q.uid
        assert ref.result[q.source] > 0


def test_serving_never_recompiles(rmat):
    """The whole point of sentinel-indexed admission: a long stream with
    many admissions/retirements compiles the tick and the admit exactly
    once each."""
    b = _graph_batcher("null", algorithms.bfs_program(D), rmat)
    rng = np.random.default_rng(0)
    for s in rng.integers(0, rmat.num_vertices, size=16):
        b.submit(int(s))
    done = b.run()
    assert len(done) == 16
    for fn in (b._tick_fn, b._admit_fn):
        if hasattr(fn, "_cache_size"):
            assert fn._cache_size() == 1


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
@pytest.mark.parametrize("policy", ("finish", "reseed"))
def test_apply_delta_mid_flight_never_torn(backend, policy):
    """Serving under mutation: a delta landing while a query is mid-flight
    must never produce a torn result.  Under "finish" the resident
    completes on the pre-delta snapshot; under "reseed" it restarts on the
    mutated graph — either way its answer is bitwise-equal to a fresh
    single-query run on the corresponding snapshot, and queries admitted
    after the delta see the mutated graph."""
    from repro.graph.structures import EdgeDelta
    n = 128
    g = circulant_graph(n, degree=2, weights=True, seed=0)
    delta = EdgeDelta(add_src=[0, 64], add_dst=[64, 0],
                      add_props={"weight": [1.0, 1.0]},
                      rem_src=[10, 11], rem_dst=[11, 13])
    g2 = g.apply_edge_delta(delta)
    prog = algorithms.bfs_program(D)
    b = _graph_batcher(backend, prog, g)
    q_old = b.submit(0)                  # resident when the delta lands
    b.pump()
    for _ in range(3):                   # mid-flight (ring ecc >> 3)
        b.tick()
    b.apply_delta(delta, policy=policy)
    q_new = b.submit(5)                  # admitted after the delta
    b.run()
    assert q_old.status == "done" and q_new.status == "done"
    resident_snapshot = g if policy == "finish" else g2
    f1 = _graph_batcher(backend, prog, resident_snapshot)
    f1.submit(0)
    (r1,) = f1.run()
    assert np.array_equal(_fix(r1.result), _fix(q_old.result))
    f2 = _graph_batcher(backend, prog, g2)
    f2.submit(5)
    (r2,) = f2.run()
    assert np.array_equal(_fix(r2.result), _fix(q_new.result))
    f3 = _graph_batcher(backend, prog, g)
    f3.submit(5)
    (r3,) = f3.run()
    assert not np.array_equal(_fix(r3.result), _fix(q_new.result)), \
        "delta invisible to post-delta admissions"


def test_apply_delta_holds_admissions_until_swap():
    """"finish"-policy semantics for QUEUED work: a query submitted while
    a delta is pending must not be admitted onto the pre-delta snapshot —
    it waits for the resident lanes to drain and runs on the mutated
    graph; an idle batcher swaps immediately."""
    from repro.graph.structures import EdgeDelta
    n = 128
    g = circulant_graph(n, degree=2, weights=True, seed=0)
    delta = EdgeDelta(add_src=[0, 64], add_dst=[64, 0],
                      add_props={"weight": [1.0, 1.0]},
                      rem_src=[10, 11], rem_dst=[11, 13])
    g2 = g.apply_edge_delta(delta)
    prog = algorithms.bfs_program(D)
    b = _graph_batcher("null", prog, g)
    qa = b.submit(0)
    b.pump()
    b.tick()
    b.apply_delta(delta)                 # default policy = "finish"
    assert b._pending_deltas             # resident lane holds the swap
    qb = b.submit(5)                     # queued during the pending delta
    b.run()
    assert not b._pending_deltas
    for q, snapshot, src in ((qa, g, 0), (qb, g2, 5)):
        f = _graph_batcher("null", prog, snapshot)
        f.submit(src)
        (r,) = f.run()
        assert np.array_equal(_fix(r.result), _fix(q.result)), q.uid
    # idle batcher: the swap happens inside apply_delta itself
    b2 = _graph_batcher("null", prog, g)
    b2.apply_delta(delta)
    assert not b2._pending_deltas


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_recycled_lane_after_delta_bitwise(backend, rmat):
    """The recycling invariant survives mutation: lanes recycled AFTER a
    delta landed answer bitwise-equal to fresh runs on the mutated
    graph — the rebuilt admit path resets lanes against the new
    topology's init state."""
    from repro.graph.structures import EdgeDelta
    rng = np.random.default_rng(7)
    pick = rng.choice(rmat.num_edges, size=8, replace=False)
    delta = EdgeDelta(
        add_src=rng.integers(0, rmat.num_vertices, size=8),
        add_dst=rng.integers(0, rmat.num_vertices, size=8),
        add_props={"weight": np.ones(8, np.float32)},
        rem_src=np.asarray(rmat.src)[pick],
        rem_dst=np.asarray(rmat.dst)[pick])
    g2 = rmat.apply_edge_delta(delta)
    prog = algorithms.bfs_program(D)
    b = _graph_batcher(backend, prog, rmat)
    b.apply_delta(delta)                 # idle: swaps immediately
    sources = [0, 3, 17, 42, 99, 7, 55, 123]   # 2 rounds of lane recycling
    for s in sources:
        b.submit(s)
    done = b.run()
    assert [q.status for q in done] == ["done"] * len(sources)
    for q in done:
        fresh = _graph_batcher(backend, prog, g2)
        fresh.submit(q.source)
        (ref,) = fresh.run()
        assert np.array_equal(_fix(ref.result), _fix(q.result)), q.uid


def test_percentile_matches_numpy_linear():
    """SLO metric regression: `_percentile` must agree with numpy's default
    linear-interpolation method at every batch size.  The nearest-rank
    shortcut it replaces rounded `q*(n-1)` to an index, so p95 over a
    20-sample window collapsed to the max and p50 over an even-length
    window picked one of the two middle samples instead of their mean."""
    from repro.serving.graph_scheduler import _percentile
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 19, 20, 100):
        vals = sorted(rng.normal(size=n).tolist())
        for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
            want = float(np.percentile(vals, q * 100.0, method="linear"))
            got = _percentile(vals, q)
            assert got == pytest.approx(want, rel=1e-12, abs=1e-12), (n, q)


def test_sum_monoid_serving_clamps_tuned_compact_plan(rmat, tmp_path):
    """Regression for the auto-tuned-plan / PPR-serving interaction: a plan
    tuned on a sparse-frontier scenario (where frontier compaction wins)
    can land on a sum-monoid serving engine via a `plan="auto-tuned"`
    cache hit or an explicit `adopt_plan`.  Compaction reorders the fp
    segment reduction by frontier occupancy — which depends on the OTHER
    queries sharing the batch — silently breaking recycled-lane bitwise
    equality.  The batcher must clamp such engines back to the dense
    frontier before any tick traces."""
    from repro.tuning import ProbeEvaluator, SMOKE_SPACE, tune

    class SparseWins(ProbeEvaluator):
        """Deterministic cost (no clocks): dense heavily penalized, so the
        tuner stores a compacted winner — the sparse-frontier scenario."""

        def evaluate(self, plan, probe_steps=2, iters=1):
            if plan.strategy == "dense":
                return 1e6
            return 1000.0 + float(plan.frontier_cap or 10 ** 5)

    scen_prog = algorithms.bfs_program()
    scen = circulant_graph(1 << 9, degree=8)
    res = tune(scen_prog, scen, cache=tmp_path / "plans.json",
               space=SMOKE_SPACE, evaluator=SparseWins(scen_prog, scen))
    assert res.plan.strategy != "dense" and not res.plan.dense_frontier

    prog = algorithms.ppr_push_program(D)
    eng = GREEngine(prog, plan=res.plan)   # what an auto-tuned hit adopts
    b = GraphQueryBatcher(eng, DevicePartition.from_graph(rmat))
    # the batcher clamped the compacted plan back to the dense frontier
    assert eng.frontier == "dense" and not eng.dense_frontier
    sources = [0, 3, 17, 42, 99, 8]
    for s in sources:
        b.submit(s)
    done = b.run()
    assert [q.status for q in done] == ["done"] * len(sources)
    for q in done:
        fresh = _graph_batcher("null", prog, rmat, frontier="dense")
        fresh.submit(q.source)
        (ref,) = fresh.run()
        assert np.array_equal(ref.result, q.result), q.uid


def test_metrics_and_frontend(rmat):
    """SLO metrics are populated and a mixed-kind frontend drains both
    batchers."""
    bfs = _graph_batcher("null", algorithms.bfs_program(D), rmat)
    ppr = _graph_batcher("null", algorithms.ppr_push_program(D), rmat,
                         frontier="dense")
    fe = ServingFrontend({"bfs": bfs, "ppr": ppr})
    rng = np.random.default_rng(1)
    ticks = poisson_ticks(10, rate_per_tick=2.0, rng=rng)
    assert (np.diff(ticks) >= 0).all()
    for i in range(10):
        fe.submit("bfs" if i % 2 else "ppr",
                  int(rng.integers(0, rmat.num_vertices)))
    done = fe.run()
    assert len(done) == 10 and all(q.status == "done" for q in done)
    m = fe.metrics()
    for kind in ("bfs", "ppr"):
        mm = m[kind]
        assert mm["queries_done"] == 5.0
        assert 0.0 < mm["lane_occupancy"] <= 1.0
        assert mm["latency_p95_s"] >= mm["latency_p50_s"] >= 0.0
        assert mm["supersteps_p50"] >= 1.0
        assert np.isfinite(mm["qps"]) and mm["qps"] > 0
