"""Continuous-batching scheduler: correctness vs offline generation."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.train import reduced_lm_config
from repro.configs import get_config
from repro.models import transformer as tfm
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def model():
    cfg, _ = get_config("smollm-135m")
    cfg = reduced_lm_config(cfg, layers=2, d_model=64, n_heads=4, n_kv=2,
                            d_head=16, d_ff=96, vocab=256)
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _offline_greedy(params, cfg, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        logits, _ = tfm.lm_forward(params, jnp.asarray([toks]), cfg)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_scheduler_matches_offline_generation(model):
    params, cfg = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, size=l).astype(np.int32)
               for l in (5, 9, 7)]
    sched = ContinuousBatcher(params, cfg, batch_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    sched.run()
    for r, p in zip(reqs, prompts):
        assert r.done and len(r.out) == 6
        want = _offline_greedy(params, cfg, p.tolist(), 6)
        assert r.out == want, (r.uid, r.out, want)


def test_scheduler_more_requests_than_slots(model):
    params, cfg = model
    rng = np.random.default_rng(1)
    sched = ContinuousBatcher(params, cfg, batch_slots=2, max_len=24)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, size=4).astype(np.int32),
                    max_new=3) for i in range(5)]
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)
