"""Frontier-compacted scatter vs the dense masked scan, and multi-source
payload batching vs independent single-source runs.

Equivalence contract (docs/frontier.md): for min-monoid
traversal programs the two strategies must produce BITWISE-identical
vertex_data — min is exactly associative/commutative, so even the segment
reduction order cannot leak through.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import algorithms
from repro.core.engine import DevicePartition, EngineState, GREEngine
from repro.graph.generators import circulant_graph, rmat_edges
from repro.graph.structures import Graph


def _run(program, part, source=None, frontier="auto", cap=None,
         max_steps=300):
    eng = GREEngine(program, frontier=frontier, frontier_cap=cap)
    out = eng.run(part, eng.init_state(part, source=source), max_steps)
    return np.asarray(out.vertex_data)


# ------------------------------------------------- dense == compact, exact
def _assert_strategies_agree(program, part, source=None, cap=None):
    dense = _run(program, part, source=source, frontier="dense")
    compact = _run(program, part, source=source, frontier="compact", cap=cap)
    np.testing.assert_array_equal(dense, compact)


def test_bfs_compact_matches_dense_power_law():
    g = rmat_edges(scale=8, edge_factor=8, seed=3).dedup()
    part = DevicePartition.from_graph(g)
    _assert_strategies_agree(algorithms.bfs_program(), part, source=0)


def test_sssp_compact_matches_dense_power_law():
    g = rmat_edges(scale=8, edge_factor=8, seed=4, weights=True).dedup()
    part = DevicePartition.from_graph(g)
    _assert_strategies_agree(algorithms.sssp_program(), part, source=0)


def test_cc_compact_matches_dense_power_law():
    g = rmat_edges(scale=7, edge_factor=8, seed=5).dedup().as_undirected()
    part = DevicePartition.from_graph(g)
    _assert_strategies_agree(algorithms.cc_program(), part)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(scale=st.integers(5, 7), edge_factor=st.integers(2, 8),
           seed=st.integers(0, 999), cap=st.sampled_from([None, 8, 64]),
           source=st.integers(0, 31))
    def test_traversal_strategies_bitwise_equal(scale, edge_factor, seed,
                                                cap, source):
        """Random power-law graphs, random capacities (including caps small
        enough to force mid-run overflow fallbacks): bitwise identical."""
        g = rmat_edges(scale=scale, edge_factor=edge_factor, seed=seed,
                       weights=True).dedup()
        part = DevicePartition.from_graph(g)
        _assert_strategies_agree(algorithms.bfs_program(), part,
                                 source=source, cap=cap)
        _assert_strategies_agree(algorithms.sssp_program(), part,
                                 source=source, cap=cap)

    @settings(max_examples=8, deadline=None)
    @given(scale=st.integers(5, 7), seed=st.integers(0, 999),
           cap=st.sampled_from([None, 16]))
    def test_cc_strategies_bitwise_equal(scale, seed, cap):
        g = rmat_edges(scale=scale, edge_factor=4,
                       seed=seed).dedup().as_undirected()
        part = DevicePartition.from_graph(g)
        _assert_strategies_agree(algorithms.cc_program(), part, cap=cap)


# --------------------------------------------------- overflow / star graph
def test_star_graph_overflow_falls_back_to_dense():
    """Hub activates EVERY leaf in one superstep — the frontier (V-1
    vertices) overflows any small capacity.  The guard must take the dense
    path for that superstep instead of silently dropping vertices."""
    n = 257
    src = np.zeros(n - 1, dtype=np.int64)
    dst = np.arange(1, n, dtype=np.int64)
    # leaves link back to the hub so the overflowing frontier also scatters
    g = Graph(n, np.concatenate([src, dst]), np.concatenate([dst, src]))
    part = DevicePartition.from_graph(g)
    depth = _run(algorithms.bfs_program(), part, source=0,
                 frontier="compact", cap=8, max_steps=10)
    want = np.concatenate([[0.0], np.ones(n - 1, np.float32)])
    np.testing.assert_array_equal(depth, want)


def test_compact_cond_branches_per_superstep():
    """On a circulant graph with cap < frontier for SSSP but not BFS, both
    still match dense exactly (per-superstep cond, not per-run)."""
    g = circulant_graph(512, degree=8, weights=True, seed=1)
    part = DevicePartition.from_graph(g)
    _assert_strategies_agree(algorithms.sssp_program(), part, source=3,
                             cap=16)


def test_auto_skips_compaction_when_tile_exceeds_dense_scan():
    """Static gate: a power-law hub makes cap*max_deg >= E; auto must
    compile the dense path only (and still be correct)."""
    n = 64
    src = np.zeros(n - 1, dtype=np.int64)
    dst = np.arange(1, n, dtype=np.int64)
    g = Graph(n, src, dst)
    part = DevicePartition.from_graph(g)
    eng = GREEngine(algorithms.bfs_program(), frontier="auto")
    assert eng._compaction_cap(part) is None
    depth = _run(algorithms.bfs_program(), part, source=0, frontier="auto")
    want = np.concatenate([[0.0], np.ones(n - 1, np.float32)])
    np.testing.assert_array_equal(depth, want)


# ------------------------------------------------------------ multi-source
@pytest.mark.parametrize("maker,weights", [
    (algorithms.bfs_program, False),
    (algorithms.sssp_program, True),
])
def test_multi_source_matches_independent_runs(maker, weights):
    g = rmat_edges(scale=7, edge_factor=8, seed=6, weights=True).dedup()
    part = DevicePartition.from_graph(g)
    sources = [0, 3, 17, 42]
    batched = _run(maker(num_sources=len(sources)), part, source=sources)
    singles = np.stack([_run(maker(), part, source=s) for s in sources],
                       axis=1)
    np.testing.assert_array_equal(batched, singles)


def test_multi_source_bfs_compact_matches_dense():
    g = rmat_edges(scale=7, edge_factor=8, seed=7).dedup()
    part = DevicePartition.from_graph(g)
    prog = algorithms.bfs_program(num_sources=3)
    _assert_strategies_agree(prog, part, source=[1, 2, 3], cap=32)


def test_multi_source_repeated_and_isolated_roots():
    """Duplicate roots give identical lanes; a sink-only root's lane stays
    inf everywhere but at the root itself."""
    g = rmat_edges(scale=6, edge_factor=4, seed=8).dedup()
    # vertex with no out-edges (if none exists, add an isolated one)
    outdeg = g.out_degree()
    sinks = np.flatnonzero(outdeg == 0)
    sink = int(sinks[0]) if sinks.size else g.num_vertices - 1
    part = DevicePartition.from_graph(g)
    sources = [0, 0, sink]
    out = _run(algorithms.bfs_program(num_sources=3), part, source=sources)
    np.testing.assert_array_equal(out[:, 0], out[:, 1])
    reach = np.flatnonzero(~np.isinf(out[:, 2]))
    assert sink in reach


# ----------------------------------------------------- multistage payloads
def test_bc_stages_compact_matches_dense_to_float_tolerance():
    """Sum-monoid stages through the compacted path: Brandes forward σ
    (halting) and backward δ (iterative but level-synchronous with
    dense_frontier=False) must match the dense strategy to float tolerance
    (the segment reduction reorders sum, unlike min/max)."""
    import dataclasses
    from repro.core.multistage import bc_backward_program, bc_forward_program

    g = circulant_graph(256, degree=4)
    D = 3
    sources = jnp.array([0, 11, 57], jnp.int32)
    lanes = jnp.arange(D)
    fwd_part = DevicePartition.from_graph(g)
    bwd_part = DevicePartition.from_graph(g, transpose=True)
    results = {}
    for strategy in ("dense", "compact"):
        fwd = GREEngine(bc_forward_program(D), frontier=strategy)
        bwd = GREEngine(bc_backward_program(D), dense_frontier=False,
                        frontier=strategy)
        assert (fwd._compaction_cap(fwd_part) is not None) == \
            (strategy == "compact")
        st = fwd.init_state(fwd_part)
        st = EngineState(
            st.vertex_data.at[sources, lanes].set(
                jnp.array([0.0, 1.0], jnp.float32)),
            st.scatter_data.at[sources, lanes].set(
                jnp.array([1.0, 1.0, 1.0], jnp.float32)),
            jnp.zeros(fwd_part.num_slots, dtype=bool).at[sources].set(True),
            st.step)
        out = fwd.run(fwd_part, st, 100)
        depth, sigma = out.vertex_data[..., 0], out.vertex_data[..., 1]
        dmax = jnp.max(jnp.where(jnp.isinf(depth), -1.0, depth))
        part_b = dataclasses.replace(
            bwd_part, aux={**bwd_part.aux, "depth": depth, "sigma": sigma,
                           "dmax": dmax})
        delta = bwd.run(part_b, bwd.init_state(part_b), 101).vertex_data
        results[strategy] = (np.asarray(out.vertex_data), np.asarray(delta))
    fix = lambda x: np.nan_to_num(x, posinf=1e30)
    np.testing.assert_allclose(fix(results["dense"][0]),
                               fix(results["compact"][0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(results["dense"][1], results["compact"][1],
                               rtol=1e-5, atol=1e-5)


def test_bc_batched_lanes_match_per_source_pipeline():
    """Payload-batched Brandes == per-source runs of the same programs."""
    from repro.core.multistage import betweenness_centrality
    import networkx as nx
    g = rmat_edges(scale=6, edge_factor=4, seed=9).dedup()
    nxg = nx.DiGraph()
    nxg.add_nodes_from(range(g.num_vertices))
    nxg.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
    want = nx.betweenness_centrality(nxg, normalized=False)
    ref = np.array([want[i] for i in range(g.num_vertices)])
    # batch smaller than |V| forces multiple payload batches + ragged tail
    got = betweenness_centrality(g, batch=24)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
