"""Degree-bucketed frontier compaction: tile coverage, overflow semantics,
capacity calibration, and multi-source payload batching.

Strategy-equivalence across the full {backend} x {strategy} x {sources}
surface lives in `tests/test_conformance.py`; this module keeps the
frontier-specific properties: the bucketed gather PARTITIONS the edge set,
per-bucket overflow degrades only the overflowing bucket, the calibrated
capacity tracks the live frontier instead of `num_slots`, and the
payload-batched multi-source/multi-stage programs agree with their
per-source references.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import algorithms
from repro.core.engine import DevicePartition, EngineState, GREEngine
from repro.core.frontier import (bucket_caps, bucketed_scatter_combine,
                                 default_cap, gather_frontier_edge_tile)
from repro.graph.generators import circulant_graph, rmat_edges
from repro.graph.structures import Graph

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def _run(program, part, source=None, frontier="auto", cap=None,
         max_steps=300):
    eng = GREEngine(program, frontier=frontier, frontier_cap=cap)
    out = eng.run(part, eng.init_state(part, source=source), max_steps)
    return np.asarray(out.vertex_data)


def _assert_strategies_agree(program, part, source=None, cap=None):
    dense = _run(program, part, source=source, frontier="dense")
    compact = _run(program, part, source=source, frontier="compact", cap=cap)
    np.testing.assert_array_equal(dense, compact)


def _star_graph(n: int) -> Graph:
    """Hub 0 -> every leaf, every leaf -> hub (so leaves scatter too)."""
    src = np.zeros(n - 1, dtype=np.int64)
    dst = np.arange(1, n, dtype=np.int64)
    return Graph(n, np.concatenate([src, dst]), np.concatenate([dst, src]))


# ----------------------------------------------- bucketed tile edge coverage
def _assert_buckets_partition_edges(g):
    part = DevicePartition.from_graph(g)
    bucket_id = np.asarray(part.bucket_id)
    deg = np.diff(np.asarray(part.csr_indptr))
    # degree-0 slots are in NO bucket (they can never emit a message)
    np.testing.assert_array_equal(bucket_id == -1, deg == 0)
    seen = set()
    for b, (size, max_deg) in enumerate(zip(part.bucket_sizes,
                                            part.bucket_max_deg)):
        members = np.flatnonzero(bucket_id == b)
        assert members.shape[0] == size
        if size == 0:
            continue
        eid, valid = gather_frontier_edge_tile(
            part, jnp.asarray(members, jnp.int32), size, max_deg)
        eids = np.asarray(eid)[np.asarray(valid)]
        fresh = set(eids.tolist())
        assert len(fresh) == eids.shape[0], "duplicate eid within a bucket"
        assert not (seen & fresh), "eid claimed by two buckets"
        seen |= fresh
    assert seen == set(range(g.num_edges))


def test_bucketed_gather_partitions_edges_star():
    _assert_buckets_partition_edges(_star_graph(300))


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(scale=st.integers(5, 8), edge_factor=st.integers(2, 8),
           seed=st.integers(0, 999))
    def test_bucketed_gather_partitions_edges(scale, edge_factor, seed):
        """Per-bucket eid sets partition range(E): every real edge is
        gathered by EXACTLY ONE bucket's tile when that bucket's full
        membership is on the frontier."""
        g = rmat_edges(scale=scale, edge_factor=edge_factor, seed=seed).dedup()
        _assert_buckets_partition_edges(g)


# --------------------------------------------------- overflow / star graphs
def test_star_graph_overflow_falls_back_to_dense():
    """Hub activates EVERY leaf in one superstep — the leaf bucket's live
    frontier (V-1 vertices) overflows any small capacity.  The per-bucket
    guard must degrade that bucket to its restricted dense scan instead of
    silently dropping vertices."""
    n = 257
    part = DevicePartition.from_graph(_star_graph(n))
    depth = _run(algorithms.bfs_program(), part, source=0,
                 frontier="compact", cap=8, max_steps=10)
    want = np.concatenate([[0.0], np.ones(n - 1, np.float32)])
    np.testing.assert_array_equal(depth, want)


def test_bucket_overflow_mixed_branches():
    """One bucket exceeds its cap while the others stay compact: the
    overflowing bucket's partial ⊕ comes from the bucket-restricted dense
    scan, the rest from their tiles — the total must equal the dense scan
    bitwise (min monoid)."""
    n = 300  # hub deg 299 -> bucket 2 (<=512); 299 leaves deg 1 -> bucket 0
    part = DevicePartition.from_graph(_star_graph(n))
    prog = algorithms.bfs_program()
    caps = bucket_caps(part.bucket_sizes, 8)
    # the scenario really exercises BOTH branches: leaves overflow, hub fits
    bucket_id = np.asarray(part.bucket_id)
    leaves_b = int(bucket_id[1])
    hub_b = int(bucket_id[0])
    assert leaves_b != hub_b
    assert part.bucket_sizes[leaves_b] > caps[leaves_b]
    assert part.bucket_sizes[hub_b] <= caps[hub_b]
    # every real slot live, distinct scatter values so the ⊕ is nontrivial
    eng = GREEngine(prog, frontier="dense")
    st0 = eng.init_state(part)
    state = EngineState(
        st0.vertex_data,
        st0.scatter_data.at[:n].set(jnp.arange(n, dtype=jnp.float32)),
        jnp.zeros(part.num_slots, dtype=bool).at[:n].set(True),
        st0.step)
    dense = eng.dense_scatter_combine(part, state, part.num_slots)
    bucketed = bucketed_scatter_combine(prog, part, state, part.num_slots,
                                        caps)
    np.testing.assert_array_equal(np.asarray(bucketed), np.asarray(dense))


def test_compact_cond_branches_per_superstep():
    """On a circulant graph with cap < frontier for SSSP but not BFS, both
    still match dense exactly (per-superstep cond, not per-run)."""
    g = circulant_graph(512, degree=8, weights=True, seed=1)
    part = DevicePartition.from_graph(g)
    _assert_strategies_agree(algorithms.sssp_program(), part, source=3,
                             cap=16)


# --------------------------------------------------- static plan resolution
def test_bucketed_plan_replaces_hub_gate_on_power_law():
    """The old static `cap * max_deg >= E` gate forced power-law graphs
    dense (one hub poisons the single tile's `max_deg`); bucketed tiles
    bound the worst case by `sum_b cap_b * max_deg_b`, so auto now
    compiles the compacted path on the SAME graph where the flat bound
    still gates."""
    from repro.graph.generators import barabasi_albert_graph
    g = barabasi_albert_graph(4096, m=8, seed=3).dedup()
    part = DevicePartition.from_graph(g)
    prog = algorithms.bfs_program()
    cap = default_cap(part.num_slots)
    # the flat single-tile bound is pathological: hub degree x cap >= E ...
    assert cap * part.csr_max_deg >= part.src.shape[0]
    assert GREEngine(prog, frontier="flat")._frontier_plan(part) == \
        ("flat", cap)  # forced flat skips the gate (overflow guard covers)
    # ... but the bucketed bound stays well under the dense scan
    plan = GREEngine(prog, frontier="auto")._frontier_plan(part)
    assert plan is not None and plan[0] == "bucketed"
    worst = sum(c * d for c, d in zip(plan[1], part.bucket_max_deg))
    assert worst < part.src.shape[0]
    depth = _run(prog, part, source=0, frontier="auto")
    np.testing.assert_array_equal(depth, _run(prog, part, source=0,
                                              frontier="dense"))


def test_degenerate_tiny_graph_stays_dense():
    """A directed star so small that even full bucket tiles out-scan the
    dense path: auto must compile the dense branch only (and be correct)."""
    n = 64
    src = np.zeros(n - 1, dtype=np.int64)
    dst = np.arange(1, n, dtype=np.int64)
    part = DevicePartition.from_graph(Graph(n, src, dst))
    eng = GREEngine(algorithms.bfs_program(), frontier="auto")
    assert eng._frontier_plan(part) is None
    depth = _run(algorithms.bfs_program(), part, source=0, frontier="auto")
    want = np.concatenate([[0.0], np.ones(n - 1, np.float32)])
    np.testing.assert_array_equal(depth, want)


# ----------------------------------------------------- capacity calibration
def test_calibrated_cap_tracks_live_frontier():
    """`default_cap` from the live first-superstep histogram: BFS from a
    LEAF of a large star sees frontiers of size 1, so the calibrated cap
    must be far below the fixed `num_slots/16` fraction (which
    over-allocates on large shards) — and the run stays exact even when
    the hub later floods every leaf past the calibrated cap."""
    n = 4097
    part = DevicePartition.from_graph(_star_graph(n))
    prog = algorithms.bfs_program()
    eng = GREEngine(prog, frontier="compact")
    state = eng.init_state(part, source=1)     # a leaf
    hist = eng.calibrate_frontier_cap(part, state)
    assert hist == [1, 1]                      # leaf -> hub: size-1 fronts
    cap = eng.frontier_cap
    assert cap <= 16, cap                      # 4x the observed size-1 front
    assert cap < default_cap(part.num_slots)   # fixed fraction: 256
    assert eng.frontier_hist == hist           # the tuner's density facet
    out = eng.run(part, state, 10)
    want = np.full(n, 2.0, np.float32)
    want[1], want[0] = 0.0, 1.0
    np.testing.assert_array_equal(np.asarray(out.vertex_data), want)


def test_default_cap_histogram_and_fallback():
    assert default_cap(4096) == 256            # fixed-fraction fallback
    assert default_cap(4096, frontier_hist=[1, 3]) == 16   # 4*3 -> round 8
    assert default_cap(64, frontier_hist=[200]) == 64      # clamped to slots


# ------------------------------------------------------------ multi-source
@pytest.mark.parametrize("maker,weights", [
    (algorithms.bfs_program, False),
    (algorithms.sssp_program, True),
])
def test_multi_source_matches_independent_runs(maker, weights):
    g = rmat_edges(scale=7, edge_factor=8, seed=6, weights=True).dedup()
    part = DevicePartition.from_graph(g)
    sources = [0, 3, 17, 42]
    batched = _run(maker(num_sources=len(sources)), part, source=sources)
    singles = np.stack([_run(maker(), part, source=s) for s in sources],
                       axis=1)
    np.testing.assert_array_equal(batched, singles)


def test_multi_source_bfs_compact_matches_dense():
    g = rmat_edges(scale=7, edge_factor=8, seed=7).dedup()
    part = DevicePartition.from_graph(g)
    prog = algorithms.bfs_program(num_sources=3)
    _assert_strategies_agree(prog, part, source=[1, 2, 3], cap=32)


def test_multi_source_repeated_and_isolated_roots():
    """Duplicate roots give identical lanes; a sink-only root's lane stays
    inf everywhere but at the root itself."""
    g = rmat_edges(scale=6, edge_factor=4, seed=8).dedup()
    # vertex with no out-edges (if none exists, add an isolated one)
    outdeg = g.out_degree()
    sinks = np.flatnonzero(outdeg == 0)
    sink = int(sinks[0]) if sinks.size else g.num_vertices - 1
    part = DevicePartition.from_graph(g)
    sources = [0, 0, sink]
    out = _run(algorithms.bfs_program(num_sources=3), part, source=sources)
    np.testing.assert_array_equal(out[:, 0], out[:, 1])
    reach = np.flatnonzero(~np.isinf(out[:, 2]))
    assert sink in reach


# ----------------------------------------------------- multistage payloads
def test_bc_stages_compact_matches_dense_to_float_tolerance():
    """Sum-monoid stages through the compacted path: Brandes forward σ
    (halting) and backward δ (iterative but level-synchronous with
    dense_frontier=False) must match the dense strategy to float tolerance
    (the segment reduction reorders sum, unlike min/max)."""
    import dataclasses
    from repro.core.multistage import bc_backward_program, bc_forward_program

    g = circulant_graph(256, degree=4)
    D = 3
    sources = jnp.array([0, 11, 57], jnp.int32)
    lanes = jnp.arange(D)
    fwd_part = DevicePartition.from_graph(g)
    bwd_part = DevicePartition.from_graph(g, transpose=True)
    results = {}
    for strategy in ("dense", "compact"):
        fwd = GREEngine(bc_forward_program(D), frontier=strategy)
        bwd = GREEngine(bc_backward_program(D), dense_frontier=False,
                        frontier=strategy)
        assert (fwd._frontier_plan(fwd_part) is not None) == \
            (strategy == "compact")
        st = fwd.init_state(fwd_part)
        st = EngineState(
            st.vertex_data.at[sources, lanes].set(
                jnp.array([0.0, 1.0], jnp.float32)),
            st.scatter_data.at[sources, lanes].set(
                jnp.array([1.0, 1.0, 1.0], jnp.float32)),
            jnp.zeros(fwd_part.num_slots, dtype=bool).at[sources].set(True),
            st.step)
        out = fwd.run(fwd_part, st, 100)
        depth, sigma = out.vertex_data[..., 0], out.vertex_data[..., 1]
        dmax = jnp.max(jnp.where(jnp.isinf(depth), -1.0, depth))
        part_b = dataclasses.replace(
            bwd_part, aux={**bwd_part.aux, "depth": depth, "sigma": sigma,
                           "dmax": dmax})
        delta = bwd.run(part_b, bwd.init_state(part_b), 101).vertex_data
        results[strategy] = (np.asarray(out.vertex_data), np.asarray(delta))
    fix = lambda x: np.nan_to_num(x, posinf=1e30)
    np.testing.assert_allclose(fix(results["dense"][0]),
                               fix(results["compact"][0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(results["dense"][1], results["compact"][1],
                               rtol=1e-5, atol=1e-5)


def test_bc_batched_lanes_match_per_source_pipeline():
    """Payload-batched Brandes == per-source runs of the same programs."""
    from repro.core.multistage import betweenness_centrality
    import networkx as nx
    g = rmat_edges(scale=6, edge_factor=4, seed=9).dedup()
    nxg = nx.DiGraph()
    nxg.add_nodes_from(range(g.num_vertices))
    nxg.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
    want = nx.betweenness_centrality(nxg, normalized=False)
    ref = np.array([want[i] for i in range(g.num_vertices)])
    # batch smaller than |V| forces multiple payload batches + ragged tail
    got = betweenness_centrality(g, batch=24)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- pallas tile combine
@pytest.mark.parametrize("dynamic", [True, False],
                         ids=["dynamic-table", "full-table"])
def test_bucketed_pallas_tile_combine_matches_xla(dynamic):
    """use_pallas routes the bucketed tiles through the Pallas tile combine
    (interpret mode on CPU) — by default over the on-device
    `dynamic_block_table` pruning pass, with the degenerate full table as
    the `dynamic_table=False` fallback: bitwise vs the dense reference for
    the min monoid either way."""
    g = rmat_edges(scale=6, edge_factor=8, seed=11, weights=True).dedup()
    part = DevicePartition.from_graph(g)
    dense = _run(algorithms.sssp_program(), part, source=0, frontier="dense")
    eng = GREEngine(algorithms.sssp_program(), frontier="compact",
                    use_pallas=True, dynamic_table=dynamic)
    out = eng.run(part, eng.init_state(part, source=0), 300)
    np.testing.assert_array_equal(np.asarray(out.vertex_data), dense)
