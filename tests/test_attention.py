"""Chunked/flash attention (XLA path) + decode consistency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.nn.attention import (_gqa_scores_ref, decode_attention,
                                apply_rope, flash_attention_jax)

RNG = np.random.default_rng(1)


def _rand(shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


@pytest.mark.parametrize("s,qc,kc", [(96, 32, 32), (128, 128, 64),
                                     (100, 32, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_forward_matches_reference(s, qc, kc, causal):
    q, k, v = _rand((2, s, 2, 3, 16)), _rand((2, s, 2, 16)), _rand((2, s, 2, 16))
    out = flash_attention_jax(q, k, v, causal, qc, kc)
    want = _gqa_scores_ref(q, k, v, causal)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_custom_vjp_matches_autodiff_reference(causal):
    q, k, v = _rand((2, 64, 2, 2, 16)), _rand((2, 64, 2, 16)), _rand((2, 64, 2, 16))
    f1 = lambda q, k, v: (flash_attention_jax(q, k, v, causal, 32, 32) ** 2).sum()
    f2 = lambda q, k, v: (_gqa_scores_ref(q, k, v, causal) ** 2).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_decode_equals_full_attention():
    S = 24
    q = _rand((2, 1, 2, 3, 16))
    kc, vc = _rand((2, 32, 2, 16)), _rand((2, 32, 2, 16))
    out = decode_attention(q, kc, vc, jnp.full((2,), S, jnp.int32))
    # reference: q attends to cache[0..S] (inclusive of its own position S)
    want = _gqa_scores_ref(q, kc[:, :S + 1], vc[:, :S + 1], causal=False)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_rope_preserves_norm_and_relative_phase():
    x = _rand((2, 8, 16, 64))
    pos = jnp.broadcast_to(jnp.arange(16), (2, 8, 16))
    y = apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q, k = x[:, :1, :1], x[:, 1:2, :1]
    def dot_at(p):
        pos_q = jnp.full((2, 1, 1), p)
        pos_k = jnp.full((2, 1, 1), p + 3)
        return jnp.sum(apply_rope(q, pos_q) * apply_rope(k, pos_k))
    np.testing.assert_allclose(dot_at(0), dot_at(11), rtol=1e-4)
