import os
import sys
from pathlib import Path

# src-layout import without install; tests MUST see the default 1-device CPU
# runtime (the 512-device override is dryrun.py-only by design).
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")
