"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (deliverable f).
The FULL configs are exercised only via the dry-run."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config
from repro.graph.generators import random_geometric_molecule, rmat_edges

LM_ARCHS = ["command-r-plus-104b", "smollm-135m", "nemotron-4-15b",
            "qwen3-moe-30b-a3b", "granite-moe-1b-a400m"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(arch):
    from repro.launch.train import reduced_lm_config
    from repro.models import transformer as tfm
    from repro.optim.adamw import AdamW

    cfg, family = get_config(arch)
    assert family == "lm"
    red = reduced_lm_config(cfg, layers=2, d_model=64, n_heads=4, n_kv=2,
                            d_head=16, d_ff=96, vocab=512)
    # family structure preserved
    assert (red.moe is None) == (cfg.moe is None)
    assert red.activation == cfg.activation and red.gated == cfg.gated
    key = jax.random.PRNGKey(0)
    params = tfm.init_lm(key, red)
    tokens = jax.random.randint(key, (2, 32), 0, red.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(tfm.lm_loss, has_aux=True)(p, b, red)
        p, o = opt.update(g, o, p)
        return p, o, loss

    params, opt_state, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss))
    logits, _ = tfm.lm_forward(params, tokens, red)
    assert logits.shape == (2, 32, red.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ["gcn-cora", "gin-tu"])
def test_gnn_arch_smoke(arch):
    from repro.models.gnn import (GraphBatch, compute_gcn_edge_norm,
                                  gnn_forward, gnn_loss, init_gnn)
    cfg, family = get_config(arch)
    assert family == "gnn"
    red = dataclasses.replace(cfg, d_hidden=8)
    g = rmat_edges(scale=6, edge_factor=4, seed=0).dedup()
    key = jax.random.PRNGKey(0)
    V, E = g.num_vertices, g.num_edges
    src, dst = jnp.asarray(g.src, jnp.int32), jnp.asarray(g.dst, jnp.int32)
    mask = jnp.ones(E, bool)
    batch = GraphBatch(
        jax.random.normal(key, (V, 12)), src, dst, mask,
        jax.random.randint(key, (V,), 0, red.n_classes),
        jnp.ones(V, bool),
        edge_norm=compute_gcn_edge_norm(src, dst, mask, V))
    params = init_gnn(key, red, 12, red.n_classes)
    logits = jax.jit(lambda p, b: gnn_forward(p, b, red))(params, batch)
    assert logits.shape == (V, red.n_classes)
    assert not bool(jnp.isnan(logits).any())
    g_ = jax.grad(lambda p: gnn_loss(p, batch, red))(params)
    assert np.isfinite(float(jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda x: jnp.abs(x).sum(), g_))))


def test_dimenet_arch_smoke():
    from repro.models.dimenet import build_triplets, dimenet_forward, init_dimenet
    cfg, _ = get_config("dimenet")
    red = dataclasses.replace(cfg, n_layers=2, d_hidden=16, n_bilinear=4)
    pos_np, src, dst = random_geometric_molecule(16, 48, seed=1)
    kj, ji, tm = build_triplets(src, dst, 16)
    key = jax.random.PRNGKey(0)
    params = init_dimenet(key, red)
    out = jax.jit(lambda p: dimenet_forward(
        p, jnp.asarray(pos_np), jnp.zeros(16, jnp.int32), jnp.asarray(src),
        jnp.asarray(dst), jnp.ones(len(src), bool), jnp.asarray(kj),
        jnp.asarray(ji), jnp.asarray(tm), red))(params)
    assert out.shape == (16, 1)
    assert not bool(jnp.isnan(out).any())


def test_mace_arch_smoke():
    from repro.models.mace import init_mace, mace_forward
    cfg, _ = get_config("mace")
    red = dataclasses.replace(cfg, d_hidden=8)
    pos_np, src, dst = random_geometric_molecule(12, 36, seed=2)
    key = jax.random.PRNGKey(0)
    params = init_mace(key, red, n_species=4)
    out = jax.jit(lambda p: mace_forward(
        p, jnp.asarray(pos_np), jnp.zeros(12, jnp.int32), jnp.asarray(src),
        jnp.asarray(dst), jnp.ones(len(src), bool), red))(params)
    assert out.shape == (12, 1)
    assert not bool(jnp.isnan(out).any())


def test_autoint_arch_smoke():
    import dataclasses as dc
    from repro.models.autoint import (autoint_logits, autoint_loss,
                                      init_autoint, synth_batch)
    cfg, family = get_config("autoint")
    assert family == "recsys"
    red = dc.replace(cfg, vocab_sizes=tuple([100] * cfg.n_sparse))
    key = jax.random.PRNGKey(0)
    params = init_autoint(key, red)
    batch = synth_batch(key, red, 32)
    logits = jax.jit(lambda p, b: autoint_logits(p, b["ids"], red))(params, batch)
    assert logits.shape == (32,)
    assert not bool(jnp.isnan(logits).any())
    g = jax.grad(lambda p: autoint_loss(p, batch, red))(params)
    assert float(jnp.abs(g["table"]).sum()) > 0


def test_registry_covers_all_cells():
    from repro.configs import all_cells, get_shapes
    cells = list(all_cells())
    assert len(cells) == 40  # 5 LM × 4 + 4 GNN × 4 + 1 recsys × 4
    for arch in ALL_ARCHS:
        assert len(get_shapes(arch)) == 4
