"""Cross-backend x cross-strategy conformance matrix.

ONE suite asserting BITWISE-equal results across the combinatorial surface

    {null, agent, dense, pipelined} exchange backends
  x {dense, flat, compact, auto} frontier strategies
  x {XLA, Pallas-dynamic-table, Pallas-full-table} combine kernels
  x {single-source, multi-source} payloads

on random power-law (R-MAT) and circulant graphs, replacing the ad-hoc
per-pair checks that previously accreted across `test_exchange.py`,
`test_frontier.py` and `test_pipeline_overlap.py`.  The reference is
always the single-shard dense-strategy NullExchange run; min-monoid
traversal programs (BFS/SSSP/CC) must match it bitwise — min is exactly
associative/commutative, so neither the exchange's two-stage ⊕, the
bucketed tiles' per-bucket partial order, nor the Pallas dynamic pruning
pass's on-device dst sort can leak through.  Every combination runs
through the ONE plan executor (`repro.core.plan.execute_plan`): there is
no separate pipelined loop to diverge from.

The in-process matrix covers the null backend (every strategy and kernel,
interpret-mode Pallas) and the pipelined backend on a 1-device mesh
(split tiles + deferred merge, degenerate flush).  The real multi-shard
matrix needs the 8-device XLA_FLAGS set before jax initializes, so it
runs in a subprocess and is marked `slow`.  A kernel-level section checks
the on-device `dynamic_block_table` pruning pass against the full table
and the XLA oracle directly; each hypothesis test has a fixed-seed twin
so the matrix still runs where `hypothesis` is absent.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.core import algorithms
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core.engine import DevicePartition, GREEngine
from repro.core.partition import greedy_partition
from repro.graph.generators import circulant_graph, rmat_edges

SRC = str(Path(__file__).resolve().parent.parent / "src")

STRATEGIES = ("dense", "compact", "auto", "flat")
MULTI_SOURCES = [0, 3, 17]


def _graph(kind: str, scale: int, edge_factor: int, seed: int):
    if kind == "circulant":
        return circulant_graph(1 << scale, degree=edge_factor, weights=True,
                               seed=seed)
    return rmat_edges(scale=scale, edge_factor=edge_factor, seed=seed,
                      weights=True).dedup()


def _single_shard(program, part, source=None, frontier="dense", cap=None,
                  max_steps=300):
    eng = GREEngine(program, frontier=frontier, frontier_cap=cap)
    out = eng.run(part, eng.init_state(part, source=source), max_steps)
    return np.asarray(out.vertex_data)


def _dist_k1(program, g, exchange="pipelined", source=None, max_steps=300,
             **kw):
    """One of the distributed exchanges on a 1-device mesh (degenerate
    collectives, real phase shape — including the async staleness ring)."""
    ag = build_agent_graph(g, greedy_partition(g, 1, batch_size=64), 1)
    mesh = jax.make_mesh((1,), ("graph",))
    eng = DistGREEngine(program, mesh, ("graph",), exchange=exchange, **kw)
    out, _ = eng.run(ag, source=source, max_steps=max_steps)
    return out


def _pipelined(program, g, source=None, max_steps=300, **kw):
    return _dist_k1(program, g, exchange="pipelined", source=source,
                    max_steps=max_steps, **kw)


def _fix(x):
    return np.nan_to_num(x, posinf=-1.0)


# ------------------------------------------------ in-process strategy matrix
def _check_null_matrix(kind, scale, edge_factor, seed, source, strategy,
                       cap):
    """Single shard: `strategy` == dense, bitwise, for single-source BFS
    and multi-source SSSP (caps small enough to force mid-run overflow
    fallbacks ride the per-bucket guards)."""
    g = _graph(kind, scale, edge_factor, seed)
    part = DevicePartition.from_graph(g)
    bfs_ref = _single_shard(algorithms.bfs_program(), part, source=source)
    got = _single_shard(algorithms.bfs_program(), part, source=source,
                        frontier=strategy, cap=cap)
    np.testing.assert_array_equal(got, bfs_ref)
    ms = algorithms.sssp_program(num_sources=len(MULTI_SOURCES))
    ms_ref = _single_shard(ms, part, source=MULTI_SOURCES)
    got = _single_shard(ms, part, source=MULTI_SOURCES,
                        frontier=strategy, cap=cap)
    np.testing.assert_array_equal(got, ms_ref)


def _check_pipelined_k1(kind, scale, edge_factor, seed, source, strategy):
    """Pipelined backend (split tiles + deferred merge) on a 1-device
    mesh: `strategy` == the single-shard dense reference, bitwise, for
    BFS and SSSP."""
    g = _graph(kind, scale, edge_factor, seed)
    part = DevicePartition.from_graph(g)
    for prog in (algorithms.bfs_program(), algorithms.sssp_program()):
        ref = _single_shard(prog, part, source=source)
        got = _pipelined(prog, g, source=source, frontier=strategy,
                         frontier_cap=64)
        np.testing.assert_array_equal(_fix(got), _fix(ref))


def _check_null_pallas(kind, scale, edge_factor, seed, source, strategy,
                       cap, dynamic):
    """The Pallas row: `use_pallas=True` (interpret mode) over the same
    strategies, bitwise against BOTH the XLA engine at the same strategy
    and the dense reference — with the on-device dynamic block table
    (`dynamic=True`, the default) and the degenerate full-table fallback
    (`dynamic=False`)."""
    g = _graph(kind, scale, edge_factor, seed)
    part = DevicePartition.from_graph(g)
    for prog in (algorithms.bfs_program(),
                 algorithms.sssp_program(num_sources=len(MULTI_SOURCES))):
        multi = prog.payload_shape != ()
        src = MULTI_SOURCES if multi else source
        ref = _single_shard(prog, part, source=src)
        xla = _single_shard(prog, part, source=src, frontier=strategy,
                            cap=cap)
        eng = GREEngine(prog, frontier=strategy, frontier_cap=cap,
                        use_pallas=True, dynamic_table=dynamic)
        got = np.asarray(eng.run(part, eng.init_state(part, source=src),
                                 300).vertex_data)
        np.testing.assert_array_equal(got, xla)
        np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("kind", ["rmat", "circulant"])
def test_null_backend_strategy_matrix(kind, strategy):
    _check_null_matrix(kind, 7, 8, 5, 0, strategy, cap=32)


@pytest.mark.parametrize("dynamic", [True, False],
                         ids=["dynamic-table", "full-table"])
@pytest.mark.parametrize("strategy", ("compact", "auto", "flat"))
def test_null_backend_pallas_matrix(strategy, dynamic):
    _check_null_pallas("rmat", 7, 8, 5, 0, strategy, 32, dynamic)


@pytest.mark.parametrize("strategy", ("dense", "compact", "auto"))
def test_pipelined_k1_strategy_matrix(strategy):
    _check_pipelined_k1("rmat", 7, 8, 5, 0, strategy)


# {agent, pipelined, async-k2, async-k4} rows of the backend matrix: the
# async rows drive the bounded-staleness ring (refresh collective every k
# supersteps, k-deep remote-partial ring, in-flight slots counted by the
# termination predicate) and must land on the SAME fixed point — values,
# not trajectories.
K1_BACKENDS = [("agent", {}), ("pipelined", {}),
               ("async", {"staleness": 2}), ("async", {"staleness": 4})]
K1_IDS = ["agent", "pipelined", "async-k2", "async-k4"]


@pytest.mark.parametrize("strategy", ("dense", "compact", "auto"))
@pytest.mark.parametrize("backend,opts", K1_BACKENDS, ids=K1_IDS)
def test_backend_k1_strategy_matrix(backend, opts, strategy):
    g = _graph("rmat", 7, 8, 5)
    part = DevicePartition.from_graph(g)
    for prog in (algorithms.bfs_program(), algorithms.sssp_program()):
        ref = _single_shard(prog, part, source=0)
        got = _dist_k1(prog, g, exchange=backend, source=0,
                       frontier=strategy, frontier_cap=64, **opts)
        np.testing.assert_array_equal(_fix(got), _fix(ref))


@pytest.mark.parametrize("backend,opts", K1_BACKENDS, ids=K1_IDS)
def test_backend_k1_cc(backend, opts):
    """CC (every vertex initially active, undirected) across the same
    backend rows — the all-slots-live stress for the async ring fold."""
    g = rmat_edges(scale=6, edge_factor=4, seed=5).dedup().as_undirected()
    part = DevicePartition.from_graph(g)
    ref = _single_shard(algorithms.cc_program(), part)
    got = _dist_k1(algorithms.cc_program(), g, exchange=backend,
                   frontier="auto", frontier_cap=64, **opts)
    np.testing.assert_array_equal(_fix(got), _fix(ref))


def test_async_refuses_sum_monoid_programs():
    """Bounded staleness is only sound for idempotent min/max fixed points
    (`VertexProgram.monotone`): a sum-monoid program would double-count
    every re-delivered partial.  All three ingress points must refuse
    loudly — constructor, adopt_plan, and the tuner's candidate axis is
    pruned (covered in test_tuning)."""
    from repro.core.plan import SuperstepPlan
    mesh = jax.make_mesh((1,), ("graph",))
    pr = algorithms.pagerank_program()
    with pytest.raises(ValueError, match="monotone"):
        DistGREEngine(pr, mesh, ("graph",), exchange="async", staleness=2)
    ppr = algorithms.ppr_push_program(2)
    with pytest.raises(ValueError, match="monotone"):
        DistGREEngine(ppr, mesh, ("graph",), exchange="async", staleness=2)
    eng = DistGREEngine(pr, mesh, ("graph",), exchange="agent")
    with pytest.raises(ValueError, match="monotone"):
        eng.adopt_plan(SuperstepPlan(phases="async", staleness=2))


def test_async_staleness_validation():
    """exchange='async' needs a ring depth >= 1; the serving tick cannot
    run async at all (un-flushed ring partials would be dropped across
    ticks)."""
    mesh = jax.make_mesh((1,), ("graph",))
    bfs = algorithms.bfs_program()
    with pytest.raises(ValueError, match="staleness"):
        DistGREEngine(bfs, mesh, ("graph",), exchange="async", staleness=0)
    g = _graph("rmat", 6, 4, 3)
    ag = build_agent_graph(g, greedy_partition(g, 1, batch_size=64), 1)
    eng = DistGREEngine(bfs, mesh, ("graph",), exchange="async", staleness=2)
    eng.device_topology(ag)
    with pytest.raises(ValueError, match="serving"):
        eng.make_superstep(ag)


def test_pipelined_k1_pallas():
    """Pallas tile combine (dynamic table) through the pipelined backend's
    split edge tiles on a 1-device mesh: bitwise vs the dense XLA
    reference."""
    g = _graph("rmat", 7, 8, 5)
    part = DevicePartition.from_graph(g)
    prog = algorithms.sssp_program()
    ref = _single_shard(prog, part, source=0)
    got = _pipelined(prog, g, source=0, frontier="compact", frontier_cap=64,
                     use_pallas=True)
    np.testing.assert_array_equal(_fix(got), _fix(ref))


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(kind=st.sampled_from(["rmat", "circulant"]),
           scale=st.integers(5, 7), edge_factor=st.integers(2, 8),
           seed=st.integers(0, 999), source=st.integers(0, 31),
           strategy=st.sampled_from(STRATEGIES),
           cap=st.sampled_from([None, 8, 64]))
    def test_null_backend_strategy_matrix_random(kind, scale, edge_factor,
                                                 seed, source, strategy,
                                                 cap):
        _check_null_matrix(kind, scale, edge_factor, seed, source, strategy,
                           cap)

    @settings(max_examples=8, deadline=None)
    @given(kind=st.sampled_from(["rmat", "circulant"]),
           scale=st.integers(5, 7), edge_factor=st.integers(2, 8),
           seed=st.integers(0, 999), source=st.integers(0, 31),
           strategy=st.sampled_from(("dense", "compact", "auto")))
    def test_pipelined_k1_strategy_matrix_random(kind, scale, edge_factor,
                                                 seed, source, strategy):
        _check_pipelined_k1(kind, scale, edge_factor, seed, source, strategy)

    # fixed-seed twin: test_null_backend_pallas_matrix
    @settings(max_examples=6, deadline=None)
    @given(kind=st.sampled_from(["rmat", "circulant"]),
           scale=st.integers(5, 7), edge_factor=st.integers(2, 8),
           seed=st.integers(0, 999), source=st.integers(0, 31),
           strategy=st.sampled_from(("compact", "auto", "flat")),
           dynamic=st.booleans())
    def test_null_backend_pallas_matrix_random(kind, scale, edge_factor,
                                               seed, source, strategy,
                                               dynamic):
        _check_null_pallas(kind, scale, edge_factor, seed, source, strategy,
                           32, dynamic)

    # fixed-seed twin: test_dynamic_block_table_fixed
    @settings(max_examples=15, deadline=None)
    @given(e=st.integers(1, 600), v=st.integers(1, 300),
           d=st.sampled_from([1, 4, 8]), op=st.sampled_from(["min", "sum"]),
           valid_frac=st.floats(0.0, 1.0), seed=st.integers(0, 2**16))
    def test_dynamic_block_table_random(e, v, d, op, valid_frac, seed):
        _check_dynamic_table(e, v, d, op, valid_frac, seed)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_cc_strategy_matrix(strategy):
    """CC (min monoid, every vertex initially active — the all-buckets-live
    stress for the bucketed gather): strategies agree bitwise."""
    g = rmat_edges(scale=6, edge_factor=4, seed=5).dedup().as_undirected()
    part = DevicePartition.from_graph(g)
    ref = _single_shard(algorithms.cc_program(), part)
    got = _single_shard(algorithms.cc_program(), part, frontier=strategy,
                        cap=16)
    np.testing.assert_array_equal(got, ref)


# ------------------------------------------- mutation conformance (warm)
MUT_BACKENDS = ("null", "agent", "dense", "pipelined")
MUT_STRATEGIES = ("dense", "compact", "auto")


def _mutation_delta(g, seed, frac=0.08, undirected=False):
    """A fixed-seed churn batch: retire `frac` of the live edges and add
    the same number of fresh ones (symmetric pairs when `undirected`, so
    CC's both-directions invariant holds).  Weights are small integers —
    exact in f32, so warm-vs-cold comparisons stay bitwise."""
    from repro.graph.structures import EdgeDelta
    rng = np.random.default_rng(seed)
    src, dst = np.asarray(g.src), np.asarray(g.dst)
    n = g.num_vertices
    if undirected:
        fwd = np.flatnonzero(src < dst)
        m = max(1, int(fwd.size * frac))
        pick = rng.choice(fwd, size=m, replace=False)
        rem_s = np.concatenate([src[pick], dst[pick]])
        rem_d = np.concatenate([dst[pick], src[pick]])
        u = rng.integers(0, n, size=m)
        v = (u + 1 + rng.integers(0, n - 1, size=m)) % n   # never u == v
        # dedup by unordered pair: the symmetric concat below would turn a
        # repeated {u, v} into in-batch duplicate rows, which ingress rejects
        lo, hi = np.minimum(u, v), np.maximum(u, v)
        _, first = np.unique(lo.astype(np.int64) * n + hi, return_index=True)
        keep = np.sort(first)
        u, v = u[keep], v[keep]
        add_s, add_d = np.concatenate([u, v]), np.concatenate([v, u])
        m_prop = keep.size
    else:
        m = max(1, int(g.num_edges * frac))
        pick = rng.choice(g.num_edges, size=m, replace=False)
        rem_s, rem_d = src[pick], dst[pick]
        add_s = rng.integers(0, n, size=m)
        add_d = rng.integers(0, n, size=m)
        _, first = np.unique(add_s.astype(np.int64) * n + add_d,
                             return_index=True)
        keep = np.sort(first)
        add_s, add_d = add_s[keep], add_d[keep]
        m_prop = keep.size
    props = {}
    for key in g.edge_props:
        w = rng.integers(1, 100, size=m_prop).astype(np.float32)
        props[key] = np.concatenate([w, w]) if undirected else w
    return EdgeDelta(add_src=add_s, add_dst=add_d, add_props=props,
                     rem_src=rem_s, rem_dst=rem_d)


def _warm_single(prog, g, delta, source, strategy, max_steps=300):
    eng = GREEngine(prog, frontier=strategy, frontier_cap=32)
    part = DevicePartition.from_graph(g)
    prev = eng.run(part, eng.init_state(part, source=source), max_steps)
    _, out, _ = eng.rerun_incremental(part, prev, delta, source=source,
                                      max_steps=max_steps)
    return np.asarray(out.vertex_data)


def _warm_dist(prog, g, delta, source, backend, strategy, max_steps=300):
    ag = build_agent_graph(g, greedy_partition(g, 1, batch_size=64), 1)
    mesh = jax.make_mesh((1,), ("graph",))
    eng = DistGREEngine(prog, mesh, ("graph",), exchange=backend,
                        frontier=strategy, frontier_cap=64)
    _, prev = eng.run(ag, source=source, max_steps=max_steps)
    _, result, _, _ = eng.rerun_incremental(ag, prev, delta, source=source,
                                            max_steps=max_steps)
    return result


@pytest.mark.parametrize("strategy", MUT_STRATEGIES)
@pytest.mark.parametrize("backend", MUT_BACKENDS)
def test_mutation_warm_equals_cold(backend, strategy):
    """THE incremental-re-convergence invariant (docs/incremental.md): a
    warm start from the pre-delta fixed point must land on BITWISE the
    same fixed point as a cold recompute of the mutated graph — min is
    idempotent and the fixed point unique, so seeding only the affected
    region may change the path, never the answer.  Single-source BFS and
    multi-source SSSP, every backend x frontier strategy."""
    g = _graph("rmat", 6, 4, 11)
    delta = _mutation_delta(g, seed=21)
    part2 = DevicePartition.from_graph(g.apply_edge_delta(delta))
    for prog, src in ((algorithms.bfs_program(), 0),
                      (algorithms.sssp_program(
                          num_sources=len(MULTI_SOURCES)), MULTI_SOURCES)):
        ref = _single_shard(prog, part2, source=src)   # cold recompute
        if backend == "null":
            got = _warm_single(prog, g, delta, src, strategy)
        else:
            got = _warm_dist(prog, g, delta, src, backend, strategy)
        np.testing.assert_array_equal(_fix(got), _fix(ref))


@pytest.mark.parametrize("strategy", MUT_STRATEGIES)
@pytest.mark.parametrize("backend", MUT_BACKENDS)
def test_mutation_warm_equals_cold_cc(backend, strategy):
    """CC under mutation: label propagation's support is CYCLIC, so
    removals invalidate by reachability over the pre-delta edge set
    (`invalidation="component"`) — the warm fixed point must still equal
    the cold recompute bitwise on every backend x strategy."""
    g = rmat_edges(scale=6, edge_factor=4, seed=5).dedup().as_undirected()
    delta = _mutation_delta(g, seed=33, undirected=True)
    part2 = DevicePartition.from_graph(g.apply_edge_delta(delta))
    prog = algorithms.cc_program()
    ref = _single_shard(prog, part2)
    if backend == "null":
        got = _warm_single(prog, g, delta, None, strategy)
    else:
        got = _warm_dist(prog, g, delta, None, backend, strategy)
    np.testing.assert_array_equal(_fix(got), _fix(ref))


# ------------------------------------------------------- plan composition
def test_superstep_plan_composition():
    """The plan surface: engines expose the composed mode as ONE static
    object — frontier strategy request, kernel stage, and the phase shape
    the selected backend's protocol drives — and the recorded phase shape
    matches the backend's `phases` attribute."""
    import jax
    from repro.core.exchange import NULL_EXCHANGE
    from repro.core.plan import KernelPlan
    prog = algorithms.bfs_program()
    eng = GREEngine(prog, frontier="compact", use_pallas=True,
                    dynamic_table=False, frontier_cap=64)
    plan = eng.make_plan()
    assert plan.phases == NULL_EXCHANGE.phases == "sync"
    assert plan.strategy == "compact" and plan.frontier_cap == 64
    assert plan.kernel == KernelPlan(use_pallas=True, dynamic_table=False)
    # the frontier stage resolves per partition (bucketed on this graph)
    part = DevicePartition.from_graph(_graph("rmat", 7, 8, 5))
    fp = plan.frontier(part)
    assert fp.kind == "bucketed" and sum(fp.caps) > 0
    mesh = jax.make_mesh((1,), ("graph",))
    for exchange, phases in (("pipelined", "pipelined"), ("agent", "sync")):
        dist = DistGREEngine(prog, mesh, ("graph",), exchange=exchange)
        assert dist.plan.phases == phases
        backend_cls = {"pipelined": "PipelinedAgentExchange",
                       "agent": "AgentExchange"}[exchange]
        from repro.core import exchange as ex
        assert getattr(ex, backend_cls).phases == phases
    # calibration between construction and run is honored: the plan is
    # rebuilt on access, never a stale frozen copy
    dist = DistGREEngine(prog, mesh, ("graph",), exchange="agent")
    dist.local.frontier_cap = 8
    assert dist.plan.frontier_cap == 8


# ----------------------------------------------------- plan serialization
def test_superstep_plan_json_round_trip():
    """Every plan the search space can emit must survive
    to_json -> (real JSON text) -> from_json EQUAL — the persistent plan
    cache (repro.tuning.cache) stores nothing else."""
    import json

    from repro.core.plan import KernelPlan, SuperstepPlan
    plans = [
        SuperstepPlan(),
        SuperstepPlan(strategy="flat", frontier_cap=64),
        SuperstepPlan(strategy="compact", frontier_cap=128,
                      bucket_bounds=(4, 16, 64, 256)),
        SuperstepPlan(strategy="dense", dense_frontier=True,
                      phases="pipelined",
                      kernel=KernelPlan(use_pallas=True,
                                        dynamic_table=False)),
        SuperstepPlan(phases="async", staleness=2),
        SuperstepPlan(strategy="compact", frontier_cap=64,
                      phases="async", staleness=4),
    ]
    for plan in plans:
        wire = json.loads(json.dumps(plan.to_json()))
        assert SuperstepPlan.from_json(wire) == plan, plan


def test_superstep_plan_staleness_validation():
    """`staleness` is the async ring depth: phases='async' needs >= 1,
    every other phase shape must carry 0 — a cached plan can't smuggle a
    stale ring depth into a sync engine."""
    from repro.core.plan import SuperstepPlan
    with pytest.raises(ValueError, match="staleness"):
        SuperstepPlan(phases="async", staleness=0)
    with pytest.raises(ValueError, match="staleness"):
        SuperstepPlan(phases="sync", staleness=2)
    with pytest.raises(ValueError, match="staleness"):
        SuperstepPlan(phases="pipelined", staleness=2)
    good = SuperstepPlan(phases="async", staleness=3).to_json()
    assert good["staleness"] == 3
    from_wire = SuperstepPlan.from_json(good)
    assert from_wire.staleness == 3 and from_wire.phases == "async"


def test_superstep_plan_json_rejects_unknown_fields():
    """Schema drift fails loudly at load time — at the plan level AND
    inside the nested kernel dict — instead of silently dropping a knob
    a future version considered load-bearing."""
    from repro.core.plan import SuperstepPlan
    good = SuperstepPlan(strategy="flat", frontier_cap=64).to_json()
    with pytest.raises(ValueError, match="unknown"):
        SuperstepPlan.from_json({**good, "exchange_fanout": 4})
    with pytest.raises(ValueError, match="unknown"):
        SuperstepPlan.from_json(
            {**good, "kernel": {**good["kernel"], "vector_width": 8}})


def test_cached_plan_executes_bitwise_identical(tmp_path):
    """A plan round-tripped through the persistent cache file must drive
    `execute_plan` to BITWISE-identical results vs the in-memory
    original: adopting a cached plan may never change semantics, only
    speed."""
    from repro.core.plan import SuperstepPlan
    from repro.tuning import PlanCache
    plan = SuperstepPlan(strategy="compact", frontier_cap=64)
    cache = PlanCache(tmp_path / "plans.json")
    cache.store("k", plan, probe_us=1.0)
    reloaded = PlanCache(tmp_path / "plans.json").lookup("k")
    assert reloaded == plan

    g = _graph("rmat", 7, 8, 3)
    prog = algorithms.sssp_program()
    finals = []
    for p in (plan, reloaded):
        eng = GREEngine(prog, plan=p)
        part = DevicePartition.from_graph(g, bucket_bounds=p.bucket_bounds)
        finals.append(eng.run(part, eng.init_state(part, source=0), 64))
    np.testing.assert_array_equal(np.asarray(finals[0].vertex_data),
                                  np.asarray(finals[1].vertex_data))


# ------------------------------------------- dynamic block table (kernels)
def _check_dynamic_table(e, v, d, op, valid_frac, seed, block=64):
    """The on-device pruning pass vs the full table vs the XLA oracle, on
    a tile with `valid_frac` real lanes and sentinel (`dst == v`) padding:
    min/max must be bitwise, sum to float tolerance (the dst-sort
    reorders); the dynamic table must visit a subset of the full table's
    pairs that still covers every real edge block."""
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.segment_combine import (dynamic_block_table,
                                               tile_segment_combine_pallas)
    rng = np.random.default_rng(seed)
    valid = rng.random(e) < valid_frac
    dst = np.where(valid, rng.integers(0, v, e), v).astype(np.int32)
    ident = {"sum": 0.0, "min": np.inf, "max": -np.inf}[op]
    msgs = rng.normal(size=(e, d)).astype(np.float32)
    msgs[~valid] = ident
    kw = dict(block_e=block, block_v=block)
    dyn = tile_segment_combine_pallas(jnp.asarray(msgs), jnp.asarray(dst),
                                      v, op, **kw)
    full = tile_segment_combine_pallas(jnp.asarray(msgs), jnp.asarray(dst),
                                       v, op, dynamic=False, **kw)
    want = ref.segment_combine_ref(jnp.asarray(msgs),
                                   jnp.asarray(np.where(valid, dst, 0)),
                                   v, op)
    fix = lambda x: np.nan_to_num(np.asarray(x), posinf=1e30, neginf=-1e30)
    if op == "sum":
        np.testing.assert_allclose(fix(dyn), fix(want), rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(fix(full), fix(want), rtol=1e-5,
                                   atol=1e-5)
    else:
        np.testing.assert_array_equal(fix(dyn), fix(want))
        np.testing.assert_array_equal(fix(full), fix(want))
    # coverage: every (dst block, edge block) pair with a real dst in the
    # dst block's range appears in the sorted tile's table row
    ds = np.sort(dst)
    n_e = -(-e // block)
    table = np.asarray(dynamic_block_table(jnp.asarray(ds), v, block, block))
    dpad = np.concatenate([ds, np.full(n_e * block - e, v, np.int32)])
    dpad = dpad.reshape(n_e, block)
    for i in range(table.shape[0]):
        lo, hi = i * block, (i + 1) * block
        need = {j for j in range(n_e)   # real dsts only: sentinels (>= v)
                if ((dpad[j] >= lo) & (dpad[j] < hi)
                    & (dpad[j] < v)).any()}
        have = {int(x) for x in table[i] if x < n_e}
        assert need <= have
    # pruning: all-sentinel edge blocks never appear anywhere
    empty = {j for j in range(n_e) if (dpad[j] >= v).all()}
    seen = {int(x) for x in table.ravel() if x < n_e}
    assert not (empty & seen)


@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("e,v,d,valid_frac",
                         [(1000, 300, 8, 0.3), (513, 64, 1, 0.05),
                          (256, 256, 4, 1.0), (77, 33, 16, 0.5)])
def test_dynamic_block_table_fixed(e, v, d, valid_frac, op):
    _check_dynamic_table(e, v, d, op, valid_frac, seed=0)


# ------------------------------------------- multi-shard matrix (subprocess)
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "__SRC__")
import numpy as np
import jax

from repro.graph.generators import circulant_graph, rmat_edges
from repro.core.engine import GREEngine, DevicePartition
from repro.core.partition import hash_partition
from repro.core.agent_graph import build_agent_graph
from repro.core.dist_engine import DistGREEngine
from repro.core import algorithms

k = 8
mesh = jax.make_mesh((8,), ("graph",))
fix = lambda x: np.nan_to_num(x, posinf=-1.0)
failures = []

BACKENDS = ("agent", "dense", "pipelined")
STRATEGIES = ("dense", "flat", "compact", "auto")
MULTI = [0, 7, 33, 101]

def reference(program, part, source=None, max_steps=300):
    eng = GREEngine(program, frontier="dense")
    st = eng.run(part, eng.init_state(part, source=source), max_steps)
    return np.asarray(st.vertex_data)

def dist(program, ag, backend, strategy, source=None, max_steps=300, **kw):
    eng = DistGREEngine(program, mesh, ("graph",), exchange=backend,
                        frontier=strategy, frontier_cap=64, **kw)
    out, _ = eng.run(ag, source=source, max_steps=max_steps)
    return out

# Full matrix on the power-law graph: {agent, dense, pipelined}
# x {dense, compact, auto} x {single-source SSSP, multi-source BFS},
# all bitwise vs the single-shard dense reference.
g = rmat_edges(scale=7, edge_factor=8, seed=5, weights=True).dedup()
ag = build_agent_graph(g, hash_partition(g, k), k)
sp = DevicePartition.from_graph(g)
ss_ref = reference(algorithms.sssp_program(), sp, source=0)
ms_prog = algorithms.bfs_program(num_sources=len(MULTI))
ms_ref = np.stack([reference(algorithms.bfs_program(), sp, source=s,
                             max_steps=100) for s in MULTI], axis=1)
for backend in BACKENDS:
    for strategy in STRATEGIES:
        got = dist(algorithms.sssp_program(), ag, backend, strategy,
                   source=0)
        if not np.array_equal(fix(got), fix(ss_ref)):
            failures.append(f"rmat sssp {backend}/{strategy}")
        got = dist(ms_prog, ag, backend, strategy, source=MULTI,
                   max_steps=100)
        if not np.array_equal(fix(got), fix(ms_ref)):
            failures.append(f"rmat bfs-multi {backend}/{strategy}")

# AgentExchange(overlap=True) rewrites part.dst per superstep — the one
# backend variant outside the main matrix whose interaction with the
# compacted gather (csr_eidx position indirection) needs its own row.
got = dist(algorithms.sssp_program(), ag, "agent", "compact", source=0,
           overlap=True)
if not np.array_equal(fix(got), fix(ss_ref)):
    failures.append("rmat sssp agent-overlap/compact")

# The Pallas row (interpret mode): the tile combine's on-device dynamic
# block table under shard_map, through both the sync agent backend and the
# pipelined split tiles — bitwise vs the XLA dense reference.
for backend in ("agent", "pipelined"):
    got = dist(algorithms.sssp_program(), ag, backend, "compact", source=0,
               use_pallas=True)
    if not np.array_equal(fix(got), fix(ss_ref)):
        failures.append(f"rmat sssp {backend}/compact/pallas-dynamic")

# Async rows: bounded-staleness ring over REAL 8-shard crossings — the
# refresh collective fires every k supersteps, remote partials ride the
# k-deep ring, and the fixed point must still land bitwise on the sync
# reference (supersteps inflate ~k-fold per shard crossing; raise the
# step budget accordingly).
for st, strategy in ((2, "auto"), (2, "dense"), (4, "auto")):
    got = dist(algorithms.sssp_program(), ag, "async", strategy, source=0,
               staleness=st, max_steps=1200)
    if not np.array_equal(fix(got), fix(ss_ref)):
        failures.append(f"rmat sssp async-k{st}/{strategy}")
got = dist(ms_prog, ag, "async", "auto", source=MULTI, staleness=2,
           max_steps=800)
if not np.array_equal(fix(got), fix(ms_ref)):
    failures.append("rmat bfs-multi async-k2/auto")

# Circulant sub-matrix: the uniform-degree regime (single bucket live).
gc = circulant_graph(1 << 11, degree=8, weights=True, seed=1)
agc = build_agent_graph(gc, hash_partition(gc, k), k)
spc = DevicePartition.from_graph(gc)
cref = reference(algorithms.sssp_program(), spc, source=3, max_steps=600)
for backend in BACKENDS:
    got = dist(algorithms.sssp_program(), agc, backend, "auto", source=3,
               max_steps=600)
    if not np.array_equal(fix(got), fix(cref)):
        failures.append(f"circulant sssp {backend}/auto")
got = dist(algorithms.sssp_program(), agc, "async", "auto", source=3,
           staleness=2, max_steps=2400)
if not np.array_equal(fix(got), fix(cref)):
    failures.append("circulant sssp async-k2/auto")

# Mutation row: warm-start re-convergence after an edge delta on the REAL
# 8-shard mesh (the hash partition's tight pads exercise the compaction
# fallback in agent_graph.apply_edge_delta) — bitwise vs the cold
# single-shard dense recompute of the mutated graph.
from repro.graph.structures import EdgeDelta
rng = np.random.default_rng(21)
m = max(1, g.num_edges // 20)
pick = rng.choice(g.num_edges, size=m, replace=False)
add_s = rng.integers(0, g.num_vertices, size=m)
add_d = rng.integers(0, g.num_vertices, size=m)
# in-batch duplicate (src, dst) rows are rejected by delta ingress
_, first = np.unique(add_s.astype(np.int64) * g.num_vertices + add_d,
                     return_index=True)
keep = np.sort(first)
add_s, add_d = add_s[keep], add_d[keep]
delta = EdgeDelta(
    add_src=add_s, add_dst=add_d,
    add_props={"weight": rng.integers(1, 100, size=keep.size)
               .astype(np.float32)},
    rem_src=np.asarray(g.src)[pick], rem_dst=np.asarray(g.dst)[pick])
cold = reference(algorithms.sssp_program(),
                 DevicePartition.from_graph(g.apply_edge_delta(delta)),
                 source=0)
for backend in BACKENDS:
    eng = DistGREEngine(algorithms.sssp_program(), mesh, ("graph",),
                        exchange=backend, frontier="auto", frontier_cap=64)
    _, prev = eng.run(ag, source=0, max_steps=300)
    _, warm, _, _ = eng.rerun_incremental(ag, prev, delta, source=0,
                                          max_steps=300)
    if not np.array_equal(fix(warm), fix(cold)):
        failures.append(f"mutation warm sssp {backend}")

assert not failures, failures
print("CONFORMANCE_OK")
"""


@pytest.mark.slow
def test_conformance_matrix_8_devices(tmp_path):
    script = tmp_path / "conformance_check.py"
    script.write_text(SCRIPT.replace("__SRC__", SRC))
    proc = subprocess.run([sys.executable, str(script)], capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "CONFORMANCE_OK" in proc.stdout
